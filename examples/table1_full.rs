//! Full-fidelity Table I reproduction: all 19 SynthVTAB tasks × the full
//! strategy zoo. This is the long-running counterpart of
//! `cargo bench --bench table1` (which runs a scaled-down grid).
//!
//!   TASKEDGE_FULL=1 cargo run --release --example table1_full

use anyhow::Result;

use taskedge::coordinator::TrainConfig;
use taskedge::data::{Group, SYNTH_VTAB};
use taskedge::harness::{bench_scale, Experiment};
use taskedge::metrics::Summary;
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };
    let strategies: Vec<Strategy> = vec![
        Strategy::Full,
        Strategy::Linear,
        Strategy::BitFit,
        Strategy::Adapter,
        Strategy::Lora,
        Strategy::Vpt,
        Strategy::Magnitude { k: 2 },
        Strategy::Random { frac: 0.004 },
        Strategy::TaskEdge { k: 2 },
    ];

    let mut table = Table::new(
        "Table I (SynthVTAB-19, micro backbone)",
        &["strategy", "Natural", "Specialized", "Structured", "Mean",
          "Params %"],
    );
    for strategy in &strategies {
        let mut by_group = [Summary::default(), Summary::default(),
                            Summary::default()];
        let mut overall = Summary::default();
        let mut frac = Summary::default();
        // per-family lr, as in the table1 bench (PEFT recipes tune per method)
        let mut cfg_s = tcfg.clone();
        if matches!(strategy.family(),
                    taskedge::peft::Family::Lora
                    | taskedge::peft::Family::Vpt
                    | taskedge::peft::Family::Adapter) {
            cfg_s.lr = 5e-3;
        }
        for task in SYNTH_VTAB {
            let res = exp.run_task(task.name, strategy.clone(), cfg_s.clone(),
                                   scale.n_train, scale.n_eval)?;
            let top1 = res.record.best_top1();
            let g = match task.group {
                Group::Natural => 0,
                Group::Specialized => 1,
                Group::Structured => 2,
            };
            by_group[g].add(top1);
            overall.add(top1);
            frac.add(res.trainable_frac);
            println!(
                "  {} / {}: top1 {:.3} ({:.4}%)",
                task.name,
                strategy.name(),
                top1,
                res.trainable_frac * 100.0
            );
        }
        table.row(vec![
            strategy.name(),
            format!("{:.3}", by_group[0].mean()),
            format!("{:.3}", by_group[1].mean()),
            format!("{:.3}", by_group[2].mean()),
            format!("{:.3}", overall.mean()),
            format!("{:.4}", frac.mean() * 100.0),
        ]);
    }
    table.print();
    Ok(())
}
