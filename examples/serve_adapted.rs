//! Serving the adapted model: fine-tune once, then serve single-image
//! requests through the event-driven batching engine (`taskedge::serve`),
//! reporting throughput and queue/execute latency percentiles — the "edge
//! deployment" half of the paper's motivation (fine-tuned task-specific
//! models running on-device).
//!
//!   cargo run --release --example serve_adapted

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use taskedge::coordinator::TrainConfig;
use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::serve::{Server, ServerConfig};

fn main() -> Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let cfg = exp.rt.manifest().config(&exp.config)?.clone();
    let batch = exp.rt.manifest().batch;

    // Fine-tune on the target task. The session returns the tuned model as
    // a sparse TaskDelta over the backbone — exactly what the server wants.
    println!("fine-tuning syn-pets with TaskEdge (k=4)...");
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };
    let res = exp.run_task("pets", Strategy::TaskEdge { k: 4 }, tcfg,
                           scale.n_train, scale.n_eval)?;
    println!(
        "adapted: top1 {:.3} with {:.4}% params trainable, delta {} bytes \
         ({} values)\n",
        res.record.best_top1(),
        res.trainable_frac * 100.0,
        res.delta.file_bytes(),
        res.delta.num_values(),
    );

    // Serve: single-image requests through the dynamic batching engine.
    // The batch plan (artifact, binding order, padded buffer geometry) is
    // resolved once inside Server::new; workers wake on condvar signals.
    let task = task_by_name("pets")?;
    let n_requests = 64 * batch;
    let (_, pool) = generate_task(task, cfg.image_size, 1, n_requests, 99)?;
    let isz = pool.image_numel();
    let image = |i: usize| pool.images[i * isz..(i + 1) * isz].to_vec();

    // backbone + TaskDelta = the served model (no full-store copy per task)
    let server = Arc::new(Server::from_delta(
        exp.rt.clone(),
        &exp.config,
        Arc::new(exp.backbone.clone()),
        &res.delta,
        ServerConfig {
            linger: Duration::from_millis(2),
            workers: 2,
            max_queue: n_requests,
        },
    )?);

    println!("serving {n_requests} requests (dynamic batches of {batch})...");
    let (wall, e2e) = std::thread::scope(|scope| -> Result<_> {
        let srv = server.clone();
        let run = scope.spawn(move || srv.run());
        // drive inside a closure so shutdown always runs before the scope
        // joins the server thread, even if a submit/recv fails
        let drive = || -> Result<_> {
            // warm the executable cache: the report excludes the XLA compile
            server
                .submit(image(0))?
                .recv_timeout(Duration::from_secs(120))?;

            let t0 = Instant::now();
            let receivers: Vec<_> = (0..pool.n)
                .map(|i| server.submit(image(i)))
                .collect::<Result<_>>()?;
            let mut e2e = taskedge::metrics::Histogram::new();
            for rx in receivers {
                let resp = rx.recv_timeout(Duration::from_secs(300))?;
                debug_assert!(resp.logits.iter().all(|v| v.is_finite()));
                e2e.record(resp.latency);
            }
            Ok((t0.elapsed(), e2e))
        };
        let result = drive();
        server.shutdown();
        run.join().unwrap()?;
        result
    })?;

    let stats = server.stats();
    println!("\n== serving report ==");
    println!("requests          : {} (+1 warmup)", n_requests);
    println!("batches           : {} ({} rows padded)", stats.batches, stats.padded_rows);
    println!("throughput        : {:.0} img/s", n_requests as f64 / wall.as_secs_f64());
    println!("e2e latency       : {}", e2e.summary());
    println!("queue latency     : {}", stats.queue.summary());
    println!("execute latency   : {}", stats.execute.summary());
    Ok(())
}
