//! Serving the adapted model: fine-tune once, then serve batched inference
//! requests through the `fwd` artifact, reporting latency percentiles and
//! throughput — the "edge deployment" half of the paper's motivation
//! (fine-tuned task-specific models running on-device).
//!
//!   cargo run --release --example serve_adapted

use anyhow::{bail, Result};

use taskedge::coordinator::TrainConfig;
use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::runtime::IoBinder;

fn main() -> Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let cfg = exp.rt.manifest().config(&exp.config)?.clone();
    let batch = exp.rt.manifest().batch;

    // Fine-tune on the target task. NOTE: the dense session returns masks
    // but the adapted weights live inside the session; for serving we
    // simply rerun a short session and keep the backbone + head protocol —
    // here we demonstrate the serving path with the pretrained backbone.
    println!("fine-tuning syn-pets with TaskEdge (k=4)...");
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };
    let res = exp.run_task("pets", Strategy::TaskEdge { k: 4 }, tcfg,
                           scale.n_train, scale.n_eval)?;
    println!(
        "adapted: top1 {:.3} with {:.4}% params trainable\n",
        res.record.best_top1(),
        res.trainable_frac * 100.0
    );

    // Serve: batched requests through the fwd artifact.
    let task = task_by_name("pets")?;
    let n_requests = 64 * batch;
    let (_, pool) = generate_task(task, cfg.image_size, 1, n_requests, 99)?;
    let spec = exp.rt.manifest().artifact_for("fwd", &exp.config)?.clone();
    let binder = IoBinder::new(&spec);

    println!("serving {n_requests} requests in batches of {batch}...");
    // warm the executable cache so the first request doesn't pay XLA compile
    {
        let ids: Vec<usize> = (0..batch).collect();
        let (images, _) = pool.batch(&ids)?;
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(exp.backbone.get(p)?.clone())
            } else {
                Ok(images.clone())
            }
        })?;
        exp.rt.execute(&spec.name, &inputs)?;
    }
    let mut latencies_ms = Vec::new();
    let t_all = std::time::Instant::now();
    for start in (0..pool.n).step_by(batch) {
        let ids: Vec<usize> = (start..start + batch).collect();
        let (images, _) = pool.batch(&ids)?;
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(exp.backbone.get(p)?.clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else {
                bail!("unexpected fwd input {}", io.name)
            }
        })?;
        let t0 = std::time::Instant::now();
        let outputs = exp.rt.execute(&spec.name, &inputs)?;
        latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        // sanity: logits present and finite
        let logits = binder.output(&outputs, "logits")?;
        debug_assert!(logits.f32s()?.iter().all(|v| v.is_finite()));
    }
    let total_s = t_all.elapsed().as_secs_f64();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies_ms[(latencies_ms.len() as f64 * p) as usize];
    println!("\n== serving report ==");
    println!("requests          : {n_requests}");
    println!("batch size        : {batch}");
    println!("throughput        : {:.0} img/s", n_requests as f64 / total_s);
    println!("batch latency p50 : {:.2} ms", pct(0.50));
    println!("batch latency p95 : {:.2} ms", pct(0.95));
    println!("batch latency p99 : {:.2} ms", pct(0.99));
    println!("per-image latency : {:.3} ms (p50)", pct(0.50) / batch as f64);
    Ok(())
}
