//! Quickstart: the minimal TaskEdge loop.
//!
//! Loads the AOT artifacts, builds a (non-pretrained) micro backbone,
//! runs the full pipeline — calibrate -> score -> allocate -> sparse
//! fine-tune -> eval — on one SynthVTAB task, and prints the outcome.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use taskedge::coordinator::{FinetuneSession, TrainConfig};
use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::Experiment;
use taskedge::peft::Strategy;
use taskedge::runtime::Runtime;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn main() -> Result<()> {
    let artifacts = Experiment::default_artifacts();
    let rt = Runtime::load(&artifacts)?;
    let config = "micro";
    let cfg = rt.manifest().config(config)?;
    let batch = rt.manifest().batch;
    println!(
        "loaded manifest: {} artifacts, config {config} = {} params",
        rt.manifest().artifacts.len(),
        cfg.num_params
    );

    // Fresh backbone (see examples/finetune_edge_fleet.rs for the
    // pretrain-then-finetune end-to-end driver).
    let backbone = ParamStore::init(cfg, &mut Rng::new(7));

    let task = task_by_name("caltech101")?;
    let n_eval = 96usize.div_ceil(batch) * batch;
    let (train, eval) = generate_task(task, cfg.image_size, 256, n_eval, 7)?;
    println!("task {}: {} train / {} eval images", task.name, train.n, eval.n);

    let strategy = Strategy::TaskEdge { k: 8 };
    let tcfg = TrainConfig { epochs: 3, lr: 1e-3, seed: 7, ..Default::default() };
    let mut session = FinetuneSession::new(&rt, config, strategy.clone(), tcfg)?;
    let result = session.run(&backbone, &train, &eval, task.name)?;

    println!("\n== quickstart result ==");
    println!("strategy          : {}", strategy.name());
    println!(
        "trainable params  : {} ({:.4}% of {})",
        result.trainable_params,
        result.trainable_frac * 100.0,
        cfg.num_params
    );
    for e in &result.record.curve {
        println!(
            "epoch {}: train loss {:.4}, eval top1 {:.3}, top5 {:.3}",
            e.epoch, e.train_loss, e.eval_top1, e.eval_top5
        );
    }
    let stats = rt.stats();
    println!(
        "runtime: {} executions, {:.1} ms avg",
        stats.executions,
        stats.execute_ns as f64 / stats.executions.max(1) as f64 / 1e6
    );
    Ok(())
}
