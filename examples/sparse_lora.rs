//! Sparse low-rank adaptation (paper §III-D, Eq. 6): ΔW = (B·A) ⊙ M.
//!
//! Compares plain LoRA (all-ones mask) against TaskEdge-masked sparse LoRA
//! on one SynthVTAB task, demonstrating the plug-and-play integration: the
//! same AOT lora_train graph serves both — only the mask differs.
//!
//!   cargo run --release --example sparse_lora

use anyhow::Result;

use taskedge::coordinator::TrainConfig;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 5e-3, seed: 42,
                             ..Default::default() };

    let mut table = Table::new(
        "LoRA vs sparse-LoRA (Eq. 6) on syn-caltech101",
        &["strategy", "top1", "top5", "trainable", "mask density"],
    );
    for strategy in [Strategy::Lora, Strategy::SparseLora { k: 4 },
                     Strategy::SparseLora { k: 16 }] {
        let res = exp.run_task("caltech101", strategy.clone(), tcfg.clone(),
                               scale.n_train, scale.n_eval)?;
        let density: f64 = {
            let total: usize = res.masks.values().map(|m| m.numel()).sum();
            let ones: usize = res.masks.values().map(|m| m.count_ones()).sum();
            ones as f64 / total.max(1) as f64
        };
        table.row(vec![
            strategy.name(),
            format!("{:.3}", res.record.best_top1()),
            format!("{:.3}", res.record.best_top5()),
            format!("{}", res.trainable_params),
            format!("{:.4}", density),
        ]);
    }
    table.print();
    println!(
        "\nNote: sparse-LoRA keeps the SAME trainable factor count as LoRA \
         but constrains the effective update support to the task-aware mask \
         (Eq. 6) — the paper's 'plug-and-play' claim."
    );
    Ok(())
}
