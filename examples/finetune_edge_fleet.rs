//! End-to-end driver (DESIGN.md §5): proves all three layers compose on a
//! real small workload.
//!
//! 1. Pretrains the ViT backbone FROM SCRATCH on the synthetic upstream
//!    corpus (a few hundred steps through the AOT `train_sgd` graph),
//!    logging the loss curve.
//! 2. Runs the full TaskEdge pipeline (calibrate -> score -> allocate ->
//!    sparse-train -> eval) on real SynthVTAB tasks across a simulated
//!    edge-device fleet with memory admission control.
//! 3. Reports accuracy, trainable %, steps/s, and modeled device cost.
//!
//! Results are recorded in EXPERIMENTS.md. Scale with TASKEDGE_FULL=1.
//!
//!   cargo run --release --example finetune_edge_fleet

use std::sync::Arc;

use anyhow::Result;

use taskedge::coordinator::{pretrain, Fleet, Job, PretrainConfig, TrainConfig};
use taskedge::data::{task_by_name, upstream_corpus};
use taskedge::edge::profiles::profile_by_name;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::runtime::Runtime;
use taskedge::util::bench::Table;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn main() -> Result<()> {
    let scale = bench_scale();
    let artifacts = Experiment::default_artifacts();
    let config = "micro";
    let rt = Arc::new(Runtime::load(&artifacts)?);
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;

    // ---- Stage 1: pretrain the backbone from scratch -------------------
    println!("== stage 1: upstream pretraining ({} steps) ==", scale.pretrain_steps);
    let corpus = upstream_corpus(cfg.image_size, cfg.num_classes, 2048, 42)?;
    let mut backbone = ParamStore::init(&cfg, &mut Rng::new(42));
    let t0 = std::time::Instant::now();
    let report = pretrain(
        &rt,
        config,
        &mut backbone,
        &corpus,
        &PretrainConfig { steps: scale.pretrain_steps, seed: 42, ..Default::default() },
    )?;
    let pretrain_s = t0.elapsed().as_secs_f64();
    println!("loss curve (step, loss, acc):");
    for (step, loss, acc) in &report.loss_curve {
        println!("  {step:>5}  {loss:.4}  {acc:.3}");
    }
    println!(
        "pretrained in {:.1}s ({:.2} steps/s)\n",
        pretrain_s,
        scale.pretrain_steps as f64 / pretrain_s
    );

    // ---- Stage 2: TaskEdge fine-tuning across the edge fleet -----------
    println!("== stage 2: edge fleet fine-tuning ==");
    let tcfg = TrainConfig {
        epochs: scale.epochs,
        lr: 1e-3,
        seed: 42,
        ..Default::default()
    };
    let tasks = ["caltech101", "dtd", "clevr/count"];
    let strategies = [
        Strategy::TaskEdge { k: 4 },
        Strategy::Linear,
        Strategy::BitFit,
    ];
    let mut jobs = Vec::new();
    for t in tasks {
        for s in &strategies {
            jobs.push(Job {
                task: task_by_name(t)?.clone(),
                strategy: s.clone(),
                train_cfg: tcfg.clone(),
                n_train: scale.n_train,
                n_eval: scale.n_eval.div_ceil(batch) * batch,
            });
        }
    }
    let devices = vec![
        profile_by_name("jetson-orin-nano").unwrap(),
        profile_by_name("jetson-nano").unwrap(),
        profile_by_name("phone-flagship").unwrap(),
    ];
    let fleet = Fleet::new(devices);
    let backbone = Arc::new(backbone);
    let t0 = std::time::Instant::now();
    let reports = fleet.run(rt.clone(), config, backbone, jobs, 42)?;
    let fleet_s = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "edge fleet results",
        &["task", "strategy", "device", "top1", "top5", "train %",
          "req MB", "wall ms", "sim J"],
    );
    for r in &reports {
        table.row(vec![
            r.task.clone(),
            r.strategy.clone(),
            r.device.clone(),
            format!("{:.3}", r.top1),
            format!("{:.3}", r.top5),
            format!("{:.4}", r.trainable_frac * 100.0),
            format!("{:.0}", r.required_mb),
            format!("{:.0}", r.wall_ms),
            format!("{:.1}", r.sim_energy_j),
        ]);
    }
    table.print();

    let stats = rt.stats();
    let steps = stats.executions;
    println!(
        "\nfleet wall {:.1}s | {} graph executions | {:.2} exec/s | \
         avg exec {:.1} ms",
        fleet_s,
        steps,
        steps as f64 / fleet_s,
        stats.execute_ns as f64 / steps.max(1) as f64 / 1e6,
    );
    Ok(())
}
