//! Broken-corpus integration tests for `taskedge check`.
//!
//! Every fixture under `tests/fixtures/check/broken/` isolates exactly one
//! contract violation and must yield its *specific* finding code — not a
//! generic failure — while `tests/fixtures/check/good/` must come back
//! completely clean. CI runs the same corpus through the CLI binary (see
//! .github/workflows/ci.yml, `check` job), so these tests and the shipped
//! exit-code behaviour cannot drift apart.

use std::path::{Path, PathBuf};

use taskedge::analysis::{check_dir, check_manifest_text, has_errors, render_human, Finding};
use taskedge::runtime::{HostTensor, Manifest};
use taskedge::vit::{SparseTensorDelta, TaskDelta};

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/check")
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("taskedge_check_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_code(fs: &[Finding], code: &str, ctx: &str) {
    assert!(
        fs.iter().any(|f| f.code == code),
        "{ctx}: expected finding {code:?}, got:\n{}",
        render_human(fs)
    );
}

/// (fixture file, expected finding code, finding is error-severity)
const BROKEN: &[(&str, &str, bool)] = &[
    ("bad_json.json", "parse.json", true),
    ("dup_config_key.json", "parse.duplicate-key", true),
    ("bad_version.json", "manifest.version", true),
    ("missing_field.json", "manifest.missing-field", true),
    ("bad_dtype.json", "manifest.bad-dtype", true),
    ("bad_shape.json", "manifest.bad-shape", true),
    ("dup_artifact.json", "manifest.dup-artifact", true),
    ("dangling_config.json", "manifest.dangling-config", true),
    ("batch_skew.json", "manifest.batch-skew", true),
    ("num_params_mismatch.json", "config.num-params-mismatch", true),
    ("dup_param.json", "manifest.dup-param", true),
    ("bad_lora_target.json", "config.bad-lora-target", true),
    ("bad_lora_target_type.json", "manifest.bad-type", true),
    ("bad_adapter.json", "config.bad-adapter", true),
    ("unroutable_input.json", "plan.unroutable-input", true),
    ("unknown_param.json", "plan.unknown-param", true),
    ("sink_no_source.json", "plan.sink-no-source", true),
    ("shape_mismatch.json", "plan.shape-mismatch", true),
    ("missing_output.json", "plan.missing-output", true),
    ("dup_io.json", "plan.dup-io", true),
    ("frozen_mutated.json", "plan.frozen-mutated", true),
    ("bad_stat.json", "plan.unknown-stat", true),
    ("grad_numel_mismatch.json", "plan.shape-mismatch", true),
    ("noncanonical_name.json", "manifest.noncanonical-name", false),
    ("unknown_kind.json", "plan.unknown-kind", false),
];

#[test]
fn every_broken_fixture_yields_its_specific_code() {
    for (file, code, is_error) in BROKEN {
        let path = fixtures().join("broken").join(file);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let fs = check_manifest_text(&text, None);
        assert_code(&fs, code, file);
        assert_eq!(
            has_errors(&fs),
            *is_error,
            "{file}: error gating disagrees with the table:\n{}",
            render_human(&fs)
        );
    }
}

#[test]
fn the_table_covers_the_whole_corpus() {
    let dir = fixtures().join("broken");
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        assert!(
            BROKEN.iter().any(|(f, _, _)| *f == name),
            "fixture {name:?} has no expectation row — add it to BROKEN"
        );
    }
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), BROKEN.len());
}

#[test]
fn manifest_level_breakage_also_fails_the_strict_parser() {
    // the walk is a superset of Manifest::parse: anything the walk flags at
    // parse level must be rejected by the strict parser too
    for file in ["bad_json.json", "dup_config_key.json", "bad_dtype.json"] {
        let text = std::fs::read_to_string(fixtures().join("broken").join(file)).unwrap();
        assert!(Manifest::parse(&text).is_err(), "{file}: strict parse accepted it");
    }
}

#[test]
fn good_corpus_is_completely_clean() {
    let fs = check_dir(&fixtures().join("good"), &[]);
    assert!(
        fs.is_empty(),
        "good corpus must produce zero findings, got:\n{}",
        render_human(&fs)
    );
}

#[test]
fn missing_artifact_file_is_reported() {
    let dir = scratch("nofiles");
    let manifest = fixtures().join("good/manifest.json");
    std::fs::copy(&manifest, dir.join("manifest.json")).unwrap();
    let fs = check_dir(&dir, &[]);
    assert_code(&fs, "artifact.missing-file", "manifest without .hlo files");
    assert!(has_errors(&fs));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compatible_delta_admits_cleanly() {
    let dir = scratch("delta_ok");
    let mut d = TaskDelta::new("t");
    d.task = "pets".to_string();
    d.strategy = "taskedge_k8".to_string();
    d.sparse.insert(
        "head/kernel".to_string(),
        SparseTensorDelta { shape: vec![4, 10], indices: vec![1, 5], values: vec![0.1, 0.2] },
    );
    let path = dir.join("pets.tedl");
    d.save(&path).unwrap();
    let fs = check_dir(&fixtures().join("good"), &[("pets".to_string(), path)]);
    assert!(
        !has_errors(&fs),
        "compatible delta must admit, got:\n{}",
        render_human(&fs)
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn broken_deltas_yield_specific_codes() {
    let dir = scratch("delta_bad");
    let good = fixtures().join("good");

    // unreadable file
    let fs = check_dir(&good, &[("pets".to_string(), dir.join("absent.tedl"))]);
    assert_code(&fs, "delta.load", "missing delta file");

    // mislabeled task + unknown target + stale shape + unordered indices
    let mut d = TaskDelta::new("t");
    d.task = "other".to_string();
    d.sparse.insert(
        "head/kernel".to_string(),
        SparseTensorDelta { shape: vec![4, 4], indices: vec![1], values: vec![0.5] },
    );
    d.sparse.insert(
        "ghost".to_string(),
        SparseTensorDelta { shape: vec![2], indices: vec![0], values: vec![0.5] },
    );
    let p1 = dir.join("bad1.tedl");
    d.save(&p1).unwrap();
    let fs = check_dir(&good, &[("pets".to_string(), p1)]);
    assert_code(&fs, "delta.task-mismatch", "bad1");
    assert_code(&fs, "delta.stale-shape", "bad1");
    assert_code(&fs, "delta.unknown-target", "bad1");

    // non-increasing indices
    let mut d = TaskDelta::new("t");
    d.task = "pets".to_string();
    d.sparse.insert(
        "head/kernel".to_string(),
        SparseTensorDelta { shape: vec![4, 10], indices: vec![7, 3], values: vec![0.0; 2] },
    );
    let p2 = dir.join("bad2.tedl");
    d.save(&p2).unwrap();
    let fs = check_dir(&good, &[("pets".to_string(), p2)]);
    assert_code(&fs, "delta.index-order", "bad2");

    // index past the param's element count (stale mask shape)
    let mut d = TaskDelta::new("t");
    d.task = "pets".to_string();
    d.sparse.insert(
        "head/kernel".to_string(),
        SparseTensorDelta { shape: vec![4, 10], indices: vec![50, 99], values: vec![0.0; 2] },
    );
    let p2b = dir.join("bad2b.tedl");
    d.save(&p2b).unwrap();
    let fs = check_dir(&good, &[("pets".to_string(), p2b)]);
    assert_code(&fs, "delta.index-bounds", "bad2b");

    // delta against a config the manifest does not define
    let mut d = TaskDelta::new("ghost_cfg");
    d.task = "pets".to_string();
    d.dense.insert("head/kernel".to_string(), HostTensor::zeros(&[4, 10]));
    let p3 = dir.join("bad3.tedl");
    d.save(&p3).unwrap();
    let fs = check_dir(&good, &[("pets".to_string(), p3)]);
    assert_code(&fs, "delta.unknown-config", "bad3");

    std::fs::remove_dir_all(&dir).ok();
}
