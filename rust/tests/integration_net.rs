//! Loopback integration for the networked fleet transport: a real TCP
//! coordinator ([`FleetServer`] + [`NetRunner`]) driving real
//! [`participate`] threads. The contract under test is bit-identity: a
//! round run over the wire must produce exactly the delta files, digests,
//! and journal a plain in-process [`SimRunner`] round produces — through
//! participant disconnects, coordinator kills, and corrupted uploads.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use taskedge::coordinator::fleet::{Job, JobStatus};
use taskedge::coordinator::rounds::JOURNAL_FILE;
use taskedge::coordinator::{
    run_round, FaultPlan, JobRunner, RoundConfig, RoundReport, SimRunner,
    TrainConfig,
};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::edge::DeviceProfile;
use taskedge::net::{
    install_shipped_journal, participate, stand_by, FleetServer, NetConfig,
    NetRunner, NetState, ParticipantOpts, ParticipantStats, StandbyOpts,
};
use taskedge::util::json::Json;

const DEVICES: [&str; 3] =
    ["jetson-orin-nano", "jetson-nano", "phone-flagship"];

/// One job per PEFT family — all admit on the device pool above.
const SPECS: [(&str, &str); 4] = [
    ("pets", "taskedge:k=2"),
    ("dtd", "lora"),
    ("eurosat", "vpt"),
    ("svhn", "adapter"),
];

fn jobs(seed: u64) -> Vec<Job> {
    SPECS
        .iter()
        .map(|(task, strategy)| Job {
            task: task_by_name(task).unwrap().clone(),
            strategy: taskedge::peft::Strategy::parse(strategy).unwrap(),
            train_cfg: TrainConfig { seed, ..Default::default() },
            n_train: 8,
            n_eval: 4,
        })
        .collect()
}

fn devs() -> Vec<&'static DeviceProfile> {
    DEVICES.iter().map(|n| profile_by_name(n).unwrap()).collect()
}

fn tmp_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("taskedge_net_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn digests(r: &RoundReport) -> BTreeMap<(String, String), String> {
    r.reports
        .iter()
        .filter_map(|r| {
            r.delta_digest
                .clone()
                .map(|d| ((r.task.clone(), r.strategy.clone()), d))
        })
        .collect()
}

/// Drained delta file bytes per (task, strategy).
fn delta_files(r: &RoundReport) -> BTreeMap<(String, String), Vec<u8>> {
    r.reports
        .iter()
        .filter_map(|rep| {
            rep.delta_path.as_ref().map(|p| {
                (
                    (rep.task.clone(), rep.strategy.clone()),
                    std::fs::read(p).unwrap(),
                )
            })
        })
        .collect()
}

fn state(seed: u64, faults: FaultPlan) -> Arc<NetState> {
    state_cfg(seed, faults, 2_000, 1)
}

fn state_cfg(
    seed: u64,
    faults: FaultPlan,
    heartbeat_timeout_ms: u64,
    generation: u64,
) -> Arc<NetState> {
    NetState::new(NetConfig {
        config_name: "sim".to_string(),
        seed,
        heartbeat_timeout_ms,
        faults,
        backbone: None,
        generation,
    })
}

/// Reserve a concrete loopback address for a promoted standby to bind
/// later (participants must learn a fixed address from welcome frames, so
/// `127.0.0.1:0` won't do).
fn reserve_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().to_string()
}

/// One [`participate`] thread per device; `once: false` so participants
/// survive coordinator kills via their reconnect loop.
fn spawn_fleet(
    addr: &str,
    seed: u64,
    fault_specs: &[(&str, &str)],
) -> Vec<std::thread::JoinHandle<anyhow::Result<ParticipantStats>>> {
    DEVICES
        .iter()
        .map(|d| {
            let spec = fault_specs
                .iter()
                .find(|(dev, _)| dev == d)
                .map(|(_, s)| s.to_string());
            let opts = ParticipantOpts {
                addr: addr.to_string(),
                device: d.to_string(),
                seed,
                backoff_ms: 5,
                max_reconnects: 500,
                once: false,
                heartbeat_ms: 0,
                faults: match spec {
                    Some(s) => FaultPlan::parse(&s, seed).unwrap(),
                    None => FaultPlan::default(),
                },
            };
            std::thread::spawn(move || {
                participate(&opts, |welcome, _| {
                    Ok(Box::new(SimRunner::new(welcome.seed)?)
                        as Box<dyn JobRunner>)
                })
            })
        })
        .collect()
}

fn join_fleet(
    handles: Vec<std::thread::JoinHandle<anyhow::Result<ParticipantStats>>>,
) -> Vec<ParticipantStats> {
    handles
        .into_iter()
        .map(|h| h.join().expect("participant thread panicked").unwrap())
        .collect()
}

/// In-process ground truth: the same jobs on a plain [`SimRunner`].
fn sim_round(seed: u64, dir: &Path) -> RoundReport {
    let runner = SimRunner::new(seed).unwrap();
    let cfg = RoundConfig {
        seed,
        delta_dir: Some(dir.to_path_buf()),
        ..RoundConfig::default()
    };
    run_round(runner.manifest(), &devs(), &jobs(seed), &runner, &cfg).unwrap()
}

/// A TCP coordinator + 3 participants — one of them disconnecting the
/// moment Train starts and rejoining — must complete the round with delta
/// files and digests byte-identical to the in-process SimRunner round.
#[test]
fn tcp_round_is_bit_identical_to_sim_runner() {
    const SEED: u64 = 71;
    let dir_sim = tmp_dir("sim_truth");
    let dir_tcp = tmp_dir("tcp_round");
    let sim = sim_round(SEED, &dir_sim);
    assert_eq!(sim.summary.accepted, SPECS.len());

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let fleet = spawn_fleet(
        &server.addr.to_string(),
        SEED,
        &[("jetson-nano", "disconnect=jetson-nano@train")],
    );
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        ..RoundConfig::default()
    };
    let round = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    server.shutdown();
    let stats = join_fleet(fleet);

    assert_eq!(round.summary.accepted, SPECS.len());
    for r in &round.reports {
        assert_eq!(r.status, JobStatus::Accepted);
    }
    assert_eq!(digests(&round), digests(&sim), "digest maps must match");
    assert_eq!(
        delta_files(&round),
        delta_files(&sim),
        "drained delta files must be byte-identical over the wire"
    );
    let reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    assert!(
        reconnects >= 1,
        "the injected mid-Train disconnect must force at least one rejoin"
    );

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// Kill the coordinator (no shutdown frame), truncate the journal after
/// the first accept, restart on the SAME port with `resume: true`: the
/// surviving accepts replay bit-identically, the participants re-attach
/// through their reconnect loops, and the final state matches SimRunner.
#[test]
fn coordinator_kill_and_resume_replays_bit_identically() {
    const SEED: u64 = 83;
    let dir_sim = tmp_dir("resume_truth");
    let dir_tcp = tmp_dir("resume_tcp");
    let sim = sim_round(SEED, &dir_sim);

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let addr = server.addr.to_string();
    let fleet = spawn_fleet(&addr, SEED, &[]);
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        ..RoundConfig::default()
    };
    let first = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    assert_eq!(first.summary.accepted, SPECS.len());
    server.kill(); // crash: participants reconnect instead of exiting
    drop(server);
    drop(net);

    // the mid-round power cut: keep the journal only up to the first accept
    let journal = dir_tcp.join(JOURNAL_FILE);
    let text = std::fs::read_to_string(&journal).unwrap();
    let mut kept = Vec::new();
    let mut accepts = 0;
    for line in text.lines() {
        kept.push(line);
        if line.contains("\"kind\":\"accept\"") {
            accepts += 1;
            if accepts == 1 {
                break;
            }
        }
    }
    assert_eq!(accepts, 1, "round must have journaled accepts to truncate");
    std::fs::write(&journal, format!("{}\n", kept.join("\n"))).unwrap();

    let st2 = state(SEED, FaultPlan::default());
    let mut server2 = FleetServer::start(&addr, st2.clone())
        .expect("restarted coordinator must reclaim its port");
    server2
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();
    let net2 = NetRunner::new(st2, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let resume_cfg = RoundConfig { resume: true, ..cfg };
    let resumed =
        run_round(&manifest, &devs(), &jobs(SEED), &net2, &resume_cfg).unwrap();
    server2.shutdown();
    let stats = join_fleet(fleet);

    assert_eq!(resumed.summary.replayed, 1, "the surviving accept replays");
    assert_eq!(resumed.summary.accepted, SPECS.len());
    assert_eq!(digests(&resumed), digests(&sim));
    assert_eq!(
        delta_files(&resumed),
        delta_files(&sim),
        "post-resume delta files must be byte-identical to SimRunner's"
    );
    let reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    assert!(
        reconnects >= DEVICES.len(),
        "every participant must reconnect across the kill ({reconnects})"
    );

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// A corrupted upload must be rejected by admission analysis, retried,
/// and must never reach the journal: every journaled accept digest is one
/// the in-process ground-truth round also produced.
#[test]
fn corrupted_upload_is_rejected_and_never_journaled() {
    const SEED: u64 = 97;
    let dir_sim = tmp_dir("corrupt_truth");
    let dir_tcp = tmp_dir("corrupt_tcp");
    let sim = sim_round(SEED, &dir_sim);
    let sim_digests: BTreeSet<String> =
        digests(&sim).into_values().collect();

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let fleet = spawn_fleet(&server.addr.to_string(), SEED, &[]);
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        backoff_ms: 1,
        // job 0's first upload is corrupted after transport — admission
        // analysis must bounce it and the engine must retry clean
        faults: FaultPlan::parse("corrupt@0", SEED).unwrap(),
        ..RoundConfig::default()
    };
    let round = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    server.shutdown();
    join_fleet(fleet);

    assert_eq!(round.summary.accepted, SPECS.len());
    assert!(round.summary.rejected_uploads >= 1, "the corrupt upload bounces");
    assert!(round.summary.retries >= 1, "the bounced job retries");
    assert_eq!(digests(&round), digests(&sim), "final digests stay identical");

    // scan the journal: every accepted digest must be a ground-truth one
    let text =
        std::fs::read_to_string(dir_tcp.join(JOURNAL_FILE)).unwrap();
    let mut journaled = 0;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if j.get("kind").and_then(Json::as_str) != Some("accept") {
            continue;
        }
        let digest = j
            .get("report")
            .and_then(|r| r.get("delta_digest"))
            .and_then(Json::as_str)
            .expect("journaled accept must carry a digest")
            .to_string();
        assert!(
            sim_digests.contains(&digest),
            "corrupted bytes reached the journal: {digest}"
        );
        journaled += 1;
    }
    assert_eq!(journaled, SPECS.len(), "one journaled accept per job");

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// The HA path end-to-end: a hot standby attaches and receives every
/// journal entry (snapshot + live stream); `killprimary@collect` kills the
/// primary after all four accepts are journaled — and therefore shipped —
/// so the standby's lease expires, it promotes one generation up, the
/// participants re-target the advertised address, and the promoted
/// coordinator finishes the round through `--resume` replay with delta
/// files bit-identical to the uninterrupted SimRunner round.
#[test]
fn standby_promotes_after_primary_kill_and_finishes_bit_identically() {
    const SEED: u64 = 109;
    let dir_sim = tmp_dir("ha_truth");
    let dir_tcp = tmp_dir("ha_tcp");
    let dir_ship = tmp_dir("ha_ship");
    std::fs::create_dir_all(&dir_ship).unwrap();
    let sim = sim_round(SEED, &dir_sim);

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let addr = server.addr.to_string();
    let fleet = spawn_fleet(&addr, SEED, &[]);
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let standby_addr = reserve_addr();
    let ship_journal = dir_ship.join("ship.journal");
    let sopts = StandbyOpts {
        primary: addr.clone(),
        advertise: standby_addr.clone(),
        journal_path: ship_journal.clone(),
        lease_ms: 2_000,
        backoff_ms: 20,
        seed: SEED,
    };
    let standby = std::thread::spawn(move || stand_by(&sopts));
    // the broadcast welcome that announces the standby is what the
    // participants re-target on, so wait for the attach before racing it
    let t0 = std::time::Instant::now();
    while st.standby_addr().is_none() {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "standby never attached to the primary"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st.clone(), manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        faults: FaultPlan::parse("killprimary@collect", SEED).unwrap(),
        shipper: Some(st.journal_shipper()),
        ..RoundConfig::default()
    };
    let err = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg)
        .expect_err("killprimary@collect must abort the primary's round");
    assert!(
        format!("{err:#}").contains("primary coordinator killed"),
        "{err:#}"
    );
    // kill -9 semantics: no shutdown frames to anyone — the participants
    // and the standby both see a dead peer, not a clean goodbye
    server.kill();
    drop(server);
    drop(net);

    let report = standby.join().unwrap().unwrap();
    assert!(report.promoted, "lease expiry must promote the standby");
    assert_eq!(report.seed, SEED);
    assert_eq!(report.generation, 1);
    assert!(report.entries > 0, "live journal entries must have shipped");

    // promotion: install the shipped journal over the round's delta dir
    // and finish the round at the advertised address, one generation up
    install_shipped_journal(&ship_journal, &dir_tcp).unwrap();
    let st2 =
        state_cfg(SEED, FaultPlan::default(), 2_000, report.generation + 1);
    let mut server2 = FleetServer::start(&standby_addr, st2.clone())
        .expect("promoted standby must bind its advertised address");
    server2
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();
    let net2 = NetRunner::new(st2.clone(), manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let resume_cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        resume: true,
        shipper: Some(st2.journal_shipper()),
        ..RoundConfig::default()
    };
    let resumed =
        run_round(&manifest, &devs(), &jobs(SEED), &net2, &resume_cfg)
            .unwrap();
    server2.shutdown();
    let stats = join_fleet(fleet);

    // zero accepted-upload loss: every accept the primary journaled was
    // shipped before it was acked, so the promoted round replays them all
    assert_eq!(resumed.summary.replayed, SPECS.len());
    assert_eq!(resumed.summary.accepted, SPECS.len());
    assert_eq!(digests(&resumed), digests(&sim));
    assert_eq!(
        delta_files(&resumed),
        delta_files(&sim),
        "post-failover delta files must be byte-identical to SimRunner's"
    );
    let reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    assert!(
        reconnects >= DEVICES.len(),
        "every participant must re-target the promoted standby \
         ({reconnects})"
    );

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
    let _ = std::fs::remove_dir_all(&dir_ship);
}

/// The eviction/re-join race: a participant that never heartbeats and
/// sits on every upload for longer than the eviction deadline is always
/// swept mid-upload — it must come back through the reconnect handshake,
/// re-send the unacked cached upload, and the round must still journal
/// exactly one accept per job (the re-sent upload and the engine's retry
/// collapse, never duplicate).
#[test]
fn evicted_participant_rejoins_and_uploads_land_exactly_once() {
    const SEED: u64 = 127;
    let dir_sim = tmp_dir("evict_truth");
    let dir_tcp = tmp_dir("evict_tcp");
    let sim = sim_round(SEED, &dir_sim);

    // 600 ms eviction deadline vs a 1500 ms stall before every upload
    // send: the sweeper always wins while the upload is unacked in hand
    let st = state_cfg(SEED, FaultPlan::default(), 600, 1);
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let addr = server.addr.to_string();
    let fleet: Vec<_> = DEVICES
        .iter()
        .map(|d| {
            let stalling = *d == "jetson-nano";
            let opts = ParticipantOpts {
                addr: addr.clone(),
                device: d.to_string(),
                seed: SEED,
                backoff_ms: 5,
                max_reconnects: 500,
                once: false,
                // the stalling participant heartbeats far too slowly to
                // survive the sweep; the others use the welcome's cadence
                heartbeat_ms: if stalling { 60_000 } else { 0 },
                faults: if stalling {
                    FaultPlan::parse("stall=jetson-nano:1500", SEED).unwrap()
                } else {
                    FaultPlan::default()
                },
            };
            std::thread::spawn(move || {
                participate(&opts, |welcome, _| {
                    Ok(Box::new(SimRunner::new(welcome.seed)?)
                        as Box<dyn JobRunner>)
                })
            })
        })
        .collect();
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        max_attempts: 8,
        backoff_ms: 10,
        ..RoundConfig::default()
    };
    let round =
        run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    // make sure the evicted participant is attached (not mid-rejoin)
    // before the shutdown broadcast, so it hears the goodbye
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();
    server.shutdown();
    let stats = join_fleet(fleet);

    assert_eq!(round.summary.accepted, SPECS.len());
    assert_eq!(digests(&round), digests(&sim));
    assert_eq!(
        delta_files(&round),
        delta_files(&sim),
        "delta files must be byte-identical through eviction churn"
    );
    // the stalling participant must actually have been swept mid-upload
    // and forced back through the reconnect handshake
    let nano_at = DEVICES.iter().position(|d| *d == "jetson-nano").unwrap();
    assert!(
        stats[nano_at].reconnects >= 1,
        "eviction must force at least one rejoin"
    );

    // exactly-once: one journaled accept per (task, strategy), no matter
    // how many times the cached upload was re-sent across rejoins
    let text = std::fs::read_to_string(dir_tcp.join(JOURNAL_FILE)).unwrap();
    let mut per_job: BTreeMap<(String, String), usize> = BTreeMap::new();
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if j.get("kind").and_then(Json::as_str) != Some("accept") {
            continue;
        }
        let rep = j.get("report").expect("accept entry carries its report");
        let key = (
            rep.get("task").and_then(Json::as_str).unwrap().to_string(),
            rep.get("strategy").and_then(Json::as_str).unwrap().to_string(),
        );
        *per_job.entry(key).or_insert(0) += 1;
    }
    assert_eq!(per_job.len(), SPECS.len(), "every job journaled an accept");
    for (key, n) in &per_job {
        assert_eq!(*n, 1, "job {key:?} must journal exactly one accept");
    }

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}
