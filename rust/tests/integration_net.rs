//! Loopback integration for the networked fleet transport: a real TCP
//! coordinator ([`FleetServer`] + [`NetRunner`]) driving real
//! [`participate`] threads. The contract under test is bit-identity: a
//! round run over the wire must produce exactly the delta files, digests,
//! and journal a plain in-process [`SimRunner`] round produces — through
//! participant disconnects, coordinator kills, and corrupted uploads.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use taskedge::coordinator::fleet::{Job, JobStatus};
use taskedge::coordinator::rounds::JOURNAL_FILE;
use taskedge::coordinator::{
    run_round, FaultPlan, JobRunner, RoundConfig, RoundReport, SimRunner,
    TrainConfig,
};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::edge::DeviceProfile;
use taskedge::net::{
    participate, FleetServer, NetConfig, NetRunner, NetState, ParticipantOpts,
    ParticipantStats,
};
use taskedge::util::json::Json;

const DEVICES: [&str; 3] =
    ["jetson-orin-nano", "jetson-nano", "phone-flagship"];

/// One job per PEFT family — all admit on the device pool above.
const SPECS: [(&str, &str); 4] = [
    ("pets", "taskedge:k=2"),
    ("dtd", "lora"),
    ("eurosat", "vpt"),
    ("svhn", "adapter"),
];

fn jobs(seed: u64) -> Vec<Job> {
    SPECS
        .iter()
        .map(|(task, strategy)| Job {
            task: task_by_name(task).unwrap().clone(),
            strategy: taskedge::peft::Strategy::parse(strategy).unwrap(),
            train_cfg: TrainConfig { seed, ..Default::default() },
            n_train: 8,
            n_eval: 4,
        })
        .collect()
}

fn devs() -> Vec<&'static DeviceProfile> {
    DEVICES.iter().map(|n| profile_by_name(n).unwrap()).collect()
}

fn tmp_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("taskedge_net_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn digests(r: &RoundReport) -> BTreeMap<(String, String), String> {
    r.reports
        .iter()
        .filter_map(|r| {
            r.delta_digest
                .clone()
                .map(|d| ((r.task.clone(), r.strategy.clone()), d))
        })
        .collect()
}

/// Drained delta file bytes per (task, strategy).
fn delta_files(r: &RoundReport) -> BTreeMap<(String, String), Vec<u8>> {
    r.reports
        .iter()
        .filter_map(|rep| {
            rep.delta_path.as_ref().map(|p| {
                (
                    (rep.task.clone(), rep.strategy.clone()),
                    std::fs::read(p).unwrap(),
                )
            })
        })
        .collect()
}

fn state(seed: u64, faults: FaultPlan) -> Arc<NetState> {
    NetState::new(NetConfig {
        config_name: "sim".to_string(),
        seed,
        heartbeat_timeout_ms: 2_000,
        faults,
        backbone: None,
    })
}

/// One [`participate`] thread per device; `once: false` so participants
/// survive coordinator kills via their reconnect loop.
fn spawn_fleet(
    addr: &str,
    seed: u64,
    fault_specs: &[(&str, &str)],
) -> Vec<std::thread::JoinHandle<anyhow::Result<ParticipantStats>>> {
    DEVICES
        .iter()
        .map(|d| {
            let spec = fault_specs
                .iter()
                .find(|(dev, _)| dev == d)
                .map(|(_, s)| s.to_string());
            let opts = ParticipantOpts {
                addr: addr.to_string(),
                device: d.to_string(),
                seed,
                backoff_ms: 5,
                max_reconnects: 500,
                once: false,
                heartbeat_ms: 0,
                faults: match spec {
                    Some(s) => FaultPlan::parse(&s, seed).unwrap(),
                    None => FaultPlan::default(),
                },
            };
            std::thread::spawn(move || {
                participate(&opts, |welcome, _| {
                    Ok(Box::new(SimRunner::new(welcome.seed)?)
                        as Box<dyn JobRunner>)
                })
            })
        })
        .collect()
}

fn join_fleet(
    handles: Vec<std::thread::JoinHandle<anyhow::Result<ParticipantStats>>>,
) -> Vec<ParticipantStats> {
    handles
        .into_iter()
        .map(|h| h.join().expect("participant thread panicked").unwrap())
        .collect()
}

/// In-process ground truth: the same jobs on a plain [`SimRunner`].
fn sim_round(seed: u64, dir: &Path) -> RoundReport {
    let runner = SimRunner::new(seed).unwrap();
    let cfg = RoundConfig {
        seed,
        delta_dir: Some(dir.to_path_buf()),
        ..RoundConfig::default()
    };
    run_round(runner.manifest(), &devs(), &jobs(seed), &runner, &cfg).unwrap()
}

/// A TCP coordinator + 3 participants — one of them disconnecting the
/// moment Train starts and rejoining — must complete the round with delta
/// files and digests byte-identical to the in-process SimRunner round.
#[test]
fn tcp_round_is_bit_identical_to_sim_runner() {
    const SEED: u64 = 71;
    let dir_sim = tmp_dir("sim_truth");
    let dir_tcp = tmp_dir("tcp_round");
    let sim = sim_round(SEED, &dir_sim);
    assert_eq!(sim.summary.accepted, SPECS.len());

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let fleet = spawn_fleet(
        &server.addr.to_string(),
        SEED,
        &[("jetson-nano", "disconnect=jetson-nano@train")],
    );
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        ..RoundConfig::default()
    };
    let round = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    server.shutdown();
    let stats = join_fleet(fleet);

    assert_eq!(round.summary.accepted, SPECS.len());
    for r in &round.reports {
        assert_eq!(r.status, JobStatus::Accepted);
    }
    assert_eq!(digests(&round), digests(&sim), "digest maps must match");
    assert_eq!(
        delta_files(&round),
        delta_files(&sim),
        "drained delta files must be byte-identical over the wire"
    );
    let reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    assert!(
        reconnects >= 1,
        "the injected mid-Train disconnect must force at least one rejoin"
    );

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// Kill the coordinator (no shutdown frame), truncate the journal after
/// the first accept, restart on the SAME port with `resume: true`: the
/// surviving accepts replay bit-identically, the participants re-attach
/// through their reconnect loops, and the final state matches SimRunner.
#[test]
fn coordinator_kill_and_resume_replays_bit_identically() {
    const SEED: u64 = 83;
    let dir_sim = tmp_dir("resume_truth");
    let dir_tcp = tmp_dir("resume_tcp");
    let sim = sim_round(SEED, &dir_sim);

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let addr = server.addr.to_string();
    let fleet = spawn_fleet(&addr, SEED, &[]);
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        ..RoundConfig::default()
    };
    let first = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    assert_eq!(first.summary.accepted, SPECS.len());
    server.kill(); // crash: participants reconnect instead of exiting
    drop(server);
    drop(net);

    // the mid-round power cut: keep the journal only up to the first accept
    let journal = dir_tcp.join(JOURNAL_FILE);
    let text = std::fs::read_to_string(&journal).unwrap();
    let mut kept = Vec::new();
    let mut accepts = 0;
    for line in text.lines() {
        kept.push(line);
        if line.contains("\"kind\":\"accept\"") {
            accepts += 1;
            if accepts == 1 {
                break;
            }
        }
    }
    assert_eq!(accepts, 1, "round must have journaled accepts to truncate");
    std::fs::write(&journal, format!("{}\n", kept.join("\n"))).unwrap();

    let st2 = state(SEED, FaultPlan::default());
    let mut server2 = FleetServer::start(&addr, st2.clone())
        .expect("restarted coordinator must reclaim its port");
    server2
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();
    let net2 = NetRunner::new(st2, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let resume_cfg = RoundConfig { resume: true, ..cfg };
    let resumed =
        run_round(&manifest, &devs(), &jobs(SEED), &net2, &resume_cfg).unwrap();
    server2.shutdown();
    let stats = join_fleet(fleet);

    assert_eq!(resumed.summary.replayed, 1, "the surviving accept replays");
    assert_eq!(resumed.summary.accepted, SPECS.len());
    assert_eq!(digests(&resumed), digests(&sim));
    assert_eq!(
        delta_files(&resumed),
        delta_files(&sim),
        "post-resume delta files must be byte-identical to SimRunner's"
    );
    let reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    assert!(
        reconnects >= DEVICES.len(),
        "every participant must reconnect across the kill ({reconnects})"
    );

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// A corrupted upload must be rejected by admission analysis, retried,
/// and must never reach the journal: every journaled accept digest is one
/// the in-process ground-truth round also produced.
#[test]
fn corrupted_upload_is_rejected_and_never_journaled() {
    const SEED: u64 = 97;
    let dir_sim = tmp_dir("corrupt_truth");
    let dir_tcp = tmp_dir("corrupt_tcp");
    let sim = sim_round(SEED, &dir_sim);
    let sim_digests: BTreeSet<String> =
        digests(&sim).into_values().collect();

    let st = state(SEED, FaultPlan::default());
    let mut server = FleetServer::start("127.0.0.1:0", st.clone()).unwrap();
    let fleet = spawn_fleet(&server.addr.to_string(), SEED, &[]);
    server
        .await_participants(DEVICES.len(), Duration::from_secs(20))
        .unwrap();

    let manifest = SimRunner::new(SEED).unwrap().manifest().clone();
    let net = NetRunner::new(st, manifest.clone())
        .with_timeouts(10_000, 20_000, 20_000);
    let cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_tcp.clone()),
        backoff_ms: 1,
        // job 0's first upload is corrupted after transport — admission
        // analysis must bounce it and the engine must retry clean
        faults: FaultPlan::parse("corrupt@0", SEED).unwrap(),
        ..RoundConfig::default()
    };
    let round = run_round(&manifest, &devs(), &jobs(SEED), &net, &cfg).unwrap();
    server.shutdown();
    join_fleet(fleet);

    assert_eq!(round.summary.accepted, SPECS.len());
    assert!(round.summary.rejected_uploads >= 1, "the corrupt upload bounces");
    assert!(round.summary.retries >= 1, "the bounced job retries");
    assert_eq!(digests(&round), digests(&sim), "final digests stay identical");

    // scan the journal: every accepted digest must be a ground-truth one
    let text =
        std::fs::read_to_string(dir_tcp.join(JOURNAL_FILE)).unwrap();
    let mut journaled = 0;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if j.get("kind").and_then(Json::as_str) != Some("accept") {
            continue;
        }
        let digest = j
            .get("report")
            .and_then(|r| r.get("delta_digest"))
            .and_then(Json::as_str)
            .expect("journaled accept must carry a digest")
            .to_string();
        assert!(
            sim_digests.contains(&digest),
            "corrupted bytes reached the journal: {digest}"
        );
        journaled += 1;
    }
    assert_eq!(journaled, SPECS.len(), "one journaled accept per job");

    let _ = std::fs::remove_dir_all(&dir_sim);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}
