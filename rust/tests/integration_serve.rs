//! Serving-path integration: dynamic batching, padding correctness,
//! multi-task routing.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use taskedge::serve::{Router, Server, ServerConfig};
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn make_server(workers: usize, linger_ms: u64) -> Arc<Server> {
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let params = Arc::new(ParamStore::init(&cfg, &mut Rng::new(4)));
    Arc::new(
        Server::new(
            rt,
            "micro",
            params,
            ServerConfig {
                linger: std::time::Duration::from_millis(linger_ms),
                workers,
            },
        )
        .unwrap(),
    )
}

fn random_image(seed: u64) -> Vec<f32> {
    Rng::new(seed).normal_vec(16 * 16 * 3, 1.0)
}

#[test]
fn serves_full_and_partial_batches() {
    let server = make_server(1, 2);
    let shutdown = Arc::new(AtomicBool::new(false));
    let n = 37; // 2 full batches of 16 + partial 5

    std::thread::scope(|scope| {
        let srv = server.clone();
        let sd = shutdown.clone();
        let handle = scope.spawn(move || srv.run(sd).unwrap());

        let receivers: Vec<_> = (0..n)
            .map(|i| server.submit(random_image(i as u64)).unwrap())
            .collect();
        let mut latencies = Vec::new();
        for rx in receivers {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.logits.len(), 32);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            assert!(resp.argmax < 32);
            latencies.push(resp.latency);
        }
        shutdown.store(true, Ordering::Relaxed);
        handle.join().unwrap();
        assert_eq!(latencies.len(), n);
    });

    let stats = server.stats();
    assert_eq!(stats.requests, n);
    assert!(stats.batches >= 3, "expected >= 3 batches, got {}", stats.batches);
    assert!(stats.padded_rows > 0, "tail batch must have been padded");
}

#[test]
fn padding_does_not_corrupt_results() {
    // the same image must get the same logits whether served in a full
    // batch or as a lone padded request
    let server = make_server(1, 1);
    let shutdown = Arc::new(AtomicBool::new(false));
    let img = random_image(99);

    let (lone, batched) = std::thread::scope(|scope| {
        let srv = server.clone();
        let sd = shutdown.clone();
        let handle = scope.spawn(move || srv.run(sd).unwrap());

        // lone request -> padded batch
        let rx = server.submit(img.clone()).unwrap();
        let lone = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();

        // full batch containing the same image first
        let mut rxs = vec![server.submit(img.clone()).unwrap()];
        for i in 0..15 {
            rxs.push(server.submit(random_image(i)).unwrap());
        }
        let batched = rxs
            .remove(0)
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        for rx in rxs {
            rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        }
        shutdown.store(true, Ordering::Relaxed);
        handle.join().unwrap();
        (lone, batched)
    });

    for (a, b) in lone.logits.iter().zip(&batched.logits) {
        assert!((a - b).abs() < 1e-4, "padded vs batched logits differ: {a} {b}");
    }
    assert_eq!(lone.argmax, batched.argmax);
}

#[test]
fn router_dispatches_by_task() {
    let mut router = Router::new();
    router.register("pets", make_server(1, 1));
    router.register("dtd", make_server(1, 1));
    assert_eq!(router.tasks(), vec!["dtd", "pets"]);
    assert!(router.submit("nope", random_image(0)).is_err());
    // (serving threads not started: submit only enqueues)
    assert!(router.submit("pets", random_image(0)).is_ok());
}

#[test]
fn rejects_malformed_images() {
    let server = make_server(1, 1);
    assert!(server.submit(vec![0.0; 7]).is_err());
}
