//! Serving-path integration: event-driven dynamic batching, padding
//! correctness, backpressure, drain-on-shutdown, linger flushes, adapter
//! hot-swap under load, multi-task routing over the shared DeviceExecutor
//! (fair-queueing starvation guard), and the parameter-literal cache
//! (conversions at start/swap only, never per batch).

mod common;

use std::sync::Arc;
use std::time::Duration;

use taskedge::runtime::Runtime;
use taskedge::serve::{
    DeviceBuilder, DeviceConfig, Response, Server, ServerConfig, TaskConfig,
};
use taskedge::util::rng::Rng;
use taskedge::vit::{ParamStore, TaskDelta};

fn make_server(workers: usize, linger_ms: u64, max_queue: usize) -> Arc<Server> {
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let params = Arc::new(ParamStore::init(&cfg, &mut Rng::new(4)));
    Arc::new(
        Server::new(
            rt,
            "micro",
            params,
            ServerConfig {
                linger: Duration::from_millis(linger_ms),
                workers,
                max_queue,
            },
        )
        .unwrap(),
    )
}

fn random_image(seed: u64) -> Vec<f32> {
    Rng::new(seed).normal_vec(16 * 16 * 3, 1.0)
}

const RECV_TIMEOUT: Duration = Duration::from_secs(60);

#[test]
fn serves_full_and_partial_batches() {
    if common::skip_without_artifacts() {
        return;
    }
    let server = make_server(1, 2, 1024);
    let n = 37; // 2 full batches of 16 + partial 5

    std::thread::scope(|scope| {
        let srv = server.clone();
        let handle = scope.spawn(move || srv.run().unwrap());

        let receivers: Vec<_> = (0..n)
            .map(|i| server.submit(random_image(i as u64)).unwrap())
            .collect();
        let mut latencies = Vec::new();
        for rx in receivers {
            let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
            assert_eq!(resp.logits.len(), 32);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            assert!(resp.argmax < 32);
            latencies.push(resp.latency);
        }
        server.shutdown();
        handle.join().unwrap();
        assert_eq!(latencies.len(), n);
    });

    let stats = server.stats();
    assert_eq!(stats.requests, n);
    assert!(stats.batches >= 3, "expected >= 3 batches, got {}", stats.batches);
    assert!(stats.padded_rows > 0, "tail batch must have been padded");
    assert_eq!(stats.rejected, 0);
    // histograms observed every request / batch
    assert_eq!(stats.queue.count(), n as u64);
    assert_eq!(stats.execute.count(), stats.batches as u64);
    assert!(stats.queue.quantile(0.99) >= stats.queue.quantile(0.5));
}

#[test]
fn padding_does_not_corrupt_results() {
    if common::skip_without_artifacts() {
        return;
    }
    // the same image must get the same logits whether served in a full
    // batch or as a lone padded request
    let server = make_server(1, 1, 1024);
    let img = random_image(99);

    let (lone, batched) = std::thread::scope(|scope| {
        let srv = server.clone();
        let handle = scope.spawn(move || srv.run().unwrap());

        // lone request -> padded batch
        let rx = server.submit(img.clone()).unwrap();
        let lone = rx.recv_timeout(RECV_TIMEOUT).unwrap();

        // full batch containing the same image first
        let mut rxs = vec![server.submit(img.clone()).unwrap()];
        for i in 0..15 {
            rxs.push(server.submit(random_image(i)).unwrap());
        }
        let batched = rxs.remove(0).recv_timeout(RECV_TIMEOUT).unwrap();
        for rx in rxs {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        server.shutdown();
        handle.join().unwrap();
        (lone, batched)
    });

    for (a, b) in lone.logits.iter().zip(&batched.logits) {
        assert!((a - b).abs() < 1e-4, "padded vs batched logits differ: {a} {b}");
    }
    assert_eq!(lone.argmax, batched.argmax);
}

#[test]
fn backpressure_rejects_when_queue_full() {
    if common::skip_without_artifacts() {
        return;
    }
    // no workers running: submissions accumulate until max_queue
    let server = make_server(1, 1, 4);
    let mut rxs = Vec::new();
    for i in 0..4 {
        rxs.push(server.submit(random_image(i)).unwrap());
    }
    let err = server.submit(random_image(9)).unwrap_err();
    assert!(
        err.to_string().contains("backpressure"),
        "unexpected rejection message: {err}"
    );
    assert_eq!(server.stats().rejected, 1);

    // draining the queue restores capacity
    std::thread::scope(|scope| {
        let srv = server.clone();
        let handle = scope.spawn(move || srv.run().unwrap());
        for rx in rxs.drain(..) {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        let rx = server.submit(random_image(10)).unwrap();
        rx.recv_timeout(RECV_TIMEOUT).unwrap();
        server.shutdown();
        handle.join().unwrap();
    });
    let stats = server.stats();
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.rejected, 1);
}

#[test]
fn shutdown_drains_pending_requests() {
    if common::skip_without_artifacts() {
        return;
    }
    // linger far above the test budget: only the drain path can flush
    let server = make_server(2, 60_000, 1024);
    let rxs: Vec<_> = (0..5)
        .map(|i| server.submit(random_image(i)).unwrap())
        .collect();
    // close *before* the workers start: the backlog must still be answered
    server.shutdown();
    server.run().unwrap();
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(1))
            .expect("shutdown dropped a pending responder");
        assert_eq!(resp.logits.len(), 32);
    }
    assert!(server.submit(random_image(7)).is_err(), "post-shutdown submit");
    let stats = server.stats();
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.padded_rows, 16 - 5);
}

#[test]
fn linger_flushes_partial_batch_within_deadline() {
    if common::skip_without_artifacts() {
        return;
    }
    let linger_ms = 100;
    let server = make_server(1, linger_ms, 1024);
    std::thread::scope(|scope| {
        let srv = server.clone();
        let handle = scope.spawn(move || srv.run().unwrap());
        let rx = server.submit(random_image(1)).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        // a lone request waits out the full linger window before flushing
        assert!(
            resp.latency >= Duration::from_millis(linger_ms - 20),
            "flushed before the linger deadline: {:?}",
            resp.latency
        );
        server.shutdown();
        handle.join().unwrap();
    });
    let stats = server.stats();
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.padded_rows, 15);
}

#[test]
fn router_dispatches_by_task_and_aggregates_stats() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(4)));
    let mut builder = DeviceBuilder::new(
        rt,
        "micro",
        DeviceConfig {
            linger: Duration::from_millis(1),
            workers: 2,
            max_queue: 1024,
        },
    );
    builder.add_task("pets", backbone.clone(), TaskConfig::default()).unwrap();
    builder.add_task("dtd", backbone.clone(), TaskConfig::default()).unwrap();
    assert!(
        builder.add_task("pets", backbone, TaskConfig::default()).is_err(),
        "duplicate task registration must fail"
    );
    let router = builder.build().unwrap();
    assert_eq!(router.tasks(), vec!["dtd", "pets"]);
    assert!(router.submit("nope", random_image(0)).is_err());

    std::thread::scope(|scope| {
        let h = scope.spawn(|| router.run().unwrap());
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(router.submit("pets", random_image(i)).unwrap());
        }
        for i in 0..4 {
            rxs.push(router.submit("dtd", random_image(100 + i)).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        router.shutdown();
        h.join().unwrap();
    });

    let stats = router.stats();
    assert_eq!(stats.per_task["pets"].requests, 8);
    assert_eq!(stats.per_task["dtd"].requests, 4);
    assert_eq!(stats.total.requests, 12);
    assert_eq!(
        stats.total.queue.count(),
        stats.per_task["pets"].queue.count() + stats.per_task["dtd"].queue.count()
    );
    assert!(stats.total.execute.count() >= 2, "one batch per task minimum");
    assert_eq!(stats.device.workers, 2);
    assert_eq!(
        stats.device.dispatches,
        stats.total.batches,
        "every sub-batch is one device dispatch"
    );
}

#[test]
fn fair_queueing_bounds_trickle_latency_under_flood() {
    if common::skip_without_artifacts() {
        return;
    }
    // One shared executor, two tasks of equal weight. The flood task
    // preloads a deep backlog; once the pool is running, a closed-loop
    // trickle's requests must flush within a couple of sub-batches (DRR
    // alternates the two tasks), not behind the whole flood backlog.
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(4)));
    let n_flood = 64 * batch;
    let mut builder = DeviceBuilder::new(
        rt,
        "micro",
        DeviceConfig {
            linger: Duration::from_millis(1),
            workers: 2,
            max_queue: n_flood + 1,
        },
    );
    builder.add_task("flood", backbone.clone(), TaskConfig::default()).unwrap();
    builder.add_task("trickle", backbone, TaskConfig::default()).unwrap();
    let router = builder.build().unwrap();

    // flood lands before the workers start: a worst-case standing backlog
    let flood_rxs: Vec<_> = (0..n_flood)
        .map(|i| router.submit("flood", random_image(i as u64)).unwrap())
        .collect();

    std::thread::scope(|scope| {
        let h = scope.spawn(|| router.run().unwrap());
        // closed-loop trickle while the flood drains
        for i in 0..12 {
            let rx = router.submit("trickle", random_image(1000 + i)).unwrap();
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        for rx in flood_rxs {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        router.shutdown();
        h.join().unwrap();
    });

    let stats = router.stats();
    assert_eq!(stats.per_task["trickle"].requests, 12);
    assert_eq!(stats.per_task["flood"].requests, n_flood);
    let trickle_p99 = stats.per_task["trickle"].queue.quantile(0.99);
    let flood_p50 = stats.per_task["flood"].queue.quantile(0.50);
    // the flood's median request waited behind half its backlog; the
    // trickle must never be queued behind that backlog at all
    assert!(
        trickle_p99 < flood_p50,
        "starved trickle task: p99 {trickle_p99:?} >= flood p50 {flood_p50:?}"
    );
    // and the flood still progressed at full batches (work conservation)
    assert!(
        stats.per_task["flood"].padded_rows <= batch,
        "flood should dispatch full sub-batches while backlogged"
    );
}

#[test]
fn swap_repopulates_param_literal_cache_exactly_once() {
    if common::skip_without_artifacts() {
        return;
    }
    // Dedicated runtime: RuntimeStats must not be polluted by tests
    // running concurrently against the shared runtime.
    let rt = Arc::new(Runtime::load(&common::artifacts_dir()).unwrap());
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(11)));
    let server = Arc::new(
        Server::new(
            rt.clone(),
            "micro",
            backbone.clone(),
            ServerConfig {
                linger: Duration::from_millis(1),
                workers: 2,
                max_queue: 1024,
            },
        )
        .unwrap(),
    );
    // parameters were converted exactly once, at server build
    assert_eq!(rt.stats().param_prepares, 1);

    // the swapped-in task: a head-bias shift, extracted as a sparse delta
    let delta = {
        let mut tuned = (*backbone).clone();
        let mut hb = tuned.get("head.b").unwrap().clone();
        for (j, v) in hb.f32s_mut().unwrap().iter_mut().enumerate() {
            *v += 1.0 + j as f32;
        }
        tuned.set("head.b", hb).unwrap();
        TaskDelta::diff(&backbone, &tuned).unwrap()
    };

    let probe = random_image(5);
    let (post_swap, stats_mid, stats_post) = std::thread::scope(|scope| {
        let srv = server.clone();
        let h = scope.spawn(move || srv.run().unwrap());

        // many batches against the same parameter set: the cache must
        // serve every one of them without reconverting
        let rxs: Vec<_> = (0..64)
            .map(|i| server.submit(random_image(i)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        let stats_mid = rt.stats();

        // swap: the very next batch must already run the new parameters,
        // and the literal set must repopulate exactly once
        server.swap_delta(&delta).unwrap();
        let post_swap = server
            .submit(probe.clone())
            .unwrap()
            .recv_timeout(RECV_TIMEOUT)
            .unwrap();
        // more batches after the swap: still no reconversion
        let rxs: Vec<_> = (0..32)
            .map(|i| server.submit(random_image(500 + i)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(RECV_TIMEOUT).unwrap();
        }
        let stats_post = rt.stats();
        server.shutdown();
        h.join().unwrap();
        (post_swap, stats_mid, stats_post)
    });

    assert_eq!(
        stats_mid.param_prepares, 1,
        "pre-swap batches must not reconvert parameter literals"
    );
    assert!(stats_mid.executions >= 4, "load must have executed batches");
    assert!(
        stats_mid.param_reuse_bytes >= stats_mid.param_prepare_bytes,
        "cached literals must be bound across batches"
    );
    assert_eq!(
        stats_post.param_prepares, 2,
        "swap must repopulate the literal cache exactly once"
    );

    // no stale literals: the post-swap output matches a server built
    // directly from backbone + delta
    let reference = Arc::new(
        Server::from_delta(
            rt.clone(),
            "micro",
            backbone,
            &delta,
            ServerConfig {
                linger: Duration::from_millis(1),
                workers: 1,
                max_queue: 64,
            },
        )
        .unwrap(),
    );
    let want = std::thread::scope(|scope| {
        let refsrv = reference.clone();
        let h = scope.spawn(move || refsrv.run().unwrap());
        let want = reference
            .submit(probe)
            .unwrap()
            .recv_timeout(RECV_TIMEOUT)
            .unwrap();
        reference.shutdown();
        h.join().unwrap();
        want
    });
    for (a, b) in post_swap.logits.iter().zip(&want.logits) {
        assert!(
            (a - b).abs() < 1e-4,
            "stale literals after swap: {a} vs {b}"
        );
    }
    assert_eq!(post_swap.argmax, want.argmax);
}

#[test]
fn hot_swap_under_load_drops_nothing_and_updates_outputs() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(4)));
    let scfg = ServerConfig {
        linger: Duration::from_millis(1),
        workers: 2,
        max_queue: 4096,
    };
    let server = Arc::new(
        Server::new(rt.clone(), "micro", backbone.clone(), scfg.clone())
            .unwrap(),
    );

    // the swapped-in task: a head-bias shift, extracted as a sparse delta
    let delta = {
        let mut tuned = (*backbone).clone();
        let mut hb = tuned.get("head.b").unwrap().clone();
        for (j, v) in hb.f32s_mut().unwrap().iter_mut().enumerate() {
            *v += 1.0 + j as f32;
        }
        tuned.set("head.b", hb).unwrap();
        let mut d = TaskDelta::diff(&backbone, &tuned).unwrap();
        d.strategy = "swap-test".into();
        d
    };

    // ground truth for post-swap outputs: a server built directly from
    // backbone + delta
    let reference = Arc::new(
        Server::from_delta(
            rt.clone(),
            "micro",
            backbone.clone(),
            &delta,
            ServerConfig {
                linger: Duration::from_millis(1),
                workers: 1,
                max_queue: 64,
            },
        )
        .unwrap(),
    );

    let n = 96usize;
    let probe = random_image(5);
    let (responses, post_swap, want) = std::thread::scope(|scope| {
        let srv = server.clone();
        let h1 = scope.spawn(move || srv.run().unwrap());
        let refsrv = reference.clone();
        let h2 = scope.spawn(move || refsrv.run().unwrap());

        // concurrent load from 4 submitters while the swap lands mid-stream
        let mut subs = Vec::new();
        for s in 0..4usize {
            let server = server.clone();
            subs.push(scope.spawn(move || -> Vec<Response> {
                let rxs: Vec<_> = (0..n / 4)
                    .map(|i| {
                        server.submit(random_image((s * 100 + i) as u64)).unwrap()
                    })
                    .collect();
                rxs.into_iter()
                    .map(|rx| rx.recv_timeout(RECV_TIMEOUT).unwrap())
                    .collect()
            }));
        }
        std::thread::sleep(Duration::from_millis(2));
        server.swap_delta(&delta).unwrap();
        let mut responses = Vec::new();
        for h in subs {
            responses.extend(h.join().unwrap());
        }

        // a fresh request after the swap must match the reference server
        let post_swap = server
            .submit(probe.clone())
            .unwrap()
            .recv_timeout(RECV_TIMEOUT)
            .unwrap();
        let want = reference
            .submit(probe.clone())
            .unwrap()
            .recv_timeout(RECV_TIMEOUT)
            .unwrap();
        server.shutdown();
        reference.shutdown();
        h1.join().unwrap();
        h2.join().unwrap();
        (responses, post_swap, want)
    });

    // zero failed or dropped requests across the live swap
    assert_eq!(responses.len(), n);
    for r in &responses {
        assert_eq!(r.logits.len(), 32);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    assert_eq!(server.stats().swaps, 1);
    assert_eq!(server.stats().requests, n + 1);

    // post-swap outputs are the swapped parameter set's outputs
    for (a, b) in post_swap.logits.iter().zip(&want.logits) {
        assert!((a - b).abs() < 1e-4, "post-swap logits diverge: {a} vs {b}");
    }
    assert_eq!(post_swap.argmax, want.argmax);
}

#[test]
fn rejects_malformed_images() {
    if common::skip_without_artifacts() {
        return;
    }
    let server = make_server(1, 1, 1024);
    assert!(server.submit(vec![0.0; 7]).is_err());
}
