//! Runtime integration: manifest-driven artifact loading, execution,
//! shape/dtype validation, determinism, and cross-graph consistency.

mod common;

use taskedge::runtime::{HostTensor, IoBinder};
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn fwd_inputs(
    rt: &taskedge::runtime::Runtime,
    params: &ParamStore,
    seed: u64,
) -> (String, Vec<HostTensor>) {
    let spec = rt.manifest().artifact_for("fwd", "micro").unwrap().clone();
    let binder = IoBinder::new(&spec);
    let mut rng = Rng::new(seed);
    let inputs = binder
        .bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else {
                Ok(HostTensor::from_f32(
                    &io.shape,
                    rng.normal_vec(io.numel(), 1.0),
                )?)
            }
        })
        .unwrap();
    (spec.name, inputs)
}

#[test]
fn manifest_lists_expected_artifacts() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let m = rt.manifest();
    for kind in ["fwd", "eval", "calibrate", "grad_scores", "train_adam",
                 "train_sgd", "lora_train", "lora_eval", "vpt_train",
                 "vpt_eval", "adapter_train", "adapter_eval"] {
        for cfg in ["micro", "tiny"] {
            assert!(
                m.artifact_for(kind, cfg).is_ok(),
                "missing artifact {kind}/{cfg}"
            );
        }
    }
    let micro = m.config("micro").unwrap();
    assert_eq!(
        micro.num_params,
        micro.params.iter().map(|p| p.numel()).sum::<usize>(),
        "manifest num_params inconsistent with param list"
    );
}

#[test]
fn fwd_executes_and_is_deterministic() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let params = ParamStore::init(&cfg, &mut Rng::new(1));
    let (name, inputs) = fwd_inputs(&rt, &params, 2);
    let out1 = rt.execute(&name, &inputs).unwrap();
    let out2 = rt.execute(&name, &inputs).unwrap();
    assert_eq!(out1.len(), 1);
    assert_eq!(out1[0].shape, vec![16, cfg.num_classes]);
    assert_eq!(out1[0], out2[0], "same inputs must give identical logits");
    assert!(out1[0].f32s().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn input_validation_rejects_bad_shapes_and_counts() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let params = ParamStore::init(&cfg, &mut Rng::new(1));
    let (name, mut inputs) = fwd_inputs(&rt, &params, 2);

    // wrong count
    let fewer = &inputs[..inputs.len() - 1];
    assert!(rt.execute(&name, fewer).is_err());

    // wrong shape on the images input
    let last = inputs.len() - 1;
    inputs[last] = HostTensor::zeros(&[1, 2, 3]);
    assert!(rt.execute(&name, &inputs).is_err());
}

#[test]
fn eval_counts_are_bounded_and_consistent_with_fwd() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let params = ParamStore::init(&cfg, &mut Rng::new(5));
    let mut rng = Rng::new(6);
    let images =
        HostTensor::from_f32(&[batch, cfg.image_size, cfg.image_size, 3],
                             rng.normal_vec(batch * cfg.image_size *
                                            cfg.image_size * 3, 1.0))
            .unwrap();
    let labels = HostTensor::from_i32(
        &[batch],
        (0..batch as i32).map(|i| i % cfg.num_classes as i32).collect(),
    )
    .unwrap();

    let spec = rt.manifest().artifact_for("eval", "micro").unwrap().clone();
    let binder = IoBinder::new(&spec);
    let inputs = binder
        .bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else {
                Ok(labels.clone())
            }
        })
        .unwrap();
    let outputs = rt.execute(&spec.name, &inputs).unwrap();
    let loss = binder.output(&outputs, "loss_sum").unwrap().item_f32().unwrap();
    let top1 = binder.output(&outputs, "n_correct").unwrap().item_f32().unwrap();
    let top5 = binder.output(&outputs, "top5_correct").unwrap().item_f32().unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=batch as f32).contains(&top1));
    assert!(top1 <= top5 && top5 <= batch as f32);

    // fwd logits argmax must agree with eval's n_correct
    let fspec = rt.manifest().artifact_for("fwd", "micro").unwrap().clone();
    let fbinder = IoBinder::new(&fspec);
    let finputs = fbinder
        .bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else {
                Ok(images.clone())
            }
        })
        .unwrap();
    let fout = rt.execute(&fspec.name, &finputs).unwrap();
    let logits = fout[0].f32s().unwrap();
    let mut correct = 0;
    for b in 0..batch {
        let row = &logits[b * cfg.num_classes..(b + 1) * cfg.num_classes];
        let argmax = taskedge::serve::argmax(row);
        if argmax as i32 == labels.i32s().unwrap()[b] {
            correct += 1;
        }
    }
    assert_eq!(correct as f32, top1, "fwd argmax disagrees with eval count");
}

#[test]
fn calibrate_stats_are_nonnegative_and_sized() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let params = ParamStore::init(&cfg, &mut Rng::new(7));
    let spec = rt.manifest().artifact_for("calibrate", "micro").unwrap().clone();
    let binder = IoBinder::new(&spec);
    let mut rng = Rng::new(8);
    let inputs = binder
        .bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else {
                Ok(HostTensor::from_f32(&io.shape,
                                        rng.normal_vec(io.numel(), 1.0))?)
            }
        })
        .unwrap();
    let outputs = rt.execute(&spec.name, &inputs).unwrap();
    assert_eq!(outputs.len(), spec.outputs.len());
    let masked: Vec<_> = cfg.masked_params().collect();
    assert_eq!(outputs.len(), masked.len(),
               "one stat per masked tensor expected");
    for (out, os) in outputs.iter().zip(&spec.outputs) {
        assert!(os.name.starts_with("stat:"));
        assert!(out.f32s().unwrap().iter().all(|v| *v >= 0.0 && v.is_finite()),
                "stat {} has negative/NaN entries", os.name);
    }
    // tokens scale: patch_embed stat over batch*n_patches rows of unit
    // normals ~ batch * n_patches per feature (loose sanity bound)
    let expect = (batch * cfg.n_patches()) as f32;
    let pe = outputs[0].f32s().unwrap();
    let mean: f32 = pe.iter().sum::<f32>() / pe.len() as f32;
    assert!((expect * 0.5..expect * 1.5).contains(&mean),
            "patch_embed colnorm_sq mean {mean} far from ~{expect}");
}

trait NPatches {
    fn n_patches(&self) -> usize;
}

impl NPatches for taskedge::runtime::ModelConfig {
    fn n_patches(&self) -> usize {
        (self.image_size / self.patch_size).pow(2)
    }
}
