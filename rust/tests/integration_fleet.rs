//! Fleet scheduler integration: multi-device concurrent jobs sharing one
//! PJRT runtime, admission control, and report consistency.

mod common;

use std::sync::Arc;

use taskedge::coordinator::{Fleet, Job, TrainConfig};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::peft::Strategy;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

#[test]
fn fleet_runs_jobs_across_devices() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(3)));

    let tcfg = TrainConfig {
        epochs: 1,
        lr: 1e-3,
        seed: 3,
        calib_batches: 1,
        ..Default::default()
    };
    let jobs: Vec<Job> = ["pets", "eurosat", "dtd"]
        .iter()
        .map(|t| Job {
            task: task_by_name(t).unwrap().clone(),
            strategy: Strategy::TaskEdge { k: 2 },
            train_cfg: tcfg.clone(),
            n_train: 48,
            n_eval: batch * 2,
        })
        .collect();

    let fleet = Fleet::new(vec![
        profile_by_name("jetson-orin-nano").unwrap(),
        profile_by_name("phone-flagship").unwrap(),
    ]);
    let reports = fleet.run(rt.clone(), "micro", backbone, jobs, 3).unwrap();

    assert_eq!(reports.len(), 3, "all jobs must produce reports");
    for r in &reports {
        assert!(r.admitted, "micro jobs must fit every profile");
        assert!(r.top1.is_finite() && (0.0..=1.0).contains(&r.top1));
        assert!(r.wall_ms > 0.0);
        assert!(r.sim_energy_j > 0.0);
        assert!(r.required_mb > 0.0);
    }
    // both devices should have participated OR at least all tasks covered
    let tasks: std::collections::HashSet<_> =
        reports.iter().map(|r| r.task.clone()).collect();
    assert_eq!(tasks.len(), 3);
}

#[test]
fn fleet_rejects_oversized_jobs() {
    if common::skip_without_artifacts() {
        return;
    }
    // The raspberry-pi profile cannot fit a job whose footprint we inflate
    // by using the Full strategy on tiny... micro still fits; instead
    // verify admission logic directly through a tiny-memory fake via the
    // cost model (covered in edge unit tests) and here through the rpi +
    // tiny config path if its footprint exceeds: skip if it fits.
    let rt = common::runtime();
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let batch = rt.manifest().batch;
    let fp = taskedge::peft::MemoryFootprint::compute(&cfg, cfg.num_params, batch);
    let rpi = profile_by_name("raspberry-pi-4").unwrap();
    let adm = taskedge::edge::admit(rpi, &fp);
    // tiny is small; the point is the arithmetic is consistent:
    assert_eq!(adm.fits, adm.required_bytes <= adm.available_bytes);
    assert!(adm.headroom > 0.0);
}

#[test]
fn concurrent_sessions_share_compiled_executables() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let before = rt.stats().compiles;
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let backbone = Arc::new(ParamStore::init(&cfg, &mut Rng::new(9)));
    let tcfg = TrainConfig {
        epochs: 1,
        lr: 1e-3,
        seed: 9,
        calib_batches: 1,
        ..Default::default()
    };
    let jobs: Vec<Job> = ["pets", "pets", "pets", "pets"]
        .iter()
        .map(|t| Job {
            task: task_by_name(t).unwrap().clone(),
            strategy: Strategy::Linear,
            train_cfg: tcfg.clone(),
            n_train: 32,
            n_eval: batch,
        })
        .collect();
    let fleet = Fleet::new(vec![
        profile_by_name("jetson-orin-nano").unwrap(),
        profile_by_name("jetson-nano").unwrap(),
        profile_by_name("phone-flagship").unwrap(),
        profile_by_name("rtx4090-edge-server").unwrap(),
    ]);
    let reports = fleet.run(rt.clone(), "micro", backbone, jobs, 9).unwrap();
    assert_eq!(reports.len(), 4);
    let after = rt.stats().compiles;
    // 4 concurrent Linear jobs need at most train_adam + eval compiles
    // (shared cache) — not 4x.
    assert!(
        after - before <= 4,
        "executable cache not shared: {} new compiles",
        after - before
    );
}
