//! Training integration: the defining invariants of sparse fine-tuning
//! (Alg. 1 step 4) exercised THROUGH the AOT graphs, plus full sessions
//! for every strategy family.

mod common;

use std::collections::BTreeMap;

use taskedge::coordinator::{FinetuneSession, TrainConfig};
use taskedge::data::{generate_task, task_by_name};
use taskedge::masking::Mask;
use taskedge::peft::{DeltaSizeReport, Strategy};
use taskedge::runtime::{HostTensor, IoBinder};
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

/// Run one train_adam step with the given masks; return (params', loss).
fn one_step(
    masks: &BTreeMap<String, Mask>,
    seed: u64,
) -> (ParamStore, BTreeMap<String, HostTensor>, f32) {
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let params = ParamStore::init(&cfg, &mut Rng::new(seed));
    let spec = rt.manifest().artifact_for("train_adam", "micro").unwrap().clone();
    let binder = IoBinder::new(&spec);
    let mut rng = Rng::new(seed + 1);
    let images = HostTensor::from_f32(
        &[batch, cfg.image_size, cfg.image_size, 3],
        rng.normal_vec(batch * cfg.image_size * cfg.image_size * 3, 1.0),
    )
    .unwrap();
    let labels = HostTensor::from_i32(
        &[batch],
        (0..batch as i32).map(|i| i % cfg.num_classes as i32).collect(),
    )
    .unwrap();
    let inputs = binder
        .bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if let Some(p) = io.name.strip_prefix("mask:") {
                Ok(masks[p].to_tensor())
            } else if io.name.starts_with("adam_") {
                Ok(HostTensor::zeros(&io.shape))
            } else {
                Ok(match io.name.as_str() {
                    "step" => HostTensor::scalar_f32(1.0),
                    "images" => images.clone(),
                    "labels" => labels.clone(),
                    "lr" => HostTensor::scalar_f32(1e-2),
                    "wd" => HostTensor::scalar_f32(0.0),
                    _ => unreachable!(),
                })
            }
        })
        .unwrap();
    let outputs = rt.execute(&spec.name, &inputs).unwrap();
    let mut new_params = ParamStore::zeros_like(&cfg);
    let mut moments = BTreeMap::new();
    let mut loss = f32::NAN;
    for (out, os) in outputs.iter().zip(&spec.outputs) {
        if let Some(p) = os.name.strip_prefix("param:") {
            new_params.set(p, out.clone()).unwrap();
        } else if os.name.starts_with("adam_") {
            moments.insert(os.name.clone(), out.clone());
        } else if os.name == "loss" {
            loss = out.item_f32().unwrap();
        }
    }
    // callers re-init the original store from the same seed to compare
    (new_params, moments, loss)
}

#[test]
fn masked_step_freezes_unselected_coordinates() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    // mask: only block0.attn.qkv.w trainable (plus nothing else)
    let mut masks: BTreeMap<String, Mask> = cfg
        .params
        .iter()
        .map(|p| (p.name.clone(), Mask::zeros(&p.shape)))
        .collect();
    masks.insert(
        "block0.attn.qkv.w".into(),
        Mask::ones(&cfg.param("block0.attn.qkv.w").unwrap().shape),
    );

    let (new_params, moments, loss) = one_step(&masks, 11);
    let orig = ParamStore::init(&cfg, &mut Rng::new(11));
    assert!(loss.is_finite() && loss > 0.0);

    for p in &cfg.params {
        let before = orig.get(&p.name).unwrap().f32s().unwrap();
        let after = new_params.get(&p.name).unwrap().f32s().unwrap();
        if p.name == "block0.attn.qkv.w" {
            assert!(
                before.iter().zip(after).any(|(a, b)| a != b),
                "trainable tensor did not move"
            );
        } else {
            assert_eq!(before, after, "frozen tensor {} moved", p.name);
        }
        // optimizer state zero off-mask (the paper's memory claim)
        let m = moments[&format!("adam_m:{}", p.name)].f32s().unwrap();
        if p.name != "block0.attn.qkv.w" {
            assert!(m.iter().all(|&v| v == 0.0),
                    "adam state nonzero for frozen {}", p.name);
        }
    }
}

#[test]
fn partial_mask_freezes_exact_coordinates() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let mut masks: BTreeMap<String, Mask> = cfg
        .params
        .iter()
        .map(|p| (p.name.clone(), Mask::zeros(&p.shape)))
        .collect();
    // checkerboard mask on fc1
    let fc1 = cfg.param("block0.mlp.fc1.w").unwrap();
    let mut mask = Mask::zeros(&fc1.shape);
    for i in (0..mask.data.len()).step_by(2) {
        mask.data[i] = 1.0;
    }
    masks.insert(fc1.name.clone(), mask.clone());

    let (new_params, _, _) = one_step(&masks, 13);
    let orig = ParamStore::init(&cfg, &mut Rng::new(13));
    let before = orig.get(&fc1.name).unwrap().f32s().unwrap();
    let after = new_params.get(&fc1.name).unwrap().f32s().unwrap();
    let mut moved = 0;
    for (i, (b, a)) in before.iter().zip(after).enumerate() {
        if mask.data[i] == 0.0 {
            assert_eq!(b, a, "frozen coordinate {i} moved");
        } else if b != a {
            moved += 1;
        }
    }
    assert!(moved > 0, "no selected coordinate moved");
}

fn session_smoke(strategy: Strategy) -> taskedge::coordinator::SessionResult {
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let backbone = ParamStore::init(&cfg, &mut Rng::new(21));
    let task = task_by_name("eurosat").unwrap();
    let (train, eval) =
        generate_task(task, cfg.image_size, 64, batch * 2, 5).unwrap();
    let tcfg = TrainConfig {
        epochs: 2,
        lr: 1e-3,
        seed: 5,
        calib_batches: 2,
        ..Default::default()
    };
    let mut session =
        FinetuneSession::new(&rt, "micro", strategy, tcfg).unwrap();
    session.run(&backbone, &train, &eval, task.name).unwrap()
}

#[test]
fn taskedge_session_end_to_end() {
    if common::skip_without_artifacts() {
        return;
    }
    let res = session_smoke(Strategy::TaskEdge { k: 2 });
    assert_eq!(res.record.curve.len(), 2);
    assert!(res.record.curve.iter().all(|e| e.train_loss.is_finite()));
    assert!(res.trainable_frac < 0.15);
    // per-neuron budget: every non-head backbone 2-D mask has exactly
    // min(2, d_in) ones per output column
    for (name, mask) in &res.masks {
        if name.starts_with("head.") || mask.shape.len() != 2 {
            continue;
        }
        if mask.count_ones() == 0 {
            continue; // non-masked tensors (1-D) stay zero
        }
        let (d_in, d_out) = (mask.shape[0], mask.shape[1]);
        let want = 2.min(d_in);
        for c in 0..d_out {
            let ones: usize = (0..d_in)
                .filter(|r| mask.data[r * d_out + c] == 1.0)
                .count();
            assert_eq!(ones, want, "{name} column {c} budget violated");
        }
    }
}

#[test]
fn session_delta_reconstructs_tuned_model_and_is_small() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let backbone = ParamStore::init(&cfg, &mut Rng::new(21));
    let task = task_by_name("eurosat").unwrap();
    let (train, eval) =
        generate_task(task, cfg.image_size, 64, batch * 2, 5).unwrap();
    let tcfg = TrainConfig {
        epochs: 1,
        lr: 1e-3,
        seed: 5,
        calib_batches: 2,
        ..Default::default()
    };
    let mut session = FinetuneSession::new(
        &rt,
        "micro",
        Strategy::TaskEdge { k: 2 },
        tcfg,
    )
    .unwrap();
    let res = session.run(&backbone, &train, &eval, task.name).unwrap();

    // the delta's metadata identifies the run
    assert_eq!(res.delta.config_name, "micro");
    assert_eq!(res.delta.strategy, "taskedge_k2");
    assert_eq!(res.delta.task, "eurosat");

    // every sparse coordinate lies inside the session's masks (Alg. 1)
    for (name, sd) in &res.delta.sparse {
        let mask = &res.masks[name];
        for &i in &sd.indices {
            assert_eq!(mask.data[i as usize], 1.0, "{name} idx {i} off-mask");
        }
    }
    // the fresh head rides as a dense replacement plane
    assert!(res.delta.dense.contains_key("head.w"));

    // the delta reconstructs a servable model from the frozen backbone
    let adapted = res.delta.apply_to(&backbone).unwrap();
    assert_ne!(
        adapted.get("head.w").unwrap(),
        backbone.get("head.w").unwrap()
    );

    // per-task storage collapses vs a full checkpoint even on the toy
    // `micro` width (dim=64; the <=1% paper-regime bound is pinned at
    // d_in=4096 in tests/prop_delta.rs)
    let report = DeltaSizeReport::new(&res.delta, &cfg);
    assert!(
        report.ratio() < 0.25,
        "delta {} bytes vs full {} bytes ({:.1}%)",
        report.delta_bytes,
        report.full_bytes,
        report.ratio() * 100.0
    );
}

#[test]
fn lora_session_end_to_end() {
    if common::skip_without_artifacts() {
        return;
    }
    let res = session_smoke(Strategy::SparseLora { k: 4 });
    assert!(res.record.curve.iter().all(|e| e.train_loss.is_finite()));
    assert!(res.trainable_params > 0);
    // lora masks only cover lora targets
    let rt = common::runtime();
    let cfg = rt.manifest().config("micro").unwrap();
    assert_eq!(res.masks.len(), cfg.lora_targets.len());
}

#[test]
fn vpt_and_adapter_sessions_run() {
    if common::skip_without_artifacts() {
        return;
    }
    for s in [Strategy::Vpt, Strategy::Adapter] {
        let res = session_smoke(s.clone());
        assert!(
            res.record.curve.iter().all(|e| e.train_loss.is_finite()),
            "{} produced non-finite loss",
            s.name()
        );
    }
}

#[test]
fn full_overfits_small_train_set() {
    if common::skip_without_artifacts() {
        return;
    }
    // 64 examples, Full fine-tuning, 2 epochs: train loss must drop hard.
    let res = session_smoke(Strategy::Full);
    let first = res.record.curve.first().unwrap().train_loss;
    let last = res.record.curve.last().unwrap().train_loss;
    assert!(last < first, "full FT did not reduce train loss ({first} -> {last})");
}

#[test]
fn gps_strategy_uses_grad_scores() {
    if common::skip_without_artifacts() {
        return;
    }
    let res = session_smoke(Strategy::Gps { k: 2 });
    assert!(res.trainable_params > 0);
    assert!(res.record.curve.last().unwrap().train_loss.is_finite());
}
