//! Round-engine integration: fault injection, retry/reassignment, drain
//! mode, and the resume-from-journal bit-identity property. Everything
//! here runs on [`SimRunner`] — no PJRT, no artifacts — so the suite
//! exercises the coordinator itself and runs everywhere.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use taskedge::coordinator::fleet::{Job, JobStatus};
use taskedge::coordinator::rounds::JOURNAL_FILE;
use taskedge::coordinator::{
    run_round, FaultPlan, JobReport, RoundConfig, RoundReport, SimRunner,
    TrainConfig,
};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::edge::DeviceProfile;
use taskedge::util::json::Json;

fn sim_jobs(specs: &[(&str, &str)], seed: u64) -> Vec<Job> {
    specs
        .iter()
        .map(|(task, strategy)| Job {
            task: task_by_name(task).unwrap().clone(),
            strategy: taskedge::peft::Strategy::parse(strategy).unwrap(),
            train_cfg: TrainConfig { seed, ..Default::default() },
            n_train: 8,
            n_eval: 4,
        })
        .collect()
}

fn devs(names: &[&str]) -> Vec<&'static DeviceProfile> {
    names.iter().map(|n| profile_by_name(n).unwrap()).collect()
}

fn tmp_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("taskedge_rounds_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Every report field that must survive a resume bit-identically.
/// `wall_ms` is excluded: replayed jobs restore it from the journal but
/// re-run jobs re-measure it — it is a measurement, not an output.
fn fingerprint(r: &JobReport) -> Vec<String> {
    vec![
        r.task.clone(),
        r.strategy.clone(),
        r.device.clone(),
        r.admitted.to_string(),
        format!("{:016x}", r.required_mb.to_bits()),
        format!("{:016x}", r.top1.to_bits()),
        format!("{:016x}", r.top5.to_bits()),
        format!("{:016x}", r.trainable_frac.to_bits()),
        format!("{:016x}", r.sim_energy_j.to_bits()),
        format!("{:016x}", r.sim_step_ms.to_bits()),
        r.delta_bytes.to_string(),
        r.status.name().to_string(),
        r.attempts.to_string(),
        format!("{:?}", r.error),
        format!(
            "{:?}",
            r.delta_path.as_ref().and_then(|p| p.file_name())
        ),
        format!("{:?}", r.delta_digest),
    ]
}

fn delta_files(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        let name = p.file_name().unwrap().to_string_lossy().to_string();
        if name.ends_with(".tedl") {
            out.insert(name, std::fs::read(&p).unwrap());
        }
    }
    out
}

fn journal_kinds(dir: &Path) -> Vec<String> {
    std::fs::read_to_string(dir.join(JOURNAL_FILE))
        .unwrap()
        .lines()
        .map(|l| {
            Json::parse(l)
                .unwrap()
                .get("kind")
                .and_then(Json::as_str)
                .unwrap()
                .to_string()
        })
        .collect()
}

/// Regression pin: the pre-round-engine `Fleet::run` collected reports
/// behind a shared `Mutex`; a panicking job poisoned it and every job
/// after the panic died with a `PoisonError` instead of a report. The
/// round engine keeps all state in the coordinator loop, so a round where
/// EVERY job panics on its first attempt must still complete with every
/// job retried and accepted.
#[test]
fn panicking_jobs_never_poison_the_round() {
    let runner = SimRunner::new(7).unwrap();
    let jobs = sim_jobs(
        &[("pets", "taskedge:k=2"), ("dtd", "lora"), ("eurosat", "vpt"),
          ("svhn", "adapter")],
        7,
    );
    let devices = devs(&["jetson-orin-nano", "phone-flagship"]);
    let cfg = RoundConfig {
        seed: 7,
        backoff_ms: 1,
        faults: FaultPlan::parse("panic=1.0", 7).unwrap(),
        ..RoundConfig::default()
    };
    let round = run_round(runner.manifest(), &devices, &jobs, &runner, &cfg)
        .expect("a panicking job must degrade the round, not abort it");
    assert_eq!(round.summary.accepted, jobs.len());
    assert_eq!(round.summary.panics, jobs.len() as u64);
    assert_eq!(round.summary.retries, jobs.len() as u64);
    for r in &round.reports {
        assert_eq!(r.status, JobStatus::Accepted);
        assert_eq!(r.attempts, 2, "first attempt panics, second lands");
        assert!(r.delta.is_some());
    }
}

#[test]
fn hard_panic_exhausts_retries_and_drops_terminally() {
    let runner = SimRunner::new(11).unwrap();
    let jobs = sim_jobs(&[("pets", "taskedge:k=2"), ("dtd", "lora")], 11);
    let devices = devs(&["jetson-orin-nano"]);
    let cfg = RoundConfig {
        seed: 11,
        max_attempts: 2,
        backoff_ms: 1,
        quorum: 0.4,
        faults: FaultPlan::parse("panic@0", 11).unwrap(),
        ..RoundConfig::default()
    };
    let round =
        run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();
    let s = &round.summary;
    assert_eq!((s.accepted, s.dropped), (1, 1));
    assert_eq!(s.panics, 2, "both attempts of the hard-fault job panic");
    let dropped: Vec<_> = round
        .reports
        .iter()
        .filter(|r| r.status == JobStatus::Dropped)
        .collect();
    assert_eq!(dropped.len(), 1);
    assert_eq!(dropped[0].attempts, 2);
    let err = dropped[0].error.as_deref().unwrap();
    assert!(
        err.contains("retries exhausted") && err.contains("injected fault"),
        "drop must carry the terminal cause: {err}"
    );
    // quorum counts the admitted population: 1 accepted of ceil(0.4*2)=1
    assert!(s.quorum_met && s.quorum_required == 1);

    // the same round at full quorum reports the miss
    let strict = RoundConfig { quorum: 1.0, ..cfg };
    let round = run_round(runner.manifest(), &devices, &jobs, &runner, &strict)
        .unwrap();
    assert!(!round.summary.quorum_met);
    assert_eq!(round.summary.quorum_required, 2);
}

#[test]
fn straggler_is_reassigned_to_another_device() {
    let mut runner = SimRunner::new(13).unwrap();
    runner.work_ms = 5;
    let jobs = sim_jobs(&[("pets", "taskedge:k=2")], 13);
    // dispatch scans devices in pool order, so the stalled device takes
    // the job first
    let devices = devs(&["jetson-nano", "jetson-orin-nano"]);
    let cfg = RoundConfig {
        seed: 13,
        job_timeout_ms: 100,
        faults: FaultPlan::parse("stall=jetson-nano:700", 13).unwrap(),
        ..RoundConfig::default()
    };
    let round =
        run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();
    let r = &round.reports[0];
    assert_eq!(r.status, JobStatus::Accepted);
    assert_eq!(
        r.device, "jetson-orin-nano",
        "the reassigned attempt must win while the straggler sleeps"
    );
    assert_eq!(r.attempts, 2);
    assert!(round.summary.reassigned >= 1);
}

#[test]
fn corrupt_upload_is_rejected_then_retried_in_drain_mode() {
    let dir = tmp_dir("corrupt_drain");
    let runner = SimRunner::new(17).unwrap();
    let jobs = sim_jobs(&[("pets", "taskedge:k=2")], 17);
    let devices = devs(&["jetson-orin-nano"]);
    let cfg = RoundConfig {
        seed: 17,
        backoff_ms: 1,
        delta_dir: Some(dir.clone()),
        faults: FaultPlan::parse("corrupt@0", 17).unwrap(),
        ..RoundConfig::default()
    };
    let round =
        run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();
    let r = &round.reports[0];
    assert_eq!(r.status, JobStatus::Accepted);
    assert_eq!(r.attempts, 2, "corrupted first upload forces a retry");
    assert_eq!(round.summary.rejected_uploads, 1);
    // drain mode: the delta lives on disk, digest-pinned, not in memory
    assert!(r.delta.is_none());
    let path = r.delta_path.as_ref().unwrap();
    let bytes = std::fs::read(path).unwrap();
    assert_eq!(bytes.len(), r.delta_bytes);
    assert_eq!(
        taskedge::util::hash::fnv1a64_hex(&bytes),
        *r.delta_digest.as_ref().unwrap()
    );
    // no .tmp staging file may survive the round
    assert!(delta_files(&dir).len() == 1);
    let kinds = journal_kinds(&dir);
    assert_eq!(kinds[0], "header");
    assert!(kinds.iter().any(|k| k == "reject"));
    assert!(kinds.iter().any(|k| k == "accept"));
    assert_eq!(kinds.last().map(String::as_str), Some("summary"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn denied_admission_is_terminal_not_admitted() {
    let mut runner = SimRunner::new(19).unwrap();
    runner.deny = true;
    let jobs = sim_jobs(&[("pets", "taskedge:k=2"), ("dtd", "lora")], 19);
    let devices = devs(&["rtx4090-edge-server"]);
    let cfg = RoundConfig { seed: 19, ..RoundConfig::default() };
    let round =
        run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();
    assert_eq!(round.summary.not_admitted, 2);
    for r in &round.reports {
        assert_eq!(r.status, JobStatus::NotAdmitted);
        assert_eq!(r.attempts, 0, "admission happens before any attempt");
        assert!(!r.admitted && r.error.is_some());
    }
    // an all-refused round trivially meets quorum over its empty admitted set
    assert!(round.summary.quorum_met);
    assert_eq!(round.summary.quorum_required, 0);
}

// ---------------------------------------------------------------------------
// Resume property: journal truncated anywhere ⇒ bit-identical outputs
// ---------------------------------------------------------------------------

fn resume_fixture_cfg(seed: u64, dir: &Path) -> RoundConfig {
    RoundConfig {
        seed,
        backoff_ms: 1,
        delta_dir: Some(dir.to_path_buf()),
        // deterministic seeded faults so the journal carries assign/fail/
        // reject traffic between the accepts, not just a clean prefix
        faults: FaultPlan::parse("panic=0.5,corrupt=0.3", seed).unwrap(),
        ..RoundConfig::default()
    }
}

/// Stage a crash snapshot: the journal truncated to `text`, plus every
/// delta file the completed round left behind (files from past the cut
/// are simply ignored by replay's digest check).
fn stage(dir: &Path, text: &str, files: &BTreeMap<String, Vec<u8>>) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join(JOURNAL_FILE), text).unwrap();
    for (name, bytes) in files {
        std::fs::write(dir.join(name), bytes).unwrap();
    }
}

fn run_resumed(
    runner: &SimRunner,
    devices: &[&'static DeviceProfile],
    jobs: &[Job],
    seed: u64,
    dir: &Path,
) -> RoundReport {
    let cfg =
        RoundConfig { resume: true, ..resume_fixture_cfg(seed, dir) };
    run_round(runner.manifest(), devices, jobs, runner, &cfg).unwrap()
}

/// The satellite property test: run one faulty drained round to
/// completion, then for EVERY line-boundary truncation of its journal
/// (which includes every phase boundary) resume from the truncated copy
/// and require reports and delta bytes bit-identical to the original
/// round. One extra case tears the final accept line mid-byte — the
/// torn-write crash the journal format must absorb.
#[test]
fn resume_is_bit_identical_at_every_truncation() {
    // seed 24 makes both fixture fault kinds fire: jobs 1 and 3 panic on
    // their first attempt, jobs 2 and 4 upload corrupted first deltas
    let seed = 24;
    let runner = SimRunner::new(seed).unwrap();
    // single device: report fields (device, attempts) are then a pure
    // function of (jobs, seed), which is what bit-identity needs
    let devices = devs(&["jetson-orin-nano"]);
    let jobs = sim_jobs(
        &[
            ("pets", "taskedge:k=2"),
            ("dtd", "lora"),
            ("eurosat", "vpt"),
            ("svhn", "adapter"),
            ("caltech101", "bitfit"),
        ],
        seed,
    );

    let dir_a = tmp_dir("resume_prop_a");
    let cfg = resume_fixture_cfg(seed, &dir_a);
    let original =
        run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();
    assert_eq!(original.summary.accepted, jobs.len());
    assert!(
        original.summary.panics > 0 && original.summary.rejected_uploads > 0,
        "fixture faults must actually fire for the property to mean much"
    );
    let want_reports: Vec<_> =
        original.reports.iter().map(fingerprint).collect();
    let want_files = delta_files(&dir_a);
    let journal = std::fs::read_to_string(dir_a.join(JOURNAL_FILE)).unwrap();
    let lines: Vec<&str> = journal.lines().collect();

    let dir_b = tmp_dir("resume_prop_b");
    for cut in 1..=lines.len() {
        let text = format!("{}\n", lines[..cut].join("\n"));
        let accepts_kept = lines[..cut]
            .iter()
            .filter(|l| {
                Json::parse(l).unwrap().get("kind").and_then(Json::as_str)
                    == Some("accept")
            })
            .count();
        stage(&dir_b, &text, &want_files);
        let resumed = run_resumed(&runner, &devices, &jobs, seed, &dir_b);
        assert_eq!(
            resumed.summary.replayed, accepts_kept,
            "cut after line {cut}: every surviving accept must replay"
        );
        let got: Vec<_> = resumed.reports.iter().map(fingerprint).collect();
        assert_eq!(got, want_reports, "cut after line {cut}: reports diverged");
        assert_eq!(
            delta_files(&dir_b),
            want_files,
            "cut after line {cut}: delta bytes diverged"
        );
    }

    // torn tail: cut the last accept line in half
    let last_accept = lines
        .iter()
        .rposition(|l| {
            Json::parse(l).unwrap().get("kind").and_then(Json::as_str)
                == Some("accept")
        })
        .expect("fixture round accepts jobs");
    let mut torn = lines[..last_accept].join("\n");
    torn.push('\n');
    torn.push_str(&lines[last_accept][..lines[last_accept].len() / 2]);
    stage(&dir_b, &torn, &want_files);
    let resumed = run_resumed(&runner, &devices, &jobs, seed, &dir_b);
    let got: Vec<_> = resumed.reports.iter().map(fingerprint).collect();
    assert_eq!(got, want_reports, "torn accept line: reports diverged");
    assert_eq!(delta_files(&dir_b), want_files);

    // a journal whose delta file was edited after the crash: the digest
    // check must force that job to re-run — and it reproduces the bytes
    let full = format!("{}\n", lines.join("\n"));
    let mut edited = want_files.clone();
    let first = edited.keys().next().unwrap().clone();
    edited.get_mut(&first).unwrap()[0] ^= 0xff;
    stage(&dir_b, &full, &edited);
    let resumed = run_resumed(&runner, &devices, &jobs, seed, &dir_b);
    assert_eq!(
        resumed.summary.replayed,
        jobs.len() - 1,
        "the tampered delta must be re-run, the rest replayed"
    );
    let got: Vec<_> = resumed.reports.iter().map(fingerprint).collect();
    assert_eq!(got, want_reports);
    assert_eq!(delta_files(&dir_b), want_files, "re-run must heal the bytes");

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn resume_refuses_mismatched_journals() {
    let seed = 29;
    let runner = SimRunner::new(seed).unwrap();
    let devices = devs(&["jetson-orin-nano"]);
    let jobs = sim_jobs(&[("pets", "taskedge:k=2"), ("dtd", "lora")], seed);
    let dir = tmp_dir("resume_mismatch");
    let cfg = RoundConfig {
        seed,
        delta_dir: Some(dir.clone()),
        ..RoundConfig::default()
    };
    run_round(runner.manifest(), &devices, &jobs, &runner, &cfg).unwrap();

    // different job list
    let other = sim_jobs(&[("pets", "taskedge:k=2"), ("dtd", "vpt")], seed);
    let resume = RoundConfig { resume: true, ..cfg.clone() };
    let err = run_round(runner.manifest(), &devices, &other, &runner, &resume)
        .unwrap_err()
        .to_string();
    assert!(err.contains("job list must match"), "{err}");

    // different seed
    let reseeded = RoundConfig { seed: seed + 1, ..resume };
    let err = run_round(runner.manifest(), &devices, &jobs, &runner, &reseeded)
        .unwrap_err()
        .to_string();
    assert!(err.contains("seed"), "{err}");

    // same dir without --resume: refuse to clobber the journal
    let fresh = RoundConfig { resume: false, ..cfg };
    let err = run_round(runner.manifest(), &devices, &jobs, &runner, &fresh)
        .unwrap_err()
        .to_string();
    assert!(err.contains("already exists"), "{err}");

    // resume without a delta dir is meaningless
    let nodir = RoundConfig {
        seed,
        resume: true,
        delta_dir: None,
        ..RoundConfig::default()
    };
    let err = run_round(runner.manifest(), &devices, &jobs, &runner, &nodir)
        .unwrap_err()
        .to_string();
    assert!(err.contains("--delta-dir"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
