//! TaskDelta property tests: extract -> apply round-trips bit-exactly for
//! every strategy family, guards reject stale/mismatched deltas without
//! corrupting the target store, and the sparse encoding actually delivers
//! the paper's storage claim at realistic widths.
//!
//! These tests run on host-side stores built from an in-memory manifest —
//! no AOT artifacts or PJRT runtime needed, so they always run in CI.

use std::collections::BTreeMap;

use taskedge::masking::Mask;
use taskedge::peft::{store_checkpoint_bytes, DeltaSizeReport, Strategy};
use taskedge::runtime::{HostTensor, Manifest, ModelConfig};
use taskedge::util::prop::{check, ensure};
use taskedge::util::rng::Rng;
use taskedge::vit::{LoraFactorDelta, ParamStore, TaskDelta};

/// A small but structurally faithful config: masked 2-D backbone weights,
/// bias vectors, a head, and LoRA targets.
fn cfg() -> ModelConfig {
    Manifest::parse(
        r#"{"version":1,"batch":2,"configs":{"p":{
        "image_size":8,"patch_size":4,"dim":16,"depth":1,"heads":2,
        "mlp_ratio":2,"num_classes":8,"channels":3,"prompt_len":4,
        "adapter_dim":2,"lora_rank":2,"num_params":1208,
        "params":[
          {"name":"blk0.w","shape":[16,32],"init":"trunc_normal","masked":true,"stat":"blk0.in"},
          {"name":"blk0.b","shape":[32],"init":"zeros","masked":false,"stat":null},
          {"name":"blk1.w","shape":[32,16],"init":"trunc_normal","masked":true,"stat":"blk1.in"},
          {"name":"head.w","shape":[16,8],"init":"trunc_normal","masked":true,"stat":"head.in"},
          {"name":"head.b","shape":[8],"init":"zeros","masked":false,"stat":null},
          {"name":"ln.scale","shape":[16],"init":"ones","masked":false,"stat":null}],
        "lora_targets":["blk0.w","blk1.w"],"adapters":[]}},"artifacts":[]}"#,
    )
    .unwrap()
    .config("p")
    .unwrap()
    .clone()
}

/// Perturb `store` at exactly the coordinates selected by `masks`,
/// returning the tuned copy (every touched value provably changes bits).
fn perturb_on_masks(
    store: &ParamStore,
    masks: &BTreeMap<String, Mask>,
    rng: &mut Rng,
) -> ParamStore {
    let mut tuned = store.clone();
    for (name, mask) in masks {
        if mask.count_ones() == 0 {
            continue;
        }
        let mut t = tuned.get(name).unwrap().clone();
        let d = t.f32s_mut().unwrap();
        for (i, &m) in mask.data.iter().enumerate() {
            if m == 1.0 {
                d[i] += 0.25 + rng.uniform_f32();
            }
        }
        tuned.set(name, t).unwrap();
    }
    tuned
}

fn stores_bit_equal(a: &ParamStore, b: &ParamStore) -> Result<(), String> {
    for name in a.order() {
        let x = a.get(name).unwrap().f32s().unwrap();
        let y = b.get(name).unwrap().f32s().unwrap();
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            if p.to_bits() != q.to_bits() {
                return Err(format!("{name}[{i}]: {p} != {q}"));
            }
        }
    }
    Ok(())
}

#[test]
fn dense_family_extract_apply_roundtrip_bit_exact() {
    let cfg = cfg();
    // one representative per dense mask shape: per-neuron top-k, random
    // support, everything, head-only, and biases
    let strategies = [
        Strategy::Magnitude { k: 3 },
        Strategy::Random { frac: 0.2 },
        Strategy::Full,
        Strategy::Linear,
        Strategy::BitFit,
    ];
    for strategy in strategies {
        check(
            &format!("dense-roundtrip-{}", strategy.name()),
            8,
            |r| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let backbone = ParamStore::init(&cfg, &mut rng);
                let masks = strategy
                    .build_masks(&cfg, &backbone, None, None, &mut rng)
                    .map_err(|e| format!("build_masks: {e:#}"))?;
                let tuned = perturb_on_masks(&backbone, &masks, &mut rng);
                let delta = TaskDelta::extract(&backbone, &tuned, &masks)
                    .map_err(|e| format!("extract: {e:#}"))?;
                let adapted = delta
                    .apply_to(&backbone)
                    .map_err(|e| format!("apply: {e:#}"))?;
                stores_bit_equal(&adapted, &tuned)?;
                // revert must recover the pristine backbone
                let mut reverted = adapted;
                delta
                    .revert(&mut reverted, &backbone)
                    .map_err(|e| format!("revert: {e:#}"))?;
                stores_bit_equal(&reverted, &backbone)
            },
        );
    }
}

#[test]
fn lora_family_roundtrip_and_revert() {
    let cfg = cfg();
    for strategy in [Strategy::Lora] {
        check(
            &format!("lora-roundtrip-{}", strategy.name()),
            8,
            |r| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let backbone = ParamStore::init(&cfg, &mut rng);
                let masks = strategy
                    .build_masks(&cfg, &backbone, None, None, &mut rng)
                    .map_err(|e| format!("build_masks: {e:#}"))?;
                // simulate a trained session: fresh head + (B, A) per target
                let mut tuned = backbone.clone();
                tuned.reinit_head(&mut rng).unwrap();
                let mut delta = TaskDelta::diff(&backbone, &tuned)
                    .map_err(|e| format!("diff: {e:#}"))?;
                for (name, mask) in &masks {
                    let p = cfg.param(name).unwrap();
                    let (d_in, d_out) = (p.shape[0], p.shape[1]);
                    let r = cfg.lora_rank;
                    delta.lora.insert(
                        name.clone(),
                        LoraFactorDelta {
                            b: HostTensor::from_f32(
                                &[d_in, r],
                                rng.normal_vec(d_in * r, 0.5),
                            )
                            .unwrap(),
                            a: HostTensor::from_f32(
                                &[r, d_out],
                                rng.normal_vec(r * d_out, 0.5),
                            )
                            .unwrap(),
                            mask: mask.clone(),
                        },
                    );
                }
                let adapted = delta
                    .apply_to(&backbone)
                    .map_err(|e| format!("apply: {e:#}"))?;
                // deterministic merge: applying twice gives identical bits
                let adapted2 = delta.apply_to(&backbone).unwrap();
                stores_bit_equal(&adapted, &adapted2)?;
                // factors actually moved the targets
                for name in masks.keys() {
                    ensure(
                        adapted.get(name).unwrap() != backbone.get(name).unwrap(),
                        format!("lora target {name} unchanged"),
                    )?;
                }
                // revert must recover the pristine backbone bit-exactly
                let mut reverted = adapted;
                delta
                    .revert(&mut reverted, &backbone)
                    .map_err(|e| format!("revert: {e:#}"))?;
                stores_bit_equal(&reverted, &backbone)
            },
        );
    }
}

#[test]
fn aux_family_delta_carries_extra_tensors() {
    // VPT/Adapter deltas: dense head planes + extra tensors that apply_to
    // must carry but NOT merge (they have no backbone slot)
    let cfg = cfg();
    check("aux-roundtrip", 8, |r| r.next_u64(), |&seed| {
        let mut rng = Rng::new(seed);
        let backbone = ParamStore::init(&cfg, &mut rng);
        let mut delta = TaskDelta::new("p");
        delta.dense.insert(
            "head.w".into(),
            HostTensor::from_f32(&[16, 8], rng.normal_vec(128, 0.1)).unwrap(),
        );
        delta.extra.insert(
            "prompt".into(),
            HostTensor::from_f32(&[4, 16], rng.normal_vec(64, 0.1)).unwrap(),
        );
        let adapted = delta
            .apply_to(&backbone)
            .map_err(|e| format!("apply: {e:#}"))?;
        ensure(
            adapted.get("head.w").unwrap() == delta.dense.get("head.w").unwrap(),
            "head.w not replaced",
        )?;
        ensure(
            adapted.get("prompt").is_err(),
            "extra tensor must not be merged into the backbone",
        )?;
        let mut reverted = adapted;
        delta.revert(&mut reverted, &backbone).unwrap();
        stores_bit_equal(&reverted, &backbone)
    });
}

#[test]
fn apply_guards_reject_stale_or_mismatched_deltas() {
    let cfg = cfg();
    check("apply-guards", 8, |r| r.next_u64(), |&seed| {
        let mut rng = Rng::new(seed);
        let backbone = ParamStore::init(&cfg, &mut rng);
        let masks = Strategy::Magnitude { k: 3 }
            .build_masks(&cfg, &backbone, None, None, &mut rng)
            .unwrap();
        let tuned = perturb_on_masks(&backbone, &masks, &mut rng);
        let good = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();

        // config-name mismatch
        let mut bad = good.clone();
        bad.config_name = "other-model".into();
        ensure(bad.apply_to(&backbone).is_err(), "config mismatch accepted")?;

        // stale recorded shape
        let mut bad = good.clone();
        if let Some(sd) = bad.sparse.values_mut().next() {
            sd.shape = vec![1, 1];
            let mut store = backbone.clone();
            ensure(
                bad.apply_in_place(&mut store).is_err(),
                "stale shape accepted",
            )?;
            stores_bit_equal(&store, &backbone)
                .map_err(|e| format!("store corrupted by failed apply: {e}"))?;
        }

        // out-of-bounds index (mask built for a different layout)
        let mut bad = good.clone();
        if let Some((name, sd)) = bad.sparse.iter_mut().next() {
            let numel = backbone.get(name).unwrap().numel();
            if let Some(last) = sd.indices.last_mut() {
                *last = numel as u32;
                let mut store = backbone.clone();
                ensure(
                    bad.apply_in_place(&mut store).is_err(),
                    "out-of-bounds index accepted",
                )?;
                stores_bit_equal(&store, &backbone).map_err(|e| {
                    format!("store corrupted by failed apply: {e}")
                })?;
            }
        }
        Ok(())
    });
}

#[test]
fn save_load_roundtrips_randomized_deltas() {
    let cfg = cfg();
    check("save-load-roundtrip", 6, |r| r.next_u64(), |&seed| {
        let mut rng = Rng::new(seed);
        let backbone = ParamStore::init(&cfg, &mut rng);
        let masks = Strategy::Random { frac: 0.3 }
            .build_masks(&cfg, &backbone, None, None, &mut rng)
            .unwrap();
        let tuned = perturb_on_masks(&backbone, &masks, &mut rng);
        let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        delta.strategy = "random_0.3".into();
        delta.task = format!("task-{seed}");
        let path = std::env::temp_dir()
            .join(format!("taskedge_prop_delta_{seed:x}.bin"));
        delta.save(&path).map_err(|e| format!("save: {e:#}"))?;
        let bytes = std::fs::metadata(&path).unwrap().len() as usize;
        let loaded = TaskDelta::load(&path).map_err(|e| format!("load: {e:#}"))?;
        std::fs::remove_file(&path).ok();
        ensure(bytes == delta.file_bytes(), "file_bytes not exact")?;
        ensure(loaded == delta, "save/load changed the delta")?;
        // and the loaded artifact still applies bit-exactly
        let adapted = loaded.apply_to(&backbone).unwrap();
        stores_bit_equal(&adapted, &tuned)
    });
}

/// Acceptance: at realistic layer widths the paper's regime holds — a
/// `taskedge:k=8` delta checkpoint is <= 1% of the full checkpoint. (At
/// toy widths like `micro`'s dim=64, k=8 touches 12% of each weight and no
/// encoding can hide that; the claim is about real models, so this test
/// pins it at a real width: d_in = 4096.)
#[test]
fn taskedge_k8_delta_is_at_most_one_percent_of_full_checkpoint() {
    let cfg = Manifest::parse(
        r#"{"version":1,"batch":2,"configs":{"big":{
        "image_size":8,"patch_size":4,"dim":4096,"depth":1,"heads":2,
        "mlp_ratio":2,"num_classes":8,"channels":3,"prompt_len":4,
        "adapter_dim":2,"lora_rank":2,"num_params":16810000,
        "params":[
          {"name":"blk.w","shape":[4096,4096],"init":"zeros","masked":true,"stat":"blk.in"},
          {"name":"head.w","shape":[4096,8],"init":"zeros","masked":true,"stat":"head.in"},
          {"name":"head.b","shape":[8],"init":"zeros","masked":false,"stat":null}],
        "lora_targets":[],"adapters":[]}},"artifacts":[]}"#,
    )
    .unwrap()
    .config("big")
    .unwrap()
    .clone();
    let backbone = ParamStore::zeros_like(&cfg);

    // the Alg. 1 mask: exactly k=8 coordinates per output neuron of blk.w,
    // all of head.* (fresh per task)
    let (d_in, d_out, k) = (4096usize, 4096usize, 8usize);
    let mut mask = Mask::zeros(&[d_in, d_out]);
    for c in 0..d_out {
        for r in 0..k {
            // distinct rows per column (13 is odd, so r*13 mod 4096 differ)
            let i = (c * 7 + r * 13) % d_in;
            mask.data[i * d_out + c] = 1.0;
        }
    }
    let mut masks = BTreeMap::new();
    masks.insert("blk.w".to_string(), mask);
    masks.insert("head.w".to_string(), Mask::ones(&[4096, 8]));
    masks.insert("head.b".to_string(), Mask::ones(&[8]));

    let mut rng = Rng::new(42);
    let tuned = perturb_on_masks(&backbone, &masks, &mut rng);
    let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
    delta.strategy = "taskedge_k8".into();
    delta.task = "acceptance".into();

    let report = DeltaSizeReport::new(&delta, &cfg);
    assert_eq!(report.full_bytes, store_checkpoint_bytes(&cfg));
    assert!(
        report.delta_bytes * 100 <= report.full_bytes,
        "taskedge:k=8 delta must be <= 1% of a full checkpoint: \
         {} vs {} bytes ({:.3}%)",
        report.delta_bytes,
        report.full_bytes,
        report.ratio() * 100.0
    );
    // the accounting is exact: the saved artifact is byte-for-byte the size
    // the report claims
    let path = std::env::temp_dir().join("taskedge_prop_delta_big.bin");
    delta.save(&path).unwrap();
    assert_eq!(
        std::fs::metadata(&path).unwrap().len() as usize,
        report.delta_bytes
    );
    std::fs::remove_file(&path).ok();
}
