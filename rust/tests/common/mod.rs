//! Shared helpers for the integration tests: artifact discovery + a
//! process-wide runtime (PJRT client creation and XLA compiles are
//! expensive; tests share one).

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use taskedge::runtime::Runtime;

pub fn artifacts_dir() -> PathBuf {
    // Integration tests run from the package root.
    let dir = std::env::var("TASKEDGE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let p = PathBuf::from(dir);
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` before \
         `cargo test`"
    );
    p
}

static RT: OnceLock<Arc<Runtime>> = OnceLock::new();

pub fn runtime() -> Arc<Runtime> {
    RT.get_or_init(|| Arc::new(Runtime::load(&artifacts_dir()).unwrap()))
        .clone()
}
