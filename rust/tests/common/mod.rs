//! Shared helpers for the integration tests: artifact discovery + a
//! process-wide runtime (PJRT client creation and XLA compiles are
//! expensive; tests share one).

// not every test binary uses every helper
#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use taskedge::runtime::Runtime;

/// Artifact directory resolution shared by the loader and the skip guard.
/// Integration tests run from the package root.
fn artifacts_path() -> PathBuf {
    PathBuf::from(
        std::env::var("TASKEDGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    )
}

pub fn artifacts_dir() -> PathBuf {
    let p = artifacts_path();
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` before \
         `cargo test`"
    );
    p
}

/// True when the AOT artifacts are absent. Integration tests call this
/// first and return early, so `cargo test` stays green (skipping, loudly)
/// in environments that haven't run `make artifacts` — e.g. lint-only CI —
/// instead of panicking in every test.
pub fn skip_without_artifacts() -> bool {
    let dir = artifacts_path();
    if dir.join("manifest.json").exists() {
        return false;
    }
    eprintln!(
        "SKIP: {}/manifest.json missing — run `make artifacts` to enable \
         integration tests",
        dir.display()
    );
    true
}

static RT: OnceLock<Arc<Runtime>> = OnceLock::new();

pub fn runtime() -> Arc<Runtime> {
    RT.get_or_init(|| Arc::new(Runtime::load(&artifacts_dir()).unwrap()))
        .clone()
}
