//! The prepared-parameter training path (frozen backbone/mask literals +
//! compiled step plans + batch prefetch) must be a pure performance
//! change: bit-identical results to the per-step conversion path, and
//! frozen-set conversions that are O(1) per session — never O(steps).
//!
//! Device residency rides the same contract: resident device buffers vs
//! the literal-only path are bit-identical, eviction under a byte budget
//! degrades to re-upload (never an error, never a wrong answer), and a
//! donation re-keys a prepared set in place — old-generation lookups miss,
//! new-generation lookups hit the refreshed set.

mod common;

use std::sync::Arc;

use taskedge::coordinator::{FinetuneSession, SessionResult, TrainConfig};
use taskedge::data::{generate_task, task_by_name};
use taskedge::peft::Strategy;
use taskedge::runtime::{ArtifactSpec, HostTensor, Runtime};
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn run_once(
    rt: &Runtime,
    strategy: Strategy,
    prepared_io: bool,
    epochs: usize,
) -> SessionResult {
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    // same seed every call: backbones are bit-identical across runs
    let backbone = ParamStore::init(&cfg, &mut Rng::new(77));
    let task = task_by_name("dtd").unwrap();
    let (train, eval) =
        generate_task(task, cfg.image_size, 64, batch * 2, 5).unwrap();
    let tcfg = TrainConfig {
        epochs,
        lr: 1e-3,
        seed: 5,
        calib_batches: 2,
        prepared_io,
        ..Default::default()
    };
    let mut session = FinetuneSession::new(rt, "micro", strategy, tcfg).unwrap();
    session.run(&backbone, &train, &eval, task.name).unwrap()
}

/// The tentpole equivalence guarantee: for a dense (TaskEdge) and a
/// frozen-family (SparseLora) strategy, the prepared path and the
/// per-step conversion path produce bit-identical loss curves, eval
/// metrics, and `TaskDelta` payloads (down to the serialized bytes).
#[test]
fn prepared_and_unprepared_paths_are_bit_identical() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    for strategy in [Strategy::TaskEdge { k: 2 }, Strategy::SparseLora { k: 4 }] {
        let name = strategy.name();
        let a = run_once(&rt, strategy.clone(), true, 2);
        let b = run_once(&rt, strategy, false, 2);

        assert_eq!(a.record.curve.len(), b.record.curve.len());
        for (ea, eb) in a.record.curve.iter().zip(&b.record.curve) {
            assert_eq!(
                ea.train_loss.to_bits(),
                eb.train_loss.to_bits(),
                "{name} epoch {}: train loss diverged ({} vs {})",
                ea.epoch,
                ea.train_loss,
                eb.train_loss
            );
            assert_eq!(ea.train_acc.to_bits(), eb.train_acc.to_bits(), "{name}");
            assert_eq!(ea.eval_loss.to_bits(), eb.eval_loss.to_bits(), "{name}");
            assert_eq!(ea.eval_top1.to_bits(), eb.eval_top1.to_bits(), "{name}");
            assert_eq!(ea.eval_top5.to_bits(), eb.eval_top5.to_bits(), "{name}");
        }
        assert_eq!(a.trainable_params, b.trainable_params, "{name}");
        assert_eq!(a.masks, b.masks, "{name}: allocation diverged");

        // the tuned task state is identical in memory...
        assert_eq!(a.delta, b.delta, "{name}: TaskDelta diverged");
        // ...and byte-for-byte on disk
        let dir = std::env::temp_dir();
        let pa = dir.join(format!("taskedge_prep_{name}_a.tedl"));
        let pb = dir.join(format!("taskedge_prep_{name}_b.tedl"));
        a.delta.save(&pa).unwrap();
        b.delta.save(&pb).unwrap();
        let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
        assert_eq!(ba, bb, "{name}: serialized delta bytes diverged");
        assert_eq!(ba.len(), a.delta.file_bytes(), "{name}: byte accounting");
    }
}

/// Returns the `param_prepares` delta for one prepared session of
/// `strategy` at `epochs` epochs, on a dedicated runtime (the stats
/// counters are process-wide per runtime; sharing the test-global runtime
/// would race with concurrently running tests).
fn prepares_for(rt: &Runtime, strategy: Strategy, epochs: usize) -> usize {
    let before = rt.stats().param_prepares;
    let _ = run_once(rt, strategy, true, epochs);
    rt.stats().param_prepares - before
}

/// Frozen-backbone families must convert their frozen sets once per
/// session: the prepare count is identical whether the session runs 1 or
/// 3 epochs (the old path converted the entire backbone every step).
#[test]
fn frozen_family_prepares_are_constant_in_steps() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    for strategy in [Strategy::SparseLora { k: 4 }, Strategy::Vpt] {
        let name = strategy.name();
        let short = prepares_for(&rt, strategy.clone(), 1);
        let long = prepares_for(&rt, strategy, 3);
        assert!(short >= 1, "{name}: prepared session must prepare");
        assert!(
            short <= 4,
            "{name}: frozen sets are per-artifact, expected a handful of \
             prepares, got {short}"
        );
        assert_eq!(
            short, long,
            "{name}: frozen-set conversions must not scale with steps"
        );
    }
}

/// The per-step conversion baseline must never touch the prepared-literal
/// machinery — it is the pre-PR cost model the bench compares against.
#[test]
fn unprepared_path_never_prepares() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    let before = rt.stats().param_prepares;
    let _ = run_once(&rt, Strategy::TaskEdge { k: 2 }, false, 1);
    let _ = run_once(&rt, Strategy::SparseLora { k: 4 }, false, 1);
    assert_eq!(
        rt.stats().param_prepares,
        before,
        "prepared_io=false sessions must not build prepared literal sets"
    );
}

/// Device residency must be a pure performance change over the cached
/// literal path: a session run with resident device buffers and the same
/// session with residency disabled (`TASKEDGE_RESIDENT=0` semantics)
/// produce bit-identical curves — and the disabled runtime never uploads
/// a resident set.
#[test]
fn resident_and_literal_paths_are_bit_identical() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt_res = Runtime::load(&common::artifacts_dir()).unwrap();
    rt_res.set_resident(true);
    rt_res.set_resident_budget_bytes(usize::MAX);
    let rt_lit = Runtime::load(&common::artifacts_dir()).unwrap();
    rt_lit.set_resident(false);
    for strategy in [Strategy::TaskEdge { k: 2 }, Strategy::SparseLora { k: 4 }] {
        let name = strategy.name();
        let a = run_once(&rt_res, strategy.clone(), true, 2);
        let b = run_once(&rt_lit, strategy, true, 2);
        assert_eq!(a.record.curve.len(), b.record.curve.len(), "{name}");
        for (ea, eb) in a.record.curve.iter().zip(&b.record.curve) {
            assert_eq!(
                ea.train_loss.to_bits(),
                eb.train_loss.to_bits(),
                "{name} epoch {}: resident vs literal train loss diverged",
                ea.epoch
            );
            assert_eq!(ea.eval_loss.to_bits(), eb.eval_loss.to_bits(), "{name}");
            assert_eq!(ea.eval_top1.to_bits(), eb.eval_top1.to_bits(), "{name}");
        }
        assert_eq!(a.delta, b.delta, "{name}: TaskDelta diverged");
    }
    let res = rt_res.stats();
    let lit = rt_lit.stats();
    assert!(
        res.resident_prepares >= 1,
        "resident runtime never uploaded a device-resident set"
    );
    assert!(
        res.h2d_resident_bytes > 0,
        "resident runtime reported no resident-bound bytes"
    );
    assert_eq!(lit.resident_prepares, 0, "disabled runtime uploaded a set");
    assert_eq!(lit.resident_bytes, 0, "disabled runtime holds device bytes");
}

/// `(frozen slots, full input list)` for the fwd artifact over `store`:
/// every `param:*` input becomes a frozen slot, `images` stays dynamic.
fn fwd_io(
    spec: &ArtifactSpec,
    store: &ParamStore,
    images: &HostTensor,
) -> (Vec<(usize, HostTensor)>, Vec<HostTensor>) {
    let mut fixed = Vec::new();
    let mut full = Vec::new();
    for (i, io) in spec.inputs.iter().enumerate() {
        if let Some(p) = io.name.strip_prefix("param:") {
            let t = store.get(p).unwrap().clone();
            fixed.push((i, t.clone()));
            full.push(t);
        } else {
            full.push(images.clone());
        }
    }
    (fixed, full)
}

fn slot_refs(fixed: &[(usize, HostTensor)]) -> Vec<(usize, &HostTensor)> {
    fixed.iter().map(|(i, t)| (*i, t)).collect()
}

/// Shared fixture for the direct prepare/donate/evict tests: a dedicated
/// runtime plus two parameter stores and one image batch.
fn fwd_fixture(rt: &Runtime) -> (ArtifactSpec, ParamStore, ParamStore, HostTensor) {
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    let spec = rt.manifest().artifact_for("fwd", "micro").unwrap().clone();
    let store_a = ParamStore::init(&cfg, &mut Rng::new(21));
    let store_b = ParamStore::init(&cfg, &mut Rng::new(22));
    let task = task_by_name("dtd").unwrap();
    let (train, _) = generate_task(task, cfg.image_size, batch, 0, 5).unwrap();
    let ids: Vec<usize> = (0..batch).collect();
    let (images, _) = train.batch(&ids).unwrap();
    (spec, store_a, store_b, images)
}

/// Under a byte budget that fits exactly one set, preparing a second set
/// evicts the first (LRU), and an evicted set **degrades to re-upload**:
/// it keeps serving answers bit-identical to the unprepared execute path.
#[test]
fn eviction_under_budget_degrades_to_reupload() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    rt.set_resident(true);
    rt.set_resident_budget_bytes(usize::MAX);
    let (spec, store_a, store_b, images) = fwd_fixture(&rt);
    let (fixed_a, full_a) = fwd_io(&spec, &store_a, &images);
    let (fixed_b, full_b) = fwd_io(&spec, &store_b, &images);

    let prep_a = rt
        .prepare(&spec.name, store_a.generation(), &slot_refs(&fixed_a))
        .unwrap();
    let set_bytes = prep_a.fixed_bytes();
    assert!(set_bytes > 0, "fwd must have a frozen parameter set");
    assert_eq!(prep_a.resident_bytes(), set_bytes, "first set not resident");

    // room for exactly one set: the second prepare must push the first out
    rt.set_resident_budget_bytes(set_bytes);
    let e0 = rt.stats().resident_evictions;
    let prep_b = rt
        .prepare(&spec.name, store_b.generation(), &slot_refs(&fixed_b))
        .unwrap();
    assert!(
        rt.stats().resident_evictions > e0,
        "second set fit without evicting — budget not enforced"
    );
    assert!(
        rt.stats().resident_bytes <= set_bytes,
        "resident gauge exceeds the configured budget"
    );
    assert_eq!(prep_a.resident_bytes(), 0, "LRU set was not the one evicted");

    // the evicted set re-uploads transparently and stays bit-identical to
    // the unprepared path; its re-upload in turn evicts the other set
    let out_a = rt.execute_prepared(&prep_a, &[&images]).unwrap();
    assert_eq!(out_a, rt.execute(&spec.name, &full_a).unwrap());
    let out_b = rt.execute_prepared(&prep_b, &[&images]).unwrap();
    assert_eq!(out_b, rt.execute(&spec.name, &full_b).unwrap());
    assert!(
        rt.stats().resident_bytes <= set_bytes,
        "budget violated after degrade-to-reupload round trip"
    );
}

/// A donation refreshes frozen slots in place and re-keys the set: the
/// donated contents answer for the new generation (bit-identical to a
/// fresh execute over the new parameters), lookups at the old generation
/// miss, and lookups at the new generation hit the same set.
#[test]
fn donation_bumps_the_generation_and_rekeys_the_cache() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    rt.set_resident(true);
    rt.set_resident_budget_bytes(usize::MAX);
    let (spec, store_a, store_b, images) = fwd_fixture(&rt);
    let (fixed_a, full_a) = fwd_io(&spec, &store_a, &images);
    let (fixed_b, full_b) = fwd_io(&spec, &store_b, &images);

    let prep = rt
        .prepare(&spec.name, store_a.generation(), &slot_refs(&fixed_a))
        .unwrap();
    let gen_a = prep.generation();
    let again = rt
        .prepare(&spec.name, store_a.generation(), &slot_refs(&fixed_a))
        .unwrap();
    assert!(Arc::ptr_eq(&prep, &again), "pre-donation lookup must hit");
    let out_before = rt.execute_prepared(&prep, &[&images]).unwrap();
    assert_eq!(out_before, rt.execute(&spec.name, &full_a).unwrap());

    // the write-back: store_b's tensors donated into the same set
    let d0 = rt.stats().donations;
    rt.donate_writeback(&prep, store_b.generation(), &slot_refs(&fixed_b))
        .unwrap();
    assert_eq!(rt.stats().donations, d0 + 1);
    assert_eq!(
        prep.generation(),
        store_b.generation(),
        "donation must re-key the set to the new generation"
    );
    let out_after = rt.execute_prepared(&prep, &[&images]).unwrap();
    assert_eq!(
        out_after,
        rt.execute(&spec.name, &full_b).unwrap(),
        "donated set must answer with the donated parameters"
    );

    // old key: miss (fresh set); new key: hit the donated set in place
    let miss = rt
        .prepare(&spec.name, gen_a, &slot_refs(&fixed_a))
        .unwrap();
    assert!(
        !Arc::ptr_eq(&prep, &miss),
        "a lookup at the pre-donation generation hit the donated set"
    );
    let hit = rt
        .prepare(&spec.name, store_b.generation(), &slot_refs(&fixed_b))
        .unwrap();
    assert!(
        Arc::ptr_eq(&prep, &hit),
        "a lookup at the donated generation must hit the set in place"
    );
}
