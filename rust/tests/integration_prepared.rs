//! The prepared-parameter training path (frozen backbone/mask literals +
//! compiled step plans + batch prefetch) must be a pure performance
//! change: bit-identical results to the per-step conversion path, and
//! frozen-set conversions that are O(1) per session — never O(steps).

mod common;

use taskedge::coordinator::{FinetuneSession, SessionResult, TrainConfig};
use taskedge::data::{generate_task, task_by_name};
use taskedge::peft::Strategy;
use taskedge::runtime::Runtime;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn run_once(
    rt: &Runtime,
    strategy: Strategy,
    prepared_io: bool,
    epochs: usize,
) -> SessionResult {
    let cfg = rt.manifest().config("micro").unwrap().clone();
    let batch = rt.manifest().batch;
    // same seed every call: backbones are bit-identical across runs
    let backbone = ParamStore::init(&cfg, &mut Rng::new(77));
    let task = task_by_name("dtd").unwrap();
    let (train, eval) =
        generate_task(task, cfg.image_size, 64, batch * 2, 5).unwrap();
    let tcfg = TrainConfig {
        epochs,
        lr: 1e-3,
        seed: 5,
        calib_batches: 2,
        prepared_io,
        ..Default::default()
    };
    let mut session = FinetuneSession::new(rt, "micro", strategy, tcfg).unwrap();
    session.run(&backbone, &train, &eval, task.name).unwrap()
}

/// The tentpole equivalence guarantee: for a dense (TaskEdge) and a
/// frozen-family (SparseLora) strategy, the prepared path and the
/// per-step conversion path produce bit-identical loss curves, eval
/// metrics, and `TaskDelta` payloads (down to the serialized bytes).
#[test]
fn prepared_and_unprepared_paths_are_bit_identical() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = common::runtime();
    for strategy in [Strategy::TaskEdge { k: 2 }, Strategy::SparseLora { k: 4 }] {
        let name = strategy.name();
        let a = run_once(&rt, strategy.clone(), true, 2);
        let b = run_once(&rt, strategy, false, 2);

        assert_eq!(a.record.curve.len(), b.record.curve.len());
        for (ea, eb) in a.record.curve.iter().zip(&b.record.curve) {
            assert_eq!(
                ea.train_loss.to_bits(),
                eb.train_loss.to_bits(),
                "{name} epoch {}: train loss diverged ({} vs {})",
                ea.epoch,
                ea.train_loss,
                eb.train_loss
            );
            assert_eq!(ea.train_acc.to_bits(), eb.train_acc.to_bits(), "{name}");
            assert_eq!(ea.eval_loss.to_bits(), eb.eval_loss.to_bits(), "{name}");
            assert_eq!(ea.eval_top1.to_bits(), eb.eval_top1.to_bits(), "{name}");
            assert_eq!(ea.eval_top5.to_bits(), eb.eval_top5.to_bits(), "{name}");
        }
        assert_eq!(a.trainable_params, b.trainable_params, "{name}");
        assert_eq!(a.masks, b.masks, "{name}: allocation diverged");

        // the tuned task state is identical in memory...
        assert_eq!(a.delta, b.delta, "{name}: TaskDelta diverged");
        // ...and byte-for-byte on disk
        let dir = std::env::temp_dir();
        let pa = dir.join(format!("taskedge_prep_{name}_a.tedl"));
        let pb = dir.join(format!("taskedge_prep_{name}_b.tedl"));
        a.delta.save(&pa).unwrap();
        b.delta.save(&pb).unwrap();
        let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
        assert_eq!(ba, bb, "{name}: serialized delta bytes diverged");
        assert_eq!(ba.len(), a.delta.file_bytes(), "{name}: byte accounting");
    }
}

/// Returns the `param_prepares` delta for one prepared session of
/// `strategy` at `epochs` epochs, on a dedicated runtime (the stats
/// counters are process-wide per runtime; sharing the test-global runtime
/// would race with concurrently running tests).
fn prepares_for(rt: &Runtime, strategy: Strategy, epochs: usize) -> usize {
    let before = rt.stats().param_prepares;
    let _ = run_once(rt, strategy, true, epochs);
    rt.stats().param_prepares - before
}

/// Frozen-backbone families must convert their frozen sets once per
/// session: the prepare count is identical whether the session runs 1 or
/// 3 epochs (the old path converted the entire backbone every step).
#[test]
fn frozen_family_prepares_are_constant_in_steps() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    for strategy in [Strategy::SparseLora { k: 4 }, Strategy::Vpt] {
        let name = strategy.name();
        let short = prepares_for(&rt, strategy.clone(), 1);
        let long = prepares_for(&rt, strategy, 3);
        assert!(short >= 1, "{name}: prepared session must prepare");
        assert!(
            short <= 4,
            "{name}: frozen sets are per-artifact, expected a handful of \
             prepares, got {short}"
        );
        assert_eq!(
            short, long,
            "{name}: frozen-set conversions must not scale with steps"
        );
    }
}

/// The per-step conversion baseline must never touch the prepared-literal
/// machinery — it is the pre-PR cost model the bench compares against.
#[test]
fn unprepared_path_never_prepares() {
    if common::skip_without_artifacts() {
        return;
    }
    let rt = Runtime::load(&common::artifacts_dir()).unwrap();
    let before = rt.stats().param_prepares;
    let _ = run_once(&rt, Strategy::TaskEdge { k: 2 }, false, 1);
    let _ = run_once(&rt, Strategy::SparseLora { k: 4 }, false, 1);
    assert_eq!(
        rt.stats().param_prepares,
        before,
        "prepared_io=false sessions must not build prepared literal sets"
    );
}
