//! Loopback chaos bench for the networked fleet transport: a real TCP
//! coordinator ([`FleetServer`] + [`NetRunner`]) driving real
//! [`participate`] threads over 127.0.0.1, under injected wire faults —
//! and the bit-identity contract against the in-process [`SimRunner`].
//!
//! Five rounds:
//!   sim      — in-process SimRunner round, drained: the ground truth
//!   clean    — TCP round, one participant per device, no faults: accepted
//!              delta files and digests must be byte-identical to `sim`
//!   chaos    — TCP round under frame corruption/dup/drop/delay plus engine
//!              panics and corrupted uploads, with one participant
//!              disconnecting the moment Train starts and rejoining
//!   resume   — the coordinator is killed (no shutdown frame), the journal
//!              truncated mid-accepts, and a fresh coordinator restarted on
//!              the SAME port with `resume: true`; the surviving
//!              participants re-attach and the replay is bit-identical
//!   failover — a hot standby attaches and receives the journal stream
//!              under `shipdrop` loss; `killprimary@collect` kills the
//!              primary, the standby's lease expires and it promotes one
//!              generation up at its advertised address; the participants
//!              re-target it and the finished round loses zero accepted
//!              uploads (shipped accepts replay, dropped ones re-run
//!              bit-identically)
//!
//! Results land in `BENCH_fleet_net.json`. `TASKEDGE_SMOKE=1` shrinks the
//! job grid to CI scale.
//!
//!   cargo bench --bench fleet_net

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use taskedge::coordinator::fleet::{Job, JobStatus};
use taskedge::coordinator::rounds::JOURNAL_FILE;
use taskedge::coordinator::{
    run_round, FaultPlan, JobRunner, RoundConfig, RoundReport, SimRunner,
    TrainConfig,
};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::edge::DeviceProfile;
use taskedge::net::{
    install_shipped_journal, participate, stand_by, FleetServer, NetConfig,
    NetRunner, NetState, ParticipantOpts, ParticipantStats, StandbyOpts,
};
use taskedge::util::json::Json;

const SEED: u64 = 42;

const DEVICES: [&str; 4] =
    ["jetson-orin-nano", "jetson-nano", "phone-flagship", "rtx4090-edge-server"];

/// Wire-level storm applied by the chaos coordinator's writer threads.
const WIRE_FAULTS: &str = "netcorrupt=0.04,netdup=0.05,netdrop=0.03,netdelay=5";

/// Engine-level storm (same knobs the local chaos bench uses): transient
/// panics and corrupted uploads that `accept_upload` must reject.
const ENGINE_FAULTS: &str = "panic=0.3,corrupt=0.2";

/// One participant drops its connection the moment Train is announced,
/// then rejoins through the reconnect loop.
const DISCONNECT_DEV: &str = "phone-flagship";

/// Replication loss applied to the failover round's journal stream: each
/// shipped entry is silently lost with this probability, so the promoted
/// standby must re-run the holes instead of replaying them.
const SHIP_FAULTS: &str = "shipdrop=0.25";

fn smoke() -> bool {
    std::env::var("TASKEDGE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn tasks() -> &'static [&'static str] {
    if smoke() {
        &["pets", "dtd"]
    } else {
        &["pets", "dtd", "eurosat", "caltech101", "flowers102", "svhn"]
    }
}

fn strategies() -> &'static [&'static str] {
    if smoke() {
        &["taskedge:k=2", "lora"]
    } else {
        &["taskedge:k=2", "lora", "vpt", "adapter"]
    }
}

fn jobs() -> Result<Vec<Job>> {
    let mut jobs = Vec::new();
    for t in tasks() {
        let task = task_by_name(t)?;
        for s in strategies() {
            jobs.push(Job {
                task: task.clone(),
                strategy: taskedge::peft::Strategy::parse(s)?,
                train_cfg: TrainConfig { seed: SEED, ..Default::default() },
                n_train: 32,
                n_eval: 16,
            });
        }
    }
    Ok(jobs)
}

fn devices() -> Result<Vec<&'static DeviceProfile>> {
    DEVICES
        .iter()
        .map(|n| profile_by_name(n).with_context(|| format!("device {n:?}")))
        .collect()
}

fn tmp_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("taskedge_fleet_net_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Digest per (task, strategy) — the identity the transport must preserve.
fn digests(r: &RoundReport) -> BTreeMap<(String, String), String> {
    r.reports
        .iter()
        .filter_map(|r| {
            r.delta_digest
                .clone()
                .map(|d| ((r.task.clone(), r.strategy.clone()), d))
        })
        .collect()
}

/// Drained delta file bytes per (task, strategy).
fn delta_files(r: &RoundReport) -> Result<BTreeMap<(String, String), Vec<u8>>> {
    let mut out = BTreeMap::new();
    for rep in &r.reports {
        if let Some(path) = &rep.delta_path {
            let bytes = std::fs::read(path)
                .with_context(|| format!("reading drained delta {path:?}"))?;
            out.insert((rep.task.clone(), rep.strategy.clone()), bytes);
        }
    }
    Ok(out)
}

fn round_json(label: &str, r: &RoundReport) -> Json {
    let s = &r.summary;
    Json::obj(vec![
        ("round", label.into()),
        ("jobs", r.reports.len().into()),
        ("accepted", s.accepted.into()),
        ("dropped", s.dropped.into()),
        ("not_admitted", s.not_admitted.into()),
        ("replayed", s.replayed.into()),
        ("retried", (s.retries as usize).into()),
        ("reassigned", (s.reassigned as usize).into()),
        ("rejected_uploads", (s.rejected_uploads as usize).into()),
        ("panics", (s.panics as usize).into()),
        ("quorum_met", s.quorum_met.into()),
        ("wall_ms", s.wall_ms.into()),
    ])
}

/// Every job must end in exactly one terminal state; drained accepts must
/// carry a file + digest and keep no in-memory copy.
fn assert_accounted(label: &str, r: &RoundReport, n_jobs: usize) {
    assert_eq!(r.reports.len(), n_jobs, "{label}: one report per job");
    let s = &r.summary;
    assert_eq!(
        s.accepted + s.dropped + s.not_admitted,
        n_jobs,
        "{label}: every job terminally accounted for"
    );
    for rep in &r.reports {
        match rep.status {
            JobStatus::Accepted => {
                assert!(rep.admitted && rep.attempts >= 1 && rep.delta_bytes > 0);
                assert!(
                    rep.delta_path.is_some() && rep.delta_digest.is_some(),
                    "{label}: drained accept must record file + digest"
                );
                assert!(rep.delta.is_none(), "{label}: drain keeps no copy");
            }
            JobStatus::Dropped | JobStatus::NotAdmitted => {
                assert!(rep.delta.is_none() && rep.error.is_some());
            }
        }
    }
}

/// Truncate the journal right after the `keep`-th accept entry — the
/// mid-Train coordinator crash the resume path exists for.
fn truncate_after_accepts(path: &Path, keep: usize) -> Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let mut kept = Vec::new();
    let mut accepts = 0;
    for line in text.lines() {
        kept.push(line);
        if Json::parse(line)
            .ok()
            .and_then(|j| j.get("kind").and_then(|k| k.as_str().map(String::from)))
            .as_deref()
            == Some("accept")
        {
            accepts += 1;
            if accepts == keep {
                break;
            }
        }
    }
    std::fs::write(path, format!("{}\n", kept.join("\n")))?;
    Ok(accepts)
}

/// Spawn one [`participate`] thread per device. Participants run
/// `once: false`, so they survive round boundaries and coordinator kills
/// (reconnect loop) until a `shutdown` frame arrives.
fn spawn_fleet(
    addr: &str,
    fault_specs: &[(&str, &str)],
) -> Result<Vec<std::thread::JoinHandle<Result<ParticipantStats>>>> {
    let mut handles = Vec::new();
    for d in DEVICES {
        let spec = fault_specs
            .iter()
            .find(|(dev, _)| *dev == d)
            .map(|(_, s)| *s)
            .unwrap_or("");
        let faults = if spec.is_empty() {
            FaultPlan::default()
        } else {
            FaultPlan::parse(spec, SEED)?
        };
        let opts = ParticipantOpts {
            addr: addr.to_string(),
            device: d.to_string(),
            seed: SEED,
            backoff_ms: 5,
            max_reconnects: 500,
            once: false,
            heartbeat_ms: 0,
            faults,
        };
        handles.push(std::thread::spawn(move || {
            participate(&opts, |welcome, _backbone| {
                Ok(Box::new(SimRunner::new(welcome.seed)?) as Box<dyn JobRunner>)
            })
        }));
    }
    Ok(handles)
}

fn join_fleet(
    label: &str,
    handles: Vec<std::thread::JoinHandle<Result<ParticipantStats>>>,
) -> Result<Vec<ParticipantStats>> {
    let mut all = Vec::new();
    for h in handles {
        let stats = h
            .join()
            .map_err(|_| anyhow::anyhow!("{label}: participant panicked"))??;
        all.push(stats);
    }
    Ok(all)
}

fn net_state(
    wire_faults: &FaultPlan,
    generation: u64,
) -> std::sync::Arc<NetState> {
    NetState::new(NetConfig {
        config_name: "sim".to_string(),
        seed: SEED,
        heartbeat_timeout_ms: 2_500,
        faults: wire_faults.clone(),
        backbone: None,
        generation,
    })
}

/// Pick a free loopback port for the standby's advertised address before
/// anything listens on it.
fn reserve_addr() -> Result<String> {
    let l = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(l.local_addr()?.to_string())
}

/// Count journaled `accept` entries — what a promoted standby can replay.
fn count_accepts(path: &Path) -> Result<usize> {
    Ok(std::fs::read_to_string(path)?
        .lines()
        .filter(|line| {
            Json::parse(line)
                .ok()
                .and_then(|j| {
                    j.get("kind").and_then(|k| k.as_str().map(String::from))
                })
                .as_deref()
                == Some("accept")
        })
        .count())
}

fn main() -> Result<()> {
    let runner = SimRunner::new(SEED)?;
    let manifest = runner.manifest().clone();
    let jobs = jobs()?;
    let devices = devices()?;
    let n_jobs = jobs.len();
    let dir_sim = tmp_dir("sim");
    let dir_clean = tmp_dir("clean");
    let dir_net = tmp_dir("net");

    println!(
        "fleet net bench: {n_jobs} jobs x {} participants over loopback TCP, \
         wire faults [{WIRE_FAULTS}], engine faults [{ENGINE_FAULTS}]",
        devices.len()
    );

    // ---- round 1: in-process ground truth -------------------------------
    let sim_cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_sim.clone()),
        ..RoundConfig::default()
    };
    let sim = run_round(runner.manifest(), &devices, &jobs, &runner, &sim_cfg)?;
    assert_accounted("sim", &sim, n_jobs);
    assert_eq!(sim.summary.accepted, n_jobs, "sim round accepts everything");
    let sim_digests = digests(&sim);
    let sim_files = delta_files(&sim)?;
    println!(
        "sim   : {} accepted in {:.0} ms (in-process)",
        sim.summary.accepted, sim.summary.wall_ms
    );

    // ---- round 2: clean TCP round — must be bit-identical ---------------
    let clean = {
        let state = net_state(&FaultPlan::default(), 1);
        let mut server = FleetServer::start("127.0.0.1:0", state.clone())?;
        let fleet = spawn_fleet(&server.addr.to_string(), &[])?;
        server.await_participants(DEVICES.len(), Duration::from_secs(30))?;
        let net = NetRunner::new(state, manifest.clone())
            .with_timeouts(10_000, 30_000, 30_000);
        let cfg = RoundConfig {
            seed: SEED,
            delta_dir: Some(dir_clean.clone()),
            ..RoundConfig::default()
        };
        let report = run_round(&manifest, &devices, &jobs, &net, &cfg)?;
        server.shutdown();
        join_fleet("clean", fleet)?;
        report
    };
    assert_accounted("clean", &clean, n_jobs);
    assert_eq!(clean.summary.accepted, n_jobs, "clean TCP round accepts all");
    assert_eq!(
        digests(&clean),
        sim_digests,
        "TCP round must reproduce every in-process delta digest"
    );
    assert_eq!(
        delta_files(&clean)?,
        sim_files,
        "TCP-drained delta files must be byte-identical to in-process ones"
    );
    println!(
        "clean : {} accepted in {:.0} ms — digests and delta files \
         bit-identical to sim",
        clean.summary.accepted, clean.summary.wall_ms
    );

    // ---- rounds 3+4: chaos, then kill + restart on the same port --------
    let wire_faults = FaultPlan::parse(WIRE_FAULTS, SEED)?;
    let chaos_cfg = RoundConfig {
        seed: SEED,
        faults: FaultPlan::parse(ENGINE_FAULTS, SEED)?,
        delta_dir: Some(dir_net.clone()),
        job_timeout_ms: 2_000,
        max_attempts: 4,
        backoff_ms: 10,
        quorum: 0.5,
        ..RoundConfig::default()
    };
    let disconnect_spec = format!("disconnect={DISCONNECT_DEV}@train");
    let state = net_state(&wire_faults, 1);
    let mut server = FleetServer::start("127.0.0.1:0", state.clone())?;
    let addr = server.addr.to_string();
    let fleet =
        spawn_fleet(&addr, &[(DISCONNECT_DEV, disconnect_spec.as_str())])?;
    server.await_participants(DEVICES.len(), Duration::from_secs(30))?;
    let net = NetRunner::new(state, manifest.clone())
        .with_timeouts(10_000, 15_000, 4_000);
    let chaos = run_round(&manifest, &devices, &jobs, &net, &chaos_cfg)?;
    // crash, not shutdown: no `shutdown` frame, so every participant
    // treats it as a network failure and enters its reconnect loop
    server.kill();
    drop(server);
    drop(net);

    assert_accounted("chaos", &chaos, n_jobs);
    let hs = &chaos.summary;
    assert!(
        hs.quorum_met,
        "chaos round must reach quorum ({} accepted, {} required)",
        hs.accepted, hs.quorum_required
    );
    let chaos_digests = digests(&chaos);
    for (key, digest) in &chaos_digests {
        assert_eq!(
            Some(digest),
            sim_digests.get(key),
            "chaos-round delta for {key:?} must match the in-process digest \
             (corruption must never survive admission)"
        );
    }
    if !smoke() {
        assert!(
            hs.panics + hs.rejected_uploads + hs.retries >= 1,
            "the full-size fault storm must actually fire"
        );
    }
    println!(
        "chaos : {} accepted / {} dropped | {} retries, {} reassigned, {} \
         rejected uploads, {} panics | {:.0} ms",
        hs.accepted,
        hs.dropped,
        hs.retries,
        hs.reassigned,
        hs.rejected_uploads,
        hs.panics,
        hs.wall_ms
    );

    // truncate the journal mid-accepts and restart on the SAME port; the
    // surviving participants re-attach through their reconnect loops
    let keep = (hs.accepted / 2).max(1);
    let kept = truncate_after_accepts(&dir_net.join(JOURNAL_FILE), keep)?;
    let state2 = net_state(&FaultPlan::default(), 1);
    let mut server2 = FleetServer::start(&addr, state2.clone())
        .context("rebinding the coordinator port after the kill")?;
    server2.await_participants(DEVICES.len(), Duration::from_secs(30))?;
    let net2 = NetRunner::new(state2, manifest.clone())
        .with_timeouts(10_000, 30_000, 30_000);
    let resume_cfg = RoundConfig { resume: true, ..chaos_cfg.clone() };
    let resumed = run_round(&manifest, &devices, &jobs, &net2, &resume_cfg)?;
    server2.shutdown();
    let stats = join_fleet("resume", fleet)?;

    assert_accounted("resume", &resumed, n_jobs);
    let rs = &resumed.summary;
    assert_eq!(
        rs.replayed, kept,
        "every accept surviving the truncation must replay, not re-run"
    );
    assert_eq!(
        digests(&resumed),
        chaos_digests,
        "restarted coordinator must reproduce every delta digest bit-identically"
    );
    let total_reconnects: usize = stats.iter().map(|s| s.reconnects).sum();
    ensure!(
        total_reconnects >= DEVICES.len(),
        "every participant must have reconnected across the coordinator kill \
         (saw {total_reconnects} reconnects)"
    );
    println!(
        "resume: replayed {} of {} accepts after kill + same-port restart, \
         re-ran the rest to {} accepted | {} participant reconnects | {:.0} ms",
        rs.replayed, hs.accepted, rs.accepted, total_reconnects, rs.wall_ms
    );

    // ---- round 5: failover — ship the journal, kill, promote ------------
    let dir_ha = tmp_dir("ha");
    std::fs::create_dir_all(&dir_ha)?;
    let ha_state = net_state(&FaultPlan::parse(SHIP_FAULTS, SEED)?, 1);
    let mut primary = FleetServer::start("127.0.0.1:0", ha_state.clone())?;
    let primary_addr = primary.addr.to_string();
    let ha_fleet = spawn_fleet(&primary_addr, &[])?;
    primary.await_participants(DEVICES.len(), Duration::from_secs(30))?;

    let standby_addr = reserve_addr()?;
    let ship_journal = dir_ha.join("ship.journal");
    let sopts = StandbyOpts {
        primary: primary_addr.clone(),
        advertise: standby_addr.clone(),
        journal_path: ship_journal.clone(),
        lease_ms: 2_000,
        backoff_ms: 20,
        seed: SEED,
    };
    let standby = std::thread::spawn(move || stand_by(&sopts));
    // participants re-target the address the broadcast welcome announces,
    // so the attach must land before the primary dies
    let t0 = Instant::now();
    while ha_state.standby_addr().is_none() {
        ensure!(
            t0.elapsed() < Duration::from_secs(30),
            "standby never attached to the primary"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    let ha_net = NetRunner::new(ha_state.clone(), manifest.clone())
        .with_timeouts(10_000, 30_000, 30_000);
    let ha_cfg = RoundConfig {
        seed: SEED,
        delta_dir: Some(dir_ha.clone()),
        faults: FaultPlan::parse("killprimary@collect", SEED)?,
        shipper: Some(ha_state.journal_shipper()),
        ..RoundConfig::default()
    };
    let err = run_round(&manifest, &devices, &jobs, &ha_net, &ha_cfg)
        .expect_err("killprimary@collect must abort the primary's round");
    ensure!(
        format!("{err:#}").contains("primary coordinator killed"),
        "unexpected primary abort: {err:#}"
    );
    let killed_at = Instant::now();
    primary.kill();
    drop(primary);
    drop(ha_net);

    let sreport = standby
        .join()
        .map_err(|_| anyhow::anyhow!("standby thread panicked"))??;
    ensure!(sreport.promoted, "lease expiry must promote the standby");
    // every entry the primary shipped survives; `shipdrop` holes re-run
    let shipped_accepts = count_accepts(&ship_journal)?;
    install_shipped_journal(&ship_journal, &dir_ha)?;
    let promoted_state =
        net_state(&FaultPlan::default(), sreport.generation + 1);
    let mut promoted = FleetServer::start(&standby_addr, promoted_state.clone())
        .context("promoted standby binding its advertised address")?;
    let promotion_ms = killed_at.elapsed().as_secs_f64() * 1e3;
    promoted.await_participants(DEVICES.len(), Duration::from_secs(30))?;
    let promoted_net = NetRunner::new(promoted_state.clone(), manifest.clone())
        .with_timeouts(10_000, 30_000, 30_000);
    let ha_resume_cfg = RoundConfig {
        resume: true,
        faults: FaultPlan::default(),
        shipper: Some(promoted_state.journal_shipper()),
        ..ha_cfg.clone()
    };
    let failover =
        run_round(&manifest, &devices, &jobs, &promoted_net, &ha_resume_cfg)?;
    promoted.shutdown();
    let ha_stats = join_fleet("failover", ha_fleet)?;

    assert_accounted("failover", &failover, n_jobs);
    let fo = &failover.summary;
    ensure!(
        fo.replayed == shipped_accepts,
        "every shipped accept must replay on the promoted standby \
         (shipped {shipped_accepts}, replayed {})",
        fo.replayed
    );
    ensure!(
        fo.accepted == n_jobs,
        "the promoted round must finish every job ({} of {n_jobs})",
        fo.accepted
    );
    let failover_digests = digests(&failover);
    let lost_accepts = sim_digests
        .iter()
        .filter(|(key, digest)| failover_digests.get(*key) != Some(*digest))
        .count();
    ensure!(
        lost_accepts == 0,
        "failover must lose zero accepted uploads ({lost_accepts} deltas \
         missing or diverged)"
    );
    ensure!(
        delta_files(&failover)? == sim_files,
        "post-failover delta files must be byte-identical to in-process ones"
    );
    let ha_reconnects: usize = ha_stats.iter().map(|s| s.reconnects).sum();
    ensure!(
        ha_reconnects >= DEVICES.len(),
        "every participant must re-target the promoted standby \
         (saw {ha_reconnects} reconnects)"
    );
    println!(
        "failover: promoted generation {} in {promotion_ms:.0} ms, replayed \
         {} shipped accepts, re-ran {} shipdrop holes to {} accepted, 0 lost \
         | {} participant reconnects | {:.0} ms",
        sreport.generation + 1,
        fo.replayed,
        n_jobs - fo.replayed,
        fo.accepted,
        ha_reconnects,
        fo.wall_ms
    );

    // ---- report ---------------------------------------------------------
    let report = Json::obj(vec![
        ("bench", "fleet_net".into()),
        ("rounds", 5.into()),
        ("jobs", n_jobs.into()),
        ("participants", DEVICES.len().into()),
        ("wire_faults", WIRE_FAULTS.into()),
        ("engine_faults", ENGINE_FAULTS.into()),
        ("ship_faults", SHIP_FAULTS.into()),
        // headline fields, kept flat for the CI smoke job's assertions
        ("bit_identical", true.into()),
        ("accepted", hs.accepted.into()),
        ("dropped", hs.dropped.into()),
        ("retried", (hs.retries as usize).into()),
        ("rejected_uploads", (hs.rejected_uploads as usize).into()),
        ("panics", (hs.panics as usize).into()),
        ("quorum_met", hs.quorum_met.into()),
        ("replayed", rs.replayed.into()),
        ("reconnects", total_reconnects.into()),
        // failover headline fields, flat for the ha-smoke job's assertions
        ("failover_promotion_ms", promotion_ms.into()),
        ("failover_replayed", fo.replayed.into()),
        ("failover_lost_accepts", lost_accepts.into()),
        ("failover_bit_identical", true.into()),
        ("failover_reconnects", ha_reconnects.into()),
        ("failover_generation", ((sreport.generation + 1) as usize).into()),
        ("sim", round_json("sim", &sim)),
        ("clean", round_json("clean", &clean)),
        ("chaos", round_json("chaos", &chaos)),
        ("resume", round_json("resume", &resumed)),
        ("failover", round_json("failover", &failover)),
    ]);
    std::fs::write("BENCH_fleet_net.json", format!("{report}\n"))?;
    println!("wrote BENCH_fleet_net.json");
    for d in [&dir_sim, &dir_clean, &dir_net, &dir_ha] {
        let _ = std::fs::remove_dir_all(d);
    }
    Ok(())
}
