//! Ablation A (paper §III-C discussion): per-neuron top-K vs global
//! top-fraction allocation.
//!
//! Shows (a) the depth distribution of trainable parameters — global
//! selection concentrates them in a few tensors, per-neuron spreads them
//! evenly — and (b) the resulting accuracy difference on a structured task
//! (where shallow-layer adaptation matters most).

use taskedge::coordinator::TrainConfig;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::masking::Mask;
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn depth_distribution(masks: &std::collections::BTreeMap<String, Mask>) -> Vec<(String, f64, f64)> {
    // (tensor, share of trainable budget, within-tensor density), head excluded
    let total: usize = masks
        .iter()
        .filter(|(k, _)| !k.starts_with("head."))
        .map(|(_, m)| m.count_ones())
        .sum();
    masks
        .iter()
        .filter(|(k, m)| !k.starts_with("head.") && m.shape.len() == 2
                && m.count_ones() + 1 > 0)
        .map(|(k, m)| {
            (
                k.clone(),
                m.count_ones() as f64 / total.max(1) as f64,
                m.density(),
            )
        })
        .collect()
}

fn gini(shares: &[f64]) -> f64 {
    // inequality of the budget across tensors: 0 = even, ->1 = concentrated
    let n = shares.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mut s: Vec<f64> = shares.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sum: f64 = s.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let mut cum = 0.0;
    let mut b = 0.0;
    for v in &s {
        cum += v;
        b += cum;
    }
    1.0 + 1.0 / n - 2.0 * b / (n * sum)
}

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };

    // Budget-match: global frac chosen to select ~the same count as k=2.
    let cfg = exp.rt.manifest().config(&exp.config)?;
    let per_neuron_budget: usize = cfg
        .masked_params()
        .filter(|p| p.name != "head.w")
        .map(|p| p.shape[1] * 2.min(p.shape[0]))
        .sum();
    let backbone_total: usize = cfg
        .masked_params()
        .filter(|p| p.name != "head.w")
        .map(|p| p.numel())
        .sum();
    let frac = per_neuron_budget as f64 / backbone_total as f64;

    let mut table = Table::new(
        "Ablation A: allocation strategy (budget-matched)",
        &["allocation", "task", "top1", "gini(depth)", "max tensor share"],
    );
    for task in ["dsprites/ori", "caltech101"] {
        for (label, strategy) in [
            ("per-neuron k=2 (TaskEdge)", Strategy::TaskEdge { k: 2 }),
            ("global top-frac (ablated)", Strategy::GlobalTaskAware { frac }),
        ] {
            let res = exp.run_task(task, strategy, tcfg.clone(),
                                   scale.n_train, scale.n_eval)?;
            let dist = depth_distribution(&res.masks);
            let shares: Vec<f64> = dist.iter().map(|(_, s, _)| *s).collect();
            let max_share = shares.iter().cloned().fold(0.0, f64::max);
            table.row(vec![
                label.to_string(),
                task.to_string(),
                format!("{:.3}", res.record.best_top1()),
                format!("{:.3}", gini(&shares)),
                format!("{:.3}", max_share),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper claim: global selection concentrates the budget (high gini, \
         one tensor dominating) and underperforms on tasks needing \
         shallow-layer adaptation; per-neuron keeps gini ~0 by construction."
    );
    Ok(())
}
