//! Memory-footprint reproduction of the paper's §I motivation: "pre-training
//! LLaMA-7B consumes 58 GB — 14 GB weights + 42 GB Adam states & gradients
//! + 2 GB activations", and how TaskEdge's trainable-fraction scaling
//! changes the picture on real device budgets.
//!
//! Two parts:
//! 1. The paper's LLaMA-7B arithmetic reproduced exactly from the model
//!    (weights + dense grads + 2 Adam moments, f32/bf16 mix as cited).
//! 2. The per-strategy footprint of our ViT configs against the edge
//!    device profiles, with admission verdicts.

use taskedge::edge::{admit, DEVICE_PROFILES};
use taskedge::harness::Experiment;
use taskedge::peft::{accounting, MemoryFootprint, Strategy};
use taskedge::runtime::Runtime;
use taskedge::util::bench::Table;
use taskedge::util::rng::Rng;
use taskedge::vit::{ParamStore, TaskDelta};

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

fn main() -> anyhow::Result<()> {
    // ---- Part 1: the paper's LLaMA-7B numbers -----------------------------
    let p7b = 7e9;
    let weights = 2.0 * p7b; // bf16 weights = 14 GB
    let adam_and_grads = 3.0 * 2.0 * p7b; // grads + m + v in bf16 = 42 GB
    let activations = 2e9; // the paper's 2 GB figure at batch 1
    let mut t = Table::new(
        "Paper §I: LLaMA-7B full fine-tuning memory (reproduced arithmetic)",
        &["component", "GB", "scales with"],
    );
    t.row(vec!["weights (bf16)".into(), format!("{:.0}", weights / GB),
               "total params".into()]);
    t.row(vec!["grads + Adam m,v".into(), format!("{:.0}", adam_and_grads / GB),
               "TRAINABLE params".into()]);
    t.row(vec!["activations".into(), format!("{:.0}", activations / GB),
               "batch x depth".into()]);
    t.row(vec!["total".into(),
               format!("{:.0}", (weights + adam_and_grads + activations) / GB),
               "".into()]);
    t.print();

    // TaskEdge at 0.1% trainable on the same model:
    let trainable = 0.001 * p7b;
    let sparse_state = 3.0 * 2.0 * trainable;
    println!(
        "\nTaskEdge @0.1% trainable: grads+Adam shrink {:.0} GB -> {:.2} GB \
         (total {:.1} GB -> fits a 24 GB RTX 4090, the paper's motivating \
         device)\n",
        adam_and_grads / GB,
        sparse_state / GB,
        (weights + sparse_state + activations) / GB
    );

    // ---- Part 2: our configs x strategies x devices -----------------------
    let artifacts = Experiment::default_artifacts();
    let rt = Runtime::load(&artifacts)?;
    let batch = rt.manifest().batch;
    let strategies = [
        Strategy::Full,
        Strategy::TaskEdge { k: 2 },
        Strategy::TaskEdgeNM { n: 2, m: 4 },
        Strategy::Lora,
        Strategy::Linear,
        Strategy::BitFit,
    ];
    for (cname, _cfg) in rt.manifest().configs.iter() {
        let cfg = rt.manifest().config(cname)?;
        let mut t = Table::new(
            &format!("{cname} footprint (batch {batch}) + admission"),
            &{
                let mut h = vec!["strategy", "trainable", "opt state KB",
                                 "total KB (sparse)"];
                h.extend(DEVICE_PROFILES.iter().map(|p| p.name));
                h
            },
        );
        for s in &strategies {
            let trainable = accounting::estimate_trainable(s, cfg);
            let fp = MemoryFootprint::compute(cfg, trainable, batch);
            let mut row = vec![
                s.name(),
                trainable.to_string(),
                format!("{:.1}", fp.optimizer_bytes as f64 / 1024.0),
                format!("{:.1}", fp.total_sparse() as f64 / 1024.0),
            ];
            for prof in DEVICE_PROFILES {
                row.push(if admit(prof, &fp).fits { "fit".into() }
                         else { "OOM".into() });
            }
            t.row(row);
        }
        t.print();
        println!();
    }
    println!(
        "shape check: optimizer state scales with the trainable count — \
         TaskEdge rows should be orders of magnitude below Full, matching \
         the paper's edge-memory argument."
    );

    // ---- Part 3: per-task CHECKPOINT bytes — delta vs full store ----------
    // What a device uploads / a server stores per fine-tuned task: the full
    // ParamStore (pre-TaskDelta behavior) vs the sparse delta. Estimates
    // are analytic (accounting::estimate_delta_bytes); the `measured`
    // column extracts a real delta through TaskDelta::extract for the
    // strategies whose masks need no calibration data.
    for (cname, _cfg) in rt.manifest().configs.iter() {
        let cfg = rt.manifest().config(cname)?;
        let full = accounting::store_checkpoint_bytes(cfg);
        let mut t = Table::new(
            &format!(
                "{cname} per-task checkpoint: TaskDelta vs full store \
                 ({:.1} KB full)",
                full as f64 / 1024.0
            ),
            &["strategy", "est KB", "est % of full", "measured KB",
              "measured %"],
        );
        for s in &strategies {
            let est = accounting::estimate_delta_bytes(s, cfg);
            // ground truth where masks are buildable offline: perturb a
            // store on-mask, extract, and take the exact serialized size
            let measured = match s {
                Strategy::Full | Strategy::Linear | Strategy::BitFit => {
                    Some(measure_delta_bytes(cfg, s)?)
                }
                // magnitude masks are the same shape as taskedge's (per-
                // neuron top-k) without needing activation statistics
                Strategy::TaskEdge { k } => {
                    Some(measure_delta_bytes(cfg, &Strategy::Magnitude { k: *k })?)
                }
                _ => None,
            };
            t.row(vec![
                s.name(),
                format!("{:.1}", est as f64 / 1024.0),
                format!("{:.2}", est as f64 / full as f64 * 100.0),
                measured
                    .map(|m| format!("{:.1}", m as f64 / 1024.0))
                    .unwrap_or_else(|| "-".into()),
                measured
                    .map(|m| format!("{:.2}", m as f64 / full as f64 * 100.0))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "shape check: delta checkpoints scale with TRAINABLE parameters \
         (8 bytes per sparse coordinate + the dense fresh head), while the \
         full store scales with ALL parameters — the ~1000x shipping-size \
         gap the TaskDelta subsystem exists for appears at real layer \
         widths (see tests/prop_delta.rs for the d_in=4096 bound)."
    );
    Ok(())
}

/// Build masks for `strategy` (no calibration required), perturb a store
/// on-mask, extract the TaskDelta, and return its exact serialized size.
fn measure_delta_bytes(
    cfg: &taskedge::runtime::ModelConfig,
    strategy: &Strategy,
) -> anyhow::Result<usize> {
    let mut rng = Rng::new(0x5e1f);
    let backbone = ParamStore::init(cfg, &mut rng);
    let masks = strategy.build_masks(cfg, &backbone, None, None, &mut rng)?;
    let mut tuned = backbone.clone();
    for (name, mask) in &masks {
        if mask.count_ones() == 0 {
            continue;
        }
        let mut t = tuned.get(name)?.clone();
        let d = t.f32s_mut()?;
        for (i, &m) in mask.data.iter().enumerate() {
            if m == 1.0 {
                d[i] += 0.5;
            }
        }
        tuned.set(name, t)?;
    }
    let delta = TaskDelta::extract(&backbone, &tuned, &masks)?;
    Ok(delta.file_bytes())
}
