//! Ablation C (paper §III-D / Eq. 6): plain LoRA vs sparse-LoRA.
//!
//! Same low-rank factors, same train graph — the only difference is the
//! mask gating ΔW. The paper's claim: the sparse constraint regularizes
//! low-rank adaptation in the 1k-example regime at no extra parameter cost.

use taskedge::coordinator::TrainConfig;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 5e-3, seed: 42,
                             ..Default::default() };

    let mut table = Table::new(
        "Ablation C: LoRA vs sparse-LoRA (Eq. 6)",
        &["task", "strategy", "top1", "top5", "trainable", "delta support %"],
    );
    for task in ["caltech101", "eurosat"] {
        for strategy in [Strategy::Lora, Strategy::SparseLora { k: 4 },
                         Strategy::SparseLora { k: 16 }] {
            let res = exp.run_task(task, strategy.clone(), tcfg.clone(),
                                   scale.n_train, scale.n_eval)?;
            let total: usize = res.masks.values().map(|m| m.numel()).sum();
            let ones: usize = res.masks.values().map(|m| m.count_ones()).sum();
            table.row(vec![
                task.to_string(),
                strategy.name(),
                format!("{:.3}", res.record.best_top1()),
                format!("{:.3}", res.record.best_top5()),
                res.trainable_params.to_string(),
                format!("{:.2}", 100.0 * ones as f64 / total.max(1) as f64),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper claim: Eq. 6 masking is plug-and-play — identical factor \
         count, constrained update support, competitive or better accuracy \
         on small task data."
    );
    Ok(())
}
