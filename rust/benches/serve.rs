//! Serving-engine bench: N concurrent submitters driving the multi-task
//! router, measuring end-to-end throughput plus queue/execute latency
//! percentiles per task and aggregated — the event-driven replacement for
//! the seed's sleep-polling batcher (ISSUE 1 tentpole). While the load
//! runs, the bench live-swaps one server's fine-tuned parameter set
//! (`Server::swap_delta`) and reports swap latency plus proof that every
//! in-flight request survived (ISSUE 2 hot-swap item).
//!
//!   cargo bench --bench serve
//!
//! Scale knobs: TASKEDGE_FULL=1 quadruples the request volume.

use std::sync::Arc;
use std::time::{Duration, Instant};

use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::{full_scale, Experiment};
use taskedge::metrics::fmt_duration;
use taskedge::runtime::Runtime;
use taskedge::serve::{Router, Server, ServerConfig, ServerStats};
use taskedge::util::bench::Table;
use taskedge::util::rng::Rng;
use taskedge::vit::{ParamStore, TaskDelta};

const TASKS: [&str; 2] = ["pets", "dtd"];

fn stats_row(label: &str, st: &ServerStats) -> Vec<String> {
    let pct = |h: &taskedge::metrics::Histogram, q: f64| fmt_duration(h.quantile(q));
    vec![
        label.to_string(),
        st.requests.to_string(),
        st.batches.to_string(),
        st.padded_rows.to_string(),
        st.rejected.to_string(),
        pct(&st.queue, 0.50),
        pct(&st.queue, 0.95),
        pct(&st.queue, 0.99),
        pct(&st.execute, 0.50),
        pct(&st.execute, 0.95),
        pct(&st.execute, 0.99),
    ]
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(&Experiment::default_artifacts())?);
    let config = "micro";
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;

    let submitters = 8usize;
    let per_submitter = if full_scale() { 64 * batch } else { 16 * batch };
    let total_requests = submitters * per_submitter;

    // One server per task: same compiled graph, per-task "adapted" weights.
    let mut router = Router::new();
    let mut base_params: Vec<Arc<ParamStore>> = Vec::new();
    for (i, task) in TASKS.iter().enumerate() {
        let params = Arc::new(ParamStore::init(&cfg, &mut Rng::new(7 + i as u64)));
        base_params.push(params.clone());
        let server = Arc::new(Server::new(
            rt.clone(),
            config,
            params,
            ServerConfig {
                linger: Duration::from_millis(2),
                workers: 2,
                // sized so the bench never sheds: every submitter may have
                // its full window outstanding at once
                max_queue: total_requests,
            },
        )?);
        router.register(task, server);
    }
    let router = Arc::new(router);

    // Hot-swap payloads: successive fine-tuned variants of task 0 (distinct
    // head biases), each a sparse TaskDelta over that server's backbone.
    let swap_deltas: Arc<Vec<TaskDelta>> = Arc::new(
        (0..4u32)
            .map(|v| {
                let mut tuned = (*base_params[0]).clone();
                let mut hb = tuned.get("head.b").unwrap().clone();
                for (j, x) in hb.f32s_mut().unwrap().iter_mut().enumerate() {
                    *x += (v as f32 + 1.0) * 0.01 * (j as f32 + 1.0);
                }
                tuned.set("head.b", hb).unwrap();
                TaskDelta::diff(&base_params[0], &tuned).unwrap()
            })
            .collect(),
    );

    // Per-task request pools (single images as flat f32 rows), shared with
    // every submitter thread.
    let mut pools: Vec<Vec<Vec<f32>>> = Vec::new();
    for task in TASKS {
        let spec = task_by_name(task)?;
        let (_, pool) = generate_task(spec, cfg.image_size, 1, 2 * batch, 99)?;
        let isz = pool.image_numel();
        pools.push(
            (0..pool.n)
                .map(|i| pool.images[i * isz..(i + 1) * isz].to_vec())
                .collect(),
        );
    }
    let pools = Arc::new(pools);

    println!(
        "serve bench: {submitters} submitters x {per_submitter} requests \
         over {} tasks (batch {batch})",
        TASKS.len()
    );

    let (wall, client_lat, swap_lats) =
        std::thread::scope(|scope| -> anyhow::Result<_> {
        for task in TASKS {
            let server = router.server(task).unwrap().clone();
            scope.spawn(move || server.run().unwrap());
        }

        // run the load inside a closure so the servers are always shut down
        // before the scope joins their run threads — even on error
        let drive = || -> anyhow::Result<(
            Duration,
            taskedge::metrics::Histogram,
            Vec<Duration>,
        )> {
            // warm the executable cache so timing excludes the XLA compile
            for (t, task) in TASKS.iter().enumerate() {
                let rx = router.submit(task, pools[t][0].clone())?;
                rx.recv_timeout(Duration::from_secs(120))?;
            }

            let t0 = Instant::now();
            let mut handles = Vec::new();
            for s in 0..submitters {
                let router = router.clone();
                let pools = pools.clone();
                handles.push(scope.spawn(move || -> anyhow::Result<Vec<Duration>> {
                    let mut rxs = Vec::with_capacity(per_submitter);
                    for r in 0..per_submitter {
                        // round-robin tasks: both servers see interleaved load
                        let t = (s + r) % TASKS.len();
                        let img =
                            pools[t][(s * per_submitter + r) % pools[t].len()].clone();
                        rxs.push(router.submit(TASKS[t], img)?);
                    }
                    let mut lats = Vec::with_capacity(per_submitter);
                    for rx in rxs {
                        let resp = rx.recv_timeout(Duration::from_secs(300))?;
                        lats.push(resp.latency);
                    }
                    Ok(lats)
                }));
            }
            // while the load is in flight: live-swap task 0's parameter set
            // repeatedly; every already-queued request must still complete
            let swap_server = router.server(TASKS[0]).unwrap().clone();
            let deltas = swap_deltas.clone();
            let swapper = scope.spawn(move || -> anyhow::Result<Vec<Duration>> {
                let mut lats = Vec::new();
                for d in deltas.iter() {
                    std::thread::sleep(Duration::from_millis(15));
                    let s0 = Instant::now();
                    swap_server.swap_delta(d)?;
                    lats.push(s0.elapsed());
                }
                Ok(lats)
            });
            let mut client_lat = taskedge::metrics::Histogram::new();
            for h in handles {
                for lat in h.join().unwrap()? {
                    client_lat.record(lat);
                }
            }
            let swap_lats = swapper.join().unwrap()?;
            Ok((t0.elapsed(), client_lat, swap_lats))
        };
        let result = drive();
        router.shutdown();
        result
    })?;

    let stats = router.stats();
    let mut table = Table::new(
        "serving engine (event-driven batching)",
        &["task", "reqs", "batches", "padded", "rejected",
          "queue p50", "p95", "p99", "exec p50", "p95", "p99"],
    );
    for (task, st) in &stats.per_task {
        table.row(stats_row(task, st));
    }
    table.row(stats_row("TOTAL", &stats.total));
    table.print();

    let secs = wall.as_secs_f64();
    println!("\nwall time          : {:.2} s", secs);
    println!(
        "throughput         : {:.0} img/s ({} requests, {} submitters)",
        total_requests as f64 / secs,
        total_requests,
        submitters
    );
    println!("e2e latency        : {}", client_lat.summary());
    println!("queue latency      : {}", stats.total.queue.summary());
    println!("execute latency    : {}", stats.total.execute.summary());
    println!(
        "padding overhead   : {:.1}% of computed rows",
        100.0 * stats.total.padded_rows as f64
            / (stats.total.batches * batch).max(1) as f64
    );

    // hot-swap report: every client recv above succeeded, so completing
    // this bench at all proves no request was dropped across the swaps
    let answered: usize = client_lat.count() as usize;
    assert_eq!(
        stats.total.swaps,
        swap_lats.len(),
        "server stats must count every swap"
    );
    assert_eq!(
        answered, total_requests,
        "in-flight requests must survive hot swaps"
    );
    let mean_swap = swap_lats.iter().sum::<Duration>()
        / swap_lats.len().max(1) as u32;
    let max_swap = swap_lats.iter().max().copied().unwrap_or_default();
    println!(
        "hot-swap           : {} live swaps on task {:?}, mean {} max {} \
         (apply backbone+delta, atomic at batch boundary); {} / {} \
         requests answered, 0 dropped",
        swap_lats.len(),
        TASKS[0],
        fmt_duration(mean_swap),
        fmt_duration(max_swap),
        answered,
        total_requests
    );
    Ok(())
}
