//! Mixed multi-task serving bench: the per-task-server baseline (one
//! isolated worker pool per task — PR 1/2 architecture) vs the shared
//! **DeviceExecutor** (one work-conserving pool + deficit-weighted
//! round-robin + cached parameter literals) under the *same* skewed load
//! on the *same* total worker count.
//!
//! Load shape: two flood tasks drive closed-loop (a fixed window of
//! outstanding requests, so they saturate the device at any machine
//! speed) while a trickle task submits paced single requests — the
//! pattern that makes per-task pools burn compute on padded replica rows.
//! Reported per scenario: throughput, padded-row ratio, queue/execute
//! percentiles. The shared scenario also live-swaps one task's fine-tuned
//! delta mid-load (no request may drop) and checks `RuntimeStats` proves
//! parameter-tensor → literal conversions happen only at build time and
//! per swap — never per batch. Results land in `BENCH_serve.json`.
//!
//!   cargo bench --bench serve
//!
//! Scale knobs: TASKEDGE_FULL=1 quadruples the request volume.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::{full_scale, Experiment};
use taskedge::metrics::{fmt_bytes, fmt_duration, Histogram};
use taskedge::runtime::Runtime;
use taskedge::serve::{
    DeviceBuilder, DeviceConfig, Response, Server, ServerConfig, ServerStats,
    TaskConfig,
};
use taskedge::util::bench::Table;
use taskedge::util::json::Json;
use taskedge::util::rng::Rng;
use taskedge::vit::{ParamStore, TaskDelta};

/// (task, weight share): pets floods, flowers trickles — weights follow
/// the offered skew so each task's padded flushes are rationed to its
/// share of device compute.
const TASKS: [(&str, usize); 3] = [("pets", 8), ("dtd", 3), ("flowers102", 1)];

/// Total device workers, identical in both scenarios (baseline splits
/// them one per task; the shared executor pools them).
const WORKERS: usize = 3;

const RECV_TIMEOUT: Duration = Duration::from_secs(300);

/// How a task's submitter drives load.
#[derive(Clone, Copy)]
enum LoadMode {
    /// keep `window` requests outstanding (self-pacing flood: saturates
    /// its share of the device at any execution speed)
    Closed { window: usize },
    /// one request per `interval` (open-loop trickle: produces the
    /// partial batches whose padding this PR reclaims)
    Paced { interval: Duration },
}

fn stats_row(label: &str, st: &ServerStats) -> Vec<String> {
    let pct = |h: &Histogram, q: f64| fmt_duration(h.quantile(q));
    vec![
        label.to_string(),
        st.requests.to_string(),
        st.batches.to_string(),
        st.padded_rows.to_string(),
        st.rejected.to_string(),
        pct(&st.queue, 0.50),
        pct(&st.queue, 0.95),
        pct(&st.queue, 0.99),
        pct(&st.execute, 0.50),
        pct(&st.execute, 0.95),
        pct(&st.execute, 0.99),
    ]
}

struct LoadResult {
    wall: Duration,
    e2e: Histogram,
}

/// Architecture-abstracted submit: `(task index, image) -> receiver`.
type SubmitFn<'a> =
    &'a (dyn Fn(usize, Vec<f32>) -> anyhow::Result<mpsc::Receiver<Response>> + Sync);

/// Drive the skewed load: one submitter thread per task, then await every
/// response. `submit` abstracts over the two architectures.
fn drive_load(
    submit: SubmitFn<'_>,
    pools: &[Vec<Vec<f32>>],
    counts: &[usize],
    modes: &[LoadMode],
) -> anyhow::Result<LoadResult> {
    let t0 = Instant::now();
    let e2e = std::thread::scope(|scope| -> anyhow::Result<Histogram> {
        let mut handles = Vec::new();
        for (t, pool) in pools.iter().enumerate() {
            let mode = modes[t];
            let count = counts[t];
            handles.push(scope.spawn(move || -> anyhow::Result<Histogram> {
                let start = Instant::now();
                let mut h = Histogram::new();
                let mut pending = std::collections::VecDeque::new();
                for i in 0..count {
                    match mode {
                        LoadMode::Closed { window } => {
                            if pending.len() >= window {
                                let rx: mpsc::Receiver<Response> =
                                    pending.pop_front().unwrap();
                                h.record(rx.recv_timeout(RECV_TIMEOUT)?.latency);
                            }
                        }
                        LoadMode::Paced { interval } => {
                            let target = start + interval * i as u32;
                            let now = Instant::now();
                            if target > now {
                                std::thread::sleep(target - now);
                            }
                        }
                    }
                    pending.push_back(submit(t, pool[i % pool.len()].clone())?);
                }
                for rx in pending {
                    h.record(rx.recv_timeout(RECV_TIMEOUT)?.latency);
                }
                Ok(h)
            }));
        }
        let mut e2e = Histogram::new();
        for h in handles {
            e2e.merge(&h.join().unwrap()?);
        }
        Ok(e2e)
    })?;
    Ok(LoadResult { wall: t0.elapsed(), e2e })
}

fn padded_ratio(total: &ServerStats, batch: usize) -> f64 {
    total.padded_rows as f64 / ((total.batches * batch).max(1)) as f64
}

fn scenario_json(
    total: &ServerStats,
    batch: usize,
    res: &LoadResult,
    n_requests: usize,
) -> Json {
    let secs = res.wall.as_secs_f64();
    Json::obj(vec![
        ("requests", n_requests.into()),
        ("batches", total.batches.into()),
        ("padded_rows", total.padded_rows.into()),
        ("padded_row_ratio", padded_ratio(total, batch).into()),
        ("rejected", total.rejected.into()),
        ("wall_s", secs.into()),
        ("throughput_img_s", (n_requests as f64 / secs).into()),
        ("e2e_p99_ns", (res.e2e.quantile(0.99).as_nanos() as f64).into()),
        ("queue_p99_ns", (total.queue.quantile(0.99).as_nanos() as f64).into()),
    ])
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(&Experiment::default_artifacts())?);
    let config = "micro";
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;
    let scale = if full_scale() { 4 } else { 1 };

    // Per-task request pools (single images as flat f32 rows) and per-task
    // "adapted" parameter sets (same compiled graph, different weights).
    let mut pools: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut params: Vec<Arc<ParamStore>> = Vec::new();
    for (i, (task, _)) in TASKS.iter().enumerate() {
        let spec = task_by_name(task)?;
        let (_, pool) = generate_task(spec, cfg.image_size, 1, 2 * batch, 99)?;
        let isz = pool.image_numel();
        pools.push(
            (0..pool.n)
                .map(|i| pool.images[i * isz..(i + 1) * isz].to_vec())
                .collect(),
        );
        params.push(Arc::new(ParamStore::init(&cfg, &mut Rng::new(7 + i as u64))));
    }

    // Hot-swap payloads for task 0: successive fine-tuned variants
    // (distinct head biases), each a sparse TaskDelta over its backbone.
    let swap_deltas: Vec<TaskDelta> = (0..4u32)
        .map(|v| {
            let mut tuned = (*params[0]).clone();
            let mut hb = tuned.get("head.b").unwrap().clone();
            for (j, x) in hb.f32s_mut().unwrap().iter_mut().enumerate() {
                *x += (v as f32 + 1.0) * 0.01 * (j as f32 + 1.0);
            }
            tuned.set("head.b", hb).unwrap();
            TaskDelta::diff(&params[0], &tuned).unwrap()
        })
        .collect();

    // ---- calibrate: one throwaway server measures batch execute time ----
    // so the trickle pacing and linger stay proportional to real device
    // speed (the work-conservation comparison then holds on fast and slow
    // machines alike).
    let exec_mean = {
        let server = Arc::new(Server::new(
            rt.clone(),
            config,
            params[0].clone(),
            ServerConfig {
                linger: Duration::from_millis(1),
                workers: 1,
                max_queue: 8 * batch,
            },
        )?);
        std::thread::scope(|scope| -> anyhow::Result<Duration> {
            let srv = server.clone();
            let h = scope.spawn(move || srv.run());
            let mut rxs = Vec::new();
            for i in 0..4 * batch {
                rxs.push(server.submit(pools[0][i % pools[0].len()].clone())?);
            }
            for rx in rxs {
                rx.recv_timeout(RECV_TIMEOUT)?;
            }
            server.shutdown();
            h.join().unwrap()?;
            Ok(server.stats().execute.mean())
        })?
    };
    let exec_mean =
        exec_mean.clamp(Duration::from_micros(20), Duration::from_millis(50));
    // the trickle's linger stays below one execute, so its flush cadence
    // is worker-availability-bound, not deadline-bound, under contention
    let linger = (exec_mean / 2)
        .clamp(Duration::from_micros(50), Duration::from_millis(2));
    let trickle_interval = linger / 3;

    let counts: Vec<usize> =
        TASKS.iter().map(|(_, share)| share * 128 * scale).collect();
    let modes = [
        LoadMode::Closed { window: 6 * batch },
        LoadMode::Closed { window: 2 * batch },
        LoadMode::Paced { interval: trickle_interval },
    ];
    let n_requests: usize = counts.iter().sum();
    println!(
        "serve bench: {n_requests} requests over {} tasks (batch {batch}, \
         {WORKERS} workers, exec ~{}, linger {}, weights {:?})",
        TASKS.len(),
        fmt_duration(exec_mean),
        fmt_duration(linger),
        TASKS.map(|(_, s)| s),
    );

    // ---- scenario A: per-task servers (isolated pools; the baseline) ----
    let baseline_servers: Vec<Arc<Server>> = (0..TASKS.len())
        .map(|t| {
            Ok(Arc::new(Server::new(
                rt.clone(),
                config,
                params[t].clone(),
                ServerConfig {
                    linger,
                    workers: (WORKERS / TASKS.len()).max(1),
                    max_queue: counts[t] + 1,
                },
            )?))
        })
        .collect::<anyhow::Result<_>>()?;
    let (baseline_res, baseline_stats) =
        std::thread::scope(|scope| -> anyhow::Result<_> {
            for server in &baseline_servers {
                let srv = server.clone();
                scope.spawn(move || srv.run().unwrap());
            }
            let drive = || -> anyhow::Result<LoadResult> {
                // warm each server before timing
                for (t, server) in baseline_servers.iter().enumerate() {
                    server
                        .submit(pools[t][0].clone())?
                        .recv_timeout(RECV_TIMEOUT)?;
                }
                drive_load(
                    &|t, img| baseline_servers[t].submit(img),
                    &pools,
                    &counts,
                    &modes,
                )
            };
            let result = drive();
            for server in &baseline_servers {
                server.shutdown();
            }
            let mut total = ServerStats::default();
            for server in &baseline_servers {
                total.merge(&server.stats());
            }
            Ok((result?, total))
        })?;

    // ---- scenario B: shared DeviceExecutor (this PR) ----
    let mut builder = DeviceBuilder::new(
        rt.clone(),
        config,
        DeviceConfig { linger, workers: WORKERS, max_queue: n_requests },
    );
    for (t, (task, share)) in TASKS.iter().enumerate() {
        builder.add_task(
            task,
            params[t].clone(),
            TaskConfig { weight: *share as f64, max_queue: Some(counts[t] + 1) },
        )?;
    }
    let router = builder.build()?;
    // conversions after this point may come only from swap_delta
    let rs_before_load = rt.stats();
    let (shared_res, swap_lats) = std::thread::scope(|scope| -> anyhow::Result<_> {
        let runner = scope.spawn(|| router.run());
        let drive = || -> anyhow::Result<(LoadResult, Vec<Duration>)> {
            for (t, (task, _)) in TASKS.iter().enumerate() {
                router
                    .submit(task, pools[t][0].clone())?
                    .recv_timeout(RECV_TIMEOUT)?;
            }
            // live swaps while the load is in flight: every already-queued
            // request must still complete
            let swapper = scope.spawn(|| -> anyhow::Result<Vec<Duration>> {
                let mut lats = Vec::new();
                for d in &swap_deltas {
                    std::thread::sleep(Duration::from_millis(15));
                    let s0 = Instant::now();
                    router.swap_delta(TASKS[0].0, d)?;
                    lats.push(s0.elapsed());
                }
                Ok(lats)
            });
            let res = drive_load(
                &|t, img| router.submit(TASKS[t].0, img),
                &pools,
                &counts,
                &modes,
            )?;
            Ok((res, swapper.join().unwrap()?))
        };
        let result = drive();
        router.shutdown();
        runner
            .join()
            .map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
        result
    })?;
    let rs_after_load = rt.stats();
    let shared_stats = router.stats();

    // ---- report ----
    {
        let mut table = Table::new(
            "per-task servers (baseline)",
            &["task", "reqs", "batches", "padded", "rejected",
              "queue p50", "p95", "p99", "exec p50", "p95", "p99"],
        );
        for (t, (task, _)) in TASKS.iter().enumerate() {
            table.row(stats_row(task, &baseline_servers[t].stats()));
        }
        table.row(stats_row("TOTAL", &baseline_stats));
        table.print();
        let secs = baseline_res.wall.as_secs_f64();
        println!(
            "  wall {:.2}s | {:.0} img/s | padded rows {:.1}% | e2e {}\n",
            secs,
            n_requests as f64 / secs,
            100.0 * padded_ratio(&baseline_stats, batch),
            baseline_res.e2e.summary()
        );
    }
    {
        let mut table = Table::new(
            "shared DeviceExecutor",
            &["task", "reqs", "batches", "padded", "rejected",
              "queue p50", "p95", "p99", "exec p50", "p95", "p99"],
        );
        for (task, st) in &shared_stats.per_task {
            table.row(stats_row(task, st));
        }
        table.row(stats_row("TOTAL", &shared_stats.total));
        table.print();
        let secs = shared_res.wall.as_secs_f64();
        println!(
            "  wall {:.2}s | {:.0} img/s | padded rows {:.1}% | e2e {}\n",
            secs,
            n_requests as f64 / secs,
            100.0 * padded_ratio(&shared_stats.total, batch),
            shared_res.e2e.summary()
        );
    }
    let d = &shared_stats.device;
    println!(
        "device: {} workers, {} sub-batches, {} cross-task switches, {} DRR \
         rounds",
        d.workers, d.dispatches, d.task_switches, d.drr_rounds
    );

    // parameter-staging economics: full conversions at build only; swaps
    // on a sole-owned task donate delta-touched slots in place, and no
    // batch ever converts parameters
    let prepares = rs_after_load.param_prepares - rs_before_load.param_prepares;
    let donations = rs_after_load.donations - rs_before_load.donations;
    let donated_bytes = rs_after_load.donated_refresh_bytes
        - rs_before_load.donated_refresh_bytes;
    let reuse = rs_after_load.param_reuse_bytes - rs_before_load.param_reuse_bytes;
    println!(
        "param staging: {} full conversions + {} donations during load \
         (= {} swaps, {} refreshed in place), {} prepared total ({}), {} \
         bound from cache during load",
        prepares,
        donations,
        swap_lats.len(),
        fmt_bytes(donated_bytes),
        rs_after_load.param_prepares,
        fmt_bytes(rs_after_load.param_prepare_bytes),
        fmt_bytes(reuse),
    );
    assert_eq!(
        prepares + donations,
        swap_lats.len(),
        "parameter staging during load must come from swaps alone \
         (never per batch)"
    );
    // every bench task owns a distinct parameter generation, so its
    // prepared set is never shared and every swap takes the donation path
    assert_eq!(
        donations,
        swap_lats.len(),
        "sole-owner swaps must donate in place instead of re-preparing"
    );
    println!(
        "device residency: {} resident now, {} upload savings across the \
         load, {} evictions",
        fmt_bytes(rs_after_load.resident_bytes),
        fmt_bytes(rs_after_load.h2d_resident_bytes
            - rs_before_load.h2d_resident_bytes),
        rs_after_load.resident_evictions - rs_before_load.resident_evictions,
    );

    // hot-swap report: every client recv above succeeded, so completing
    // the shared scenario at all proves no request was dropped mid-swap
    let answered = shared_res.e2e.count() as usize;
    assert_eq!(
        shared_stats.total.swaps,
        swap_lats.len(),
        "task stats must count every swap"
    );
    assert_eq!(answered, n_requests, "in-flight requests must survive hot swaps");
    let mean_swap =
        swap_lats.iter().sum::<Duration>() / swap_lats.len().max(1) as u32;
    let max_swap = swap_lats.iter().max().copied().unwrap_or_default();
    println!(
        "hot-swap: {} live swaps on {:?}, mean {} max {} (apply \
         backbone+delta + donated in-place refresh, atomic at batch \
         boundary); {answered} / {n_requests} requests answered, 0 dropped",
        swap_lats.len(),
        TASKS[0].0,
        fmt_duration(mean_swap),
        fmt_duration(max_swap),
    );

    // the acceptance headline: same load, same worker count — the shared
    // executor computes strictly fewer padded replica rows
    let base_ratio = padded_ratio(&baseline_stats, batch);
    let shared_ratio = padded_ratio(&shared_stats.total, batch);
    println!(
        "padded-row ratio: baseline {:.1}% -> shared {:.1}%",
        100.0 * base_ratio,
        100.0 * shared_ratio
    );
    assert!(
        shared_ratio < base_ratio,
        "shared executor must pad strictly less than per-task servers \
         (baseline {base_ratio:.4} vs shared {shared_ratio:.4})"
    );

    let report = Json::obj(vec![
        ("bench", "serve".into()),
        ("batch", batch.into()),
        ("workers", WORKERS.into()),
        (
            "tasks",
            Json::Arr(
                TASKS
                    .iter()
                    .map(|(t, s)| {
                        Json::obj(vec![("task", (*t).into()), ("weight", (*s).into())])
                    })
                    .collect(),
            ),
        ),
        ("exec_mean_ns", (exec_mean.as_nanos() as f64).into()),
        ("linger_ns", (linger.as_nanos() as f64).into()),
        ("baseline", scenario_json(&baseline_stats, batch, &baseline_res,
                                   n_requests)),
        ("shared", scenario_json(&shared_stats.total, batch, &shared_res,
                                 n_requests)),
        ("padded_ratio_improvement", (base_ratio - shared_ratio).into()),
        ("device_dispatches", d.dispatches.into()),
        ("device_task_switches", d.task_switches.into()),
        ("device_drr_rounds", d.drr_rounds.into()),
        ("param_conversions_during_load", prepares.into()),
        ("param_donations_during_load", donations.into()),
        ("donated_refresh_bytes_during_load", donated_bytes.into()),
        ("param_reuse_bytes_during_load", reuse.into()),
        ("resident_bytes", rs_after_load.resident_bytes.into()),
        ("resident_evictions", rs_after_load.resident_evictions.into()),
        ("upload_savings_bytes", rs_after_load.h2d_resident_bytes.into()),
        ("swaps", swap_lats.len().into()),
        ("swap_mean_ns", (mean_swap.as_nanos() as f64).into()),
        ("swap_max_ns", (max_swap.as_nanos() as f64).into()),
    ]);
    std::fs::write("BENCH_serve.json", format!("{report}\n"))?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
