//! Serving-engine bench: N concurrent submitters driving the multi-task
//! router, measuring end-to-end throughput plus queue/execute latency
//! percentiles per task and aggregated — the event-driven replacement for
//! the seed's sleep-polling batcher (ISSUE 1 tentpole).
//!
//!   cargo bench --bench serve
//!
//! Scale knobs: TASKEDGE_FULL=1 quadruples the request volume.

use std::sync::Arc;
use std::time::{Duration, Instant};

use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::{full_scale, Experiment};
use taskedge::metrics::fmt_duration;
use taskedge::runtime::Runtime;
use taskedge::serve::{Router, Server, ServerConfig, ServerStats};
use taskedge::util::bench::Table;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

const TASKS: [&str; 2] = ["pets", "dtd"];

fn stats_row(label: &str, st: &ServerStats) -> Vec<String> {
    let pct = |h: &taskedge::metrics::Histogram, q: f64| fmt_duration(h.quantile(q));
    vec![
        label.to_string(),
        st.requests.to_string(),
        st.batches.to_string(),
        st.padded_rows.to_string(),
        st.rejected.to_string(),
        pct(&st.queue, 0.50),
        pct(&st.queue, 0.95),
        pct(&st.queue, 0.99),
        pct(&st.execute, 0.50),
        pct(&st.execute, 0.95),
        pct(&st.execute, 0.99),
    ]
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(&Experiment::default_artifacts())?);
    let config = "micro";
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;

    let submitters = 8usize;
    let per_submitter = if full_scale() { 64 * batch } else { 16 * batch };
    let total_requests = submitters * per_submitter;

    // One server per task: same compiled graph, per-task "adapted" weights.
    let mut router = Router::new();
    for (i, task) in TASKS.iter().enumerate() {
        let params = Arc::new(ParamStore::init(&cfg, &mut Rng::new(7 + i as u64)));
        let server = Arc::new(Server::new(
            rt.clone(),
            config,
            params,
            ServerConfig {
                linger: Duration::from_millis(2),
                workers: 2,
                // sized so the bench never sheds: every submitter may have
                // its full window outstanding at once
                max_queue: total_requests,
            },
        )?);
        router.register(task, server);
    }
    let router = Arc::new(router);

    // Per-task request pools (single images as flat f32 rows), shared with
    // every submitter thread.
    let mut pools: Vec<Vec<Vec<f32>>> = Vec::new();
    for task in TASKS {
        let spec = task_by_name(task)?;
        let (_, pool) = generate_task(spec, cfg.image_size, 1, 2 * batch, 99)?;
        let isz = pool.image_numel();
        pools.push(
            (0..pool.n)
                .map(|i| pool.images[i * isz..(i + 1) * isz].to_vec())
                .collect(),
        );
    }
    let pools = Arc::new(pools);

    println!(
        "serve bench: {submitters} submitters x {per_submitter} requests \
         over {} tasks (batch {batch})",
        TASKS.len()
    );

    let (wall, client_lat) = std::thread::scope(|scope| -> anyhow::Result<_> {
        for task in TASKS {
            let server = router.server(task).unwrap().clone();
            scope.spawn(move || server.run().unwrap());
        }

        // run the load inside a closure so the servers are always shut down
        // before the scope joins their run threads — even on error
        let drive = || -> anyhow::Result<(Duration, taskedge::metrics::Histogram)> {
            // warm the executable cache so timing excludes the XLA compile
            for (t, task) in TASKS.iter().enumerate() {
                let rx = router.submit(task, pools[t][0].clone())?;
                rx.recv_timeout(Duration::from_secs(120))?;
            }

            let t0 = Instant::now();
            let mut handles = Vec::new();
            for s in 0..submitters {
                let router = router.clone();
                let pools = pools.clone();
                handles.push(scope.spawn(move || -> anyhow::Result<Vec<Duration>> {
                    let mut rxs = Vec::with_capacity(per_submitter);
                    for r in 0..per_submitter {
                        // round-robin tasks: both servers see interleaved load
                        let t = (s + r) % TASKS.len();
                        let img =
                            pools[t][(s * per_submitter + r) % pools[t].len()].clone();
                        rxs.push(router.submit(TASKS[t], img)?);
                    }
                    let mut lats = Vec::with_capacity(per_submitter);
                    for rx in rxs {
                        let resp = rx.recv_timeout(Duration::from_secs(300))?;
                        lats.push(resp.latency);
                    }
                    Ok(lats)
                }));
            }
            let mut client_lat = taskedge::metrics::Histogram::new();
            for h in handles {
                for lat in h.join().unwrap()? {
                    client_lat.record(lat);
                }
            }
            Ok((t0.elapsed(), client_lat))
        };
        let result = drive();
        router.shutdown();
        result
    })?;

    let stats = router.stats();
    let mut table = Table::new(
        "serving engine (event-driven batching)",
        &["task", "reqs", "batches", "padded", "rejected",
          "queue p50", "p95", "p99", "exec p50", "p95", "p99"],
    );
    for (task, st) in &stats.per_task {
        table.row(stats_row(task, st));
    }
    table.row(stats_row("TOTAL", &stats.total));
    table.print();

    let secs = wall.as_secs_f64();
    println!("\nwall time          : {:.2} s", secs);
    println!(
        "throughput         : {:.0} img/s ({} requests, {} submitters)",
        total_requests as f64 / secs,
        total_requests,
        submitters
    );
    println!("e2e latency        : {}", client_lat.summary());
    println!("queue latency      : {}", stats.total.queue.summary());
    println!("execute latency    : {}", stats.total.execute.summary());
    println!(
        "padding overhead   : {:.1}% of computed rows",
        100.0 * stats.total.padded_rows as f64
            / (stats.total.batches * batch).max(1) as f64
    );
    Ok(())
}
