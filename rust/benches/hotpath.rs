//! Hot-path micro-benchmarks (the §Perf instrumentation): step latency of
//! every artifact kind plus the host-side pieces around them (batch
//! assembly, literal conversion, mask building). This is what the
//! performance pass iterates against (EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;

use taskedge::data::{generate_task, task_by_name};
use taskedge::harness::Experiment;
use taskedge::masking;
use taskedge::runtime::{HostTensor, IoBinder, Runtime};
use taskedge::util::bench::{bench, Table};
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn main() -> anyhow::Result<()> {
    let artifacts = Experiment::default_artifacts();
    let rt = Runtime::load(&artifacts)?;
    let config = "micro";
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;
    let mut rng = Rng::new(3);
    let params = ParamStore::init(&cfg, &mut rng);
    let task = task_by_name("caltech101")?;
    let (train, _) = generate_task(task, cfg.image_size, 256, 0, 3)?;
    let (images, labels) = train.batch(&(0..batch).collect::<Vec<_>>())?;

    println!("== host-side hot paths ==");
    bench("data/batch_assembly(16 imgs)", 3, 50, || {
        let ids: Vec<usize> = (0..batch).collect();
        std::hint::black_box(train.batch(&ids).unwrap());
    });
    let big = params.get("block0.mlp.fc1.w")?.clone();
    bench("tensor/to_literal(fc1.w)", 3, 200, || {
        std::hint::black_box(big.to_literal().unwrap());
    });
    let w = params.get("block0.attn.qkv.w")?.f32s()?.to_vec();
    let norms = vec![1.0f32; cfg.dim];
    bench("masking/importance+topk(qkv)", 3, 100, || {
        let s = masking::importance_scores(&w, 3 * cfg.dim, cfg.dim, &norms).unwrap();
        std::hint::black_box(masking::per_neuron_topk(&s, 3 * cfg.dim, cfg.dim, 4).unwrap());
    });
    bench("data/task_generation(64 imgs)", 1, 5, || {
        std::hint::black_box(generate_task(task, cfg.image_size, 64, 0, 9).unwrap());
    });

    println!("\n== artifact execution latency ==");
    let mut table = Table::new("per-step latency by artifact kind",
                               &["kind", "mean ms", "p95 ms", "imgs/s"]);
    for kind in ["fwd", "eval", "calibrate", "grad_scores", "train_adam",
                 "train_sgd", "lora_train", "vpt_train", "adapter_train"] {
        // partial artifact dirs (e.g. the fused-matmul A/B comparison) only
        // carry a subset of kinds — skip the rest
        let Ok(spec) = rt.manifest().artifact_for(kind, config) else {
            continue;
        };
        let spec = spec.clone();
        let binder = IoBinder::new(&spec);
        // generic binding: params from store, masks ones, moments zeros,
        // lora factors random-ish, scalars fixed
        let mut lrng = Rng::new(11);
        let mut cache: BTreeMap<String, HostTensor> = BTreeMap::new();
        let inputs: Vec<HostTensor> = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                return Ok(params.get(p)?.clone());
            }
            Ok(match io.name.as_str() {
                "images" => images.clone(),
                "labels" => labels.clone(),
                "step" => HostTensor::scalar_f32(1.0),
                "lr" => HostTensor::scalar_f32(1e-3),
                "wd" => HostTensor::scalar_f32(0.0),
                name if name.starts_with("mask:") => HostTensor::ones(&io.shape),
                name if name.starts_with("lora_a:") || name == "prompt" => {
                    cache
                        .entry(name.to_string())
                        .or_insert_with(|| {
                            HostTensor::from_f32(
                                &io.shape,
                                lrng.normal_vec(io.numel(), 0.05),
                            )
                            .unwrap()
                        })
                        .clone()
                }
                name if name == "head_w" => params.get("head.w")?.clone(),
                name if name == "head_b" => params.get("head.b")?.clone(),
                name if name.starts_with("adapter:") && name.ends_with("down.w") => {
                    HostTensor::from_f32(&io.shape,
                                         lrng.normal_vec(io.numel(), 0.02))?
                }
                _ => HostTensor::zeros(&io.shape),
            })
        })?;
        // warm the executable cache before timing
        rt.execute(&spec.name, &inputs)?;
        let stats = bench(&format!("exec/{kind}"), 2, 15, || {
            std::hint::black_box(rt.execute(&spec.name, &inputs).unwrap());
        });
        table.row(vec![
            kind.to_string(),
            format!("{:.2}", stats.mean_ns / 1e6),
            format!("{:.2}", stats.p95_ns / 1e6),
            format!("{:.0}", stats.throughput(batch as f64)),
        ]);
    }
    table.print();

    // ---- session-level throughput (coordinator overhead on top of exec) --
    {
        use taskedge::coordinator::{FinetuneSession, TrainConfig};
        use taskedge::peft::Strategy;
        let (strain, seval) = generate_task(task, cfg.image_size, 256, 32, 3)?;
        let tcfg = TrainConfig { epochs: 2, lr: 1e-3, seed: 3,
                                 calib_batches: 2, ..Default::default() };
        let mut session = FinetuneSession::new(&rt, config,
                                               Strategy::TaskEdge { k: 2 },
                                               tcfg)?;
        // warm executables
        let _ = session.run(&params, &strain, &seval, "warmup")?;
        let exec_before = rt.stats();
        let t0 = std::time::Instant::now();
        let res = session.run(&params, &strain, &seval, "timed")?;
        let wall = t0.elapsed().as_secs_f64();
        let exec_after = rt.stats();
        let steps: usize = res.record.curve.iter().map(|e| e.steps).sum();
        let exec_s = (exec_after.execute_ns - exec_before.execute_ns) as f64 / 1e9;
        println!(
            "\nsession: {} train steps in {:.2}s ({:.1} steps/s, {:.0} img/s); \
             PJRT execute time {:.2}s ({:.1}% of wall — the rest is \
             coordinator overhead)",
            steps,
            wall,
            steps as f64 / wall,
            (steps * batch) as f64 / wall,
            exec_s,
            100.0 * exec_s / wall
        );
    }

    let s = rt.stats();
    println!(
        "\ncumulative runtime stats: {} compiles ({:.1} s), {} executions, \
         h2d {:.1} MB, d2h {:.1} MB",
        s.compiles,
        s.compile_ns as f64 / 1e9,
        s.executions,
        s.h2d_bytes as f64 / 1e6,
        s.d2h_bytes as f64 / 1e6
    );
    Ok(())
}
