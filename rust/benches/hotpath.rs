//! Hot-path benchmarks (the §Perf instrumentation): per-artifact step
//! latency, the host-side pieces around the training loop (batch
//! assembly, prefetch, literal conversion, mask building), and the
//! headline of this record: the fine-tuning session through the
//! **prepared** input path (frozen backbone/masks converted to device
//! literals once per session + compiled step plans + batch prefetch)
//! against the per-step conversion baseline (`prepared_io = false`).
//!
//! Emits `BENCH_hotpath.json` (steps/s, img/s, coordinator-overhead %,
//! h2d bytes/step split into bound vs actually-uploaded, resident-set
//! upload/donation counts, per-kind latency, prepare counts) — the
//! training-side perf trajectory, mirroring `BENCH_serve.json`. With
//! device residency on (`TASKEDGE_RESIDENT` unset or `1`), the frozen
//! set crosses the bus once per session: `h2d_upload_bytes_per_step`
//! tracks the per-batch dynamics while `h2d_bytes_per_step` still counts
//! every bound byte.
//!
//!   cargo bench --bench hotpath
//!
//! Knobs: `TASKEDGE_SMOKE=1` shrinks every iteration count to CI scale
//! (the JSON is still emitted); `TASKEDGE_FULL=1` runs the full grid and
//! turns the ≥1.3× prepared-vs-baseline speedup expectation into a hard
//! assertion (timing asserts are meaningless at smoke scale). Without
//! `artifacts/manifest.json` the execution sections self-skip and only
//! host-side results are reported.

use std::time::Instant;

use taskedge::coordinator::{FinetuneSession, TrainConfig};
use taskedge::data::{generate_task, task_by_name, Prefetcher};
use taskedge::harness::{full_scale, Experiment};
use taskedge::masking;
use taskedge::peft::Strategy;
use taskedge::runtime::{HostTensor, IoBinder, Runtime};
use taskedge::util::bench::{bench, Table};
use taskedge::util::json::Json;
use taskedge::util::rng::Rng;
use taskedge::vit::ParamStore;

fn smoke() -> bool {
    std::env::var("TASKEDGE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One timed session run plus the `RuntimeStats` deltas that prove what
/// the hot loop did (and did not) convert.
struct SessionMeasure {
    steps: usize,
    wall_s: f64,
    steps_per_s: f64,
    img_per_s: f64,
    /// PJRT execute time / wall — the rest is coordinator overhead
    exec_frac: f64,
    /// input bytes *bound* per step (resident or not) — the legacy total
    h2d_bytes_per_step: usize,
    /// bytes actually copied host->device per step; with residency on,
    /// this tracks the per-batch dynamics, not the frozen set
    h2d_upload_bytes_per_step: usize,
    /// frozen bytes bound from resident device buffers per step — the
    /// traffic residency kept off the bus
    resident_saved_bytes_per_step: usize,
    prepares: usize,
    /// resident-set uploads (first residency + post-eviction re-uploads)
    resident_prepares: usize,
    /// in-place donated refreshes (dense eval write-backs)
    donations: usize,
    /// per-epoch train losses, for the bit-identical cross-path check
    losses: Vec<f64>,
}

impl SessionMeasure {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", self.steps.into()),
            ("wall_s", self.wall_s.into()),
            ("steps_per_s", self.steps_per_s.into()),
            ("img_per_s", self.img_per_s.into()),
            ("exec_frac", self.exec_frac.into()),
            ("coordinator_overhead_frac", (1.0 - self.exec_frac).into()),
            ("h2d_bytes_per_step", self.h2d_bytes_per_step.into()),
            ("h2d_upload_bytes_per_step", self.h2d_upload_bytes_per_step.into()),
            ("resident_saved_bytes_per_step", self.resident_saved_bytes_per_step.into()),
            ("param_prepares", self.prepares.into()),
            ("resident_prepares", self.resident_prepares.into()),
            ("donations", self.donations.into()),
        ])
    }
}

#[allow(clippy::too_many_arguments)]
fn measure_session(
    rt: &Runtime,
    config: &str,
    strategy: Strategy,
    prepared_io: bool,
    epochs: usize,
    batch: usize,
    params: &ParamStore,
    train: &taskedge::data::Dataset,
    eval: &taskedge::data::Dataset,
) -> anyhow::Result<SessionMeasure> {
    let tcfg = TrainConfig {
        epochs,
        lr: 1e-3,
        seed: 3,
        calib_batches: 2,
        prepared_io,
        ..Default::default()
    };
    let mut session = FinetuneSession::new(rt, config, strategy, tcfg)?;
    let s0 = rt.stats();
    let t0 = Instant::now();
    let res = session.run(params, train, eval, "bench")?;
    let wall_s = t0.elapsed().as_secs_f64();
    let s1 = rt.stats();
    let steps: usize = res.record.curve.iter().map(|e| e.steps).sum();
    let exec_s = (s1.execute_ns - s0.execute_ns) as f64 / 1e9;
    Ok(SessionMeasure {
        steps,
        wall_s,
        steps_per_s: steps as f64 / wall_s,
        img_per_s: (steps * batch) as f64 / wall_s,
        exec_frac: exec_s / wall_s,
        h2d_bytes_per_step: (s1.h2d_bytes - s0.h2d_bytes) / steps.max(1),
        h2d_upload_bytes_per_step: (s1.h2d_upload_bytes - s0.h2d_upload_bytes)
            / steps.max(1),
        resident_saved_bytes_per_step: (s1.h2d_resident_bytes
            - s0.h2d_resident_bytes)
            / steps.max(1),
        prepares: s1.param_prepares - s0.param_prepares,
        resident_prepares: s1.resident_prepares - s0.resident_prepares,
        donations: s1.donations - s0.donations,
        losses: res.record.curve.iter().map(|e| e.train_loss).collect(),
    })
}

/// Host-side benches need no artifacts — they always run, so the CI smoke
/// job exercises the bench binary and the JSON emission path end to end.
fn host_benches(is_smoke: bool) -> anyhow::Result<Json> {
    let (iters, gen_n) = if is_smoke { (10, 16) } else { (50, 64) };
    let image_size = 16;
    let batch = 16;
    let task = task_by_name("caltech101")?;
    let (train, _) = generate_task(task, image_size, 256, 0, 3)?;

    println!("== host-side hot paths ==");
    let ids: Vec<usize> = (0..batch).collect();
    let asm = bench("data/batch_assembly(16 imgs)", 3, iters, || {
        std::hint::black_box(train.batch(&ids).unwrap());
    });
    // the prefetch worker assembles batches ahead: the consumer sees only
    // channel-receive latency while the device (simulated here by the
    // bench harness itself) would be executing
    let mut pf = Prefetcher::spawn(&train, batch, 7, 3 + iters + 16);
    let pfb = bench("data/prefetch_next(overlapped)", 3, iters, || {
        std::hint::black_box(pf.next().unwrap());
    });
    drop(pf);
    let (imgs, _) = train.batch(&ids)?;
    let conv = bench("tensor/to_literal(image batch)", 3, iters, || {
        std::hint::black_box(imgs.to_literal().unwrap());
    });
    let dim = 64usize;
    let mut mrng = Rng::new(11);
    let w: Vec<f32> = mrng.normal_vec(3 * dim * dim, 0.05);
    let norms = vec![1.0f32; dim];
    let mask = bench("masking/importance+topk(qkv)", 3, iters, || {
        let s = masking::importance_scores(&w, 3 * dim, dim, &norms).unwrap();
        std::hint::black_box(
            masking::per_neuron_topk(&s, 3 * dim, dim, 4).unwrap(),
        );
    });
    let gen = bench("data/task_generation", 1, 3, || {
        std::hint::black_box(
            generate_task(task, image_size, gen_n, 0, 9).unwrap(),
        );
    });
    Ok(Json::obj(vec![
        ("batch_assembly_ns", asm.mean_ns.into()),
        ("prefetch_next_ns", pfb.mean_ns.into()),
        ("to_literal_image_ns", conv.mean_ns.into()),
        ("mask_importance_topk_ns", mask.mean_ns.into()),
        ("task_generation_ns", gen.mean_ns.into()),
    ]))
}

/// Per-artifact-kind execution latency (needs compiled artifacts).
fn kind_benches(rt: &Runtime, config: &str, is_smoke: bool) -> anyhow::Result<Json> {
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;
    let mut rng = Rng::new(3);
    let params = ParamStore::init(&cfg, &mut rng);
    let task = task_by_name("caltech101")?;
    let (train, _) = generate_task(task, cfg.image_size, 4 * batch, 0, 3)?;
    let (images, labels) = train.batch(&(0..batch).collect::<Vec<_>>())?;
    let iters = if is_smoke { 3 } else { 15 };

    println!("\n== artifact execution latency ==");
    let mut table = Table::new(
        "per-step latency by artifact kind",
        &["kind", "mean ms", "p95 ms", "imgs/s"],
    );
    let mut kinds = Vec::new();
    for kind in ["fwd", "eval", "calibrate", "grad_scores", "train_adam",
                 "train_sgd", "lora_train", "vpt_train", "adapter_train"] {
        // partial artifact dirs (e.g. the fused-matmul A/B comparison) only
        // carry a subset of kinds — skip the rest
        let Ok(spec) = rt.manifest().artifact_for(kind, config) else {
            continue;
        };
        let spec = spec.clone();
        let binder = IoBinder::new(&spec);
        // generic binding: params from store, masks ones, moments zeros,
        // lora factors random-ish, scalars fixed
        let mut lrng = Rng::new(11);
        let mut cache: std::collections::BTreeMap<String, HostTensor> =
            std::collections::BTreeMap::new();
        let inputs: Vec<HostTensor> = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                return Ok(params.get(p)?.clone());
            }
            Ok(match io.name.as_str() {
                "images" => images.clone(),
                "labels" => labels.clone(),
                "step" => HostTensor::scalar_f32(1.0),
                "lr" => HostTensor::scalar_f32(1e-3),
                "wd" => HostTensor::scalar_f32(0.0),
                name if name.starts_with("mask:") => HostTensor::ones(&io.shape),
                name if name.starts_with("lora_a:") || name == "prompt" => {
                    cache
                        .entry(name.to_string())
                        .or_insert_with(|| {
                            HostTensor::from_f32(
                                &io.shape,
                                lrng.normal_vec(io.numel(), 0.05),
                            )
                            .unwrap()
                        })
                        .clone()
                }
                name if name == "head_w" => params.get("head.w")?.clone(),
                name if name == "head_b" => params.get("head.b")?.clone(),
                name if name.starts_with("adapter:") && name.ends_with("down.w") => {
                    HostTensor::from_f32(&io.shape,
                                         lrng.normal_vec(io.numel(), 0.02))?
                }
                _ => HostTensor::zeros(&io.shape),
            })
        })?;
        // warm the executable cache before timing
        rt.execute(&spec.name, &inputs)?;
        let stats = bench(&format!("exec/{kind}"), 2, iters, || {
            std::hint::black_box(rt.execute(&spec.name, &inputs).unwrap());
        });
        table.row(vec![
            kind.to_string(),
            format!("{:.2}", stats.mean_ns / 1e6),
            format!("{:.2}", stats.p95_ns / 1e6),
            format!("{:.0}", stats.throughput(batch as f64)),
        ]);
        kinds.push(Json::obj(vec![
            ("kind", kind.into()),
            ("mean_ns", stats.mean_ns.into()),
            ("p95_ns", stats.p95_ns.into()),
            ("imgs_per_s", stats.throughput(batch as f64).into()),
        ]));
    }
    table.print();
    Ok(Json::Arr(kinds))
}

fn main() -> anyhow::Result<()> {
    let is_smoke = smoke();
    let artifacts = Experiment::default_artifacts();
    let mut report: Vec<(&str, Json)> = vec![
        ("bench", "hotpath".into()),
        ("smoke", is_smoke.into()),
    ];

    report.push(("host", host_benches(is_smoke)?));

    if !artifacts.join("manifest.json").exists() {
        println!(
            "\nSKIP: {}/manifest.json missing — run `make artifacts` for the \
             execution benches; emitting host-side results only",
            artifacts.display()
        );
        let j = Json::Obj(report.into_iter().map(|(k, v)| (k.to_string(), v)).collect());
        std::fs::write("BENCH_hotpath.json", format!("{j}\n"))?;
        println!("wrote BENCH_hotpath.json");
        return Ok(());
    }

    let rt = Runtime::load(&artifacts)?;
    let config = "micro";
    let cfg = rt.manifest().config(config)?.clone();
    let batch = rt.manifest().batch;
    // record whether device residency was live for this run — the JSON
    // consumer needs it to interpret the upload/bound split
    report.push(("resident", rt.resident_enabled().into()));

    report.push(("kinds", kind_benches(&rt, config, is_smoke)?));

    // ---- session-level: prepared path vs per-step conversion baseline --
    let mut rng = Rng::new(3);
    let params = ParamStore::init(&cfg, &mut rng);
    let task = task_by_name("caltech101")?;
    let n_train = if is_smoke { 4 * batch } else { 256 };
    let epochs = if is_smoke { 1 } else { 2 };
    let (strain, seval) = generate_task(task, cfg.image_size, n_train, 2 * batch, 3)?;

    // warm executables (and the page cache) outside the timed runs
    measure_session(&rt, config, Strategy::TaskEdge { k: 2 }, true, 1, batch,
                    &params, &strain, &seval)?;
    let base = measure_session(&rt, config, Strategy::TaskEdge { k: 2 }, false,
                               epochs, batch, &params, &strain, &seval)?;
    let prep = measure_session(&rt, config, Strategy::TaskEdge { k: 2 }, true,
                               epochs, batch, &params, &strain, &seval)?;
    // same seeds, same data: the two paths must produce identical math
    assert_eq!(
        base.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        prep.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "prepared and per-step conversion paths diverged numerically"
    );
    let speedup = prep.steps_per_s / base.steps_per_s;
    println!(
        "\nsession (taskedge_k2, {epochs} epochs, {} steps):\n  \
         baseline  {:6.1} steps/s  {:6.0} img/s  exec {:4.1}% of wall  \
         h2d {}/step (uploaded {})\n  prepared  {:6.1} steps/s  {:6.0} \
         img/s  exec {:4.1}% of wall  h2d {}/step (uploaded {}, resident \
         saved {})\n  speedup {speedup:.2}x (prepares: baseline {} vs \
         prepared {}; resident uploads {}, donations {})",
        base.steps,
        base.steps_per_s,
        base.img_per_s,
        100.0 * base.exec_frac,
        taskedge::metrics::fmt_bytes(base.h2d_bytes_per_step),
        taskedge::metrics::fmt_bytes(base.h2d_upload_bytes_per_step),
        prep.steps_per_s,
        prep.img_per_s,
        100.0 * prep.exec_frac,
        taskedge::metrics::fmt_bytes(prep.h2d_bytes_per_step),
        taskedge::metrics::fmt_bytes(prep.h2d_upload_bytes_per_step),
        taskedge::metrics::fmt_bytes(prep.resident_saved_bytes_per_step),
        base.prepares,
        prep.prepares,
        prep.resident_prepares,
        prep.donations,
    );
    // the baseline path must never build prepared literal sets
    assert_eq!(base.prepares, 0, "prepared_io=false must not prepare");
    // with device residency on, the frozen set stays on-device: real bus
    // traffic per step must be strictly below the bound-bytes total
    // (which still counts every resident slot the step consumed)
    if rt.resident_enabled() && prep.steps > 1 {
        assert!(
            prep.h2d_upload_bytes_per_step < prep.h2d_bytes_per_step,
            "resident path uploaded as much as it bound \
             ({} vs {} per step) — device residency is not saving traffic",
            prep.h2d_upload_bytes_per_step,
            prep.h2d_bytes_per_step
        );
        assert!(
            prep.resident_saved_bytes_per_step > 0,
            "resident path reported zero resident-bound bytes"
        );
    }
    if full_scale() {
        assert!(
            speedup >= 1.3,
            "prepared training path must be >= 1.3x the per-step baseline \
             at full scale (got {speedup:.2}x)"
        );
    }
    report.push((
        "session",
        Json::obj(vec![
            ("strategy", "taskedge_k2".into()),
            ("epochs", epochs.into()),
            ("batch", batch.into()),
            ("baseline", base.to_json()),
            ("prepared", prep.to_json()),
            ("speedup", speedup.into()),
        ]),
    ));

    // ---- frozen-family invariant: prepares are O(1) per session --------
    // (constant in the number of steps; bit-for-bit the same count when
    // the epoch count doubles)
    let lora = Strategy::SparseLora { k: 4 };
    let short = measure_session(&rt, config, lora.clone(), true, epochs, batch,
                                &params, &strain, &seval)?;
    let long = measure_session(&rt, config, lora, true, 2 * epochs,
                               batch, &params, &strain, &seval)?;
    println!(
        "frozen-family (sparse_lora_k4): {} prepares at {epochs} epochs, {} \
         at {} epochs (must match — conversions are per-session, not \
         per-step)",
        short.prepares,
        long.prepares,
        2 * epochs
    );
    assert_eq!(
        short.prepares, long.prepares,
        "frozen-set conversions must not scale with steps"
    );
    assert!(short.prepares >= 1, "prepared sessions must prepare at least once");
    // residency rides the same lifecycle: device uploads are per prepared
    // set (O(1) per session generation), never per step
    if rt.resident_enabled() {
        assert_eq!(
            short.resident_prepares, long.resident_prepares,
            "resident-set uploads must not scale with steps"
        );
    }
    report.push((
        "frozen_family",
        Json::obj(vec![
            ("strategy", "sparse_lora_k4".into()),
            ("prepares_short", short.prepares.into()),
            ("prepares_long", long.prepares.into()),
            ("resident_prepares_short", short.resident_prepares.into()),
            ("resident_prepares_long", long.resident_prepares.into()),
            ("epochs_short", epochs.into()),
            ("epochs_long", (2 * epochs).into()),
        ]),
    ));

    let s = rt.stats();
    println!(
        "\ncumulative runtime stats: {} compiles ({:.1} s), {} executions, \
         h2d {:.1} MB bound ({:.1} MB uploaded, {:.1} MB resident-saved), \
         d2h {:.1} MB, {} param prepares ({} cached hits, {} reused from \
         cache), {} resident now ({} uploads, {} evictions, {} donations)",
        s.compiles,
        s.compile_ns as f64 / 1e9,
        s.executions,
        s.h2d_bytes as f64 / 1e6,
        s.h2d_upload_bytes as f64 / 1e6,
        s.h2d_resident_bytes as f64 / 1e6,
        s.d2h_bytes as f64 / 1e6,
        s.param_prepares,
        s.param_cache_hits,
        taskedge::metrics::fmt_bytes(s.param_reuse_bytes),
        taskedge::metrics::fmt_bytes(s.resident_bytes),
        s.resident_prepares,
        s.resident_evictions,
        s.donations,
    );

    let j = Json::Obj(report.into_iter().map(|(k, v)| (k.to_string(), v)).collect());
    std::fs::write("BENCH_hotpath.json", format!("{j}\n"))?;
    println!("wrote BENCH_hotpath.json");
    Ok(())
}
