//! Ablation B (paper §III-C, structured sparsity): unstructured per-neuron
//! top-K vs N:M structured selection.
//!
//! Reports accuracy (structured constraints cost a little selection
//! freedom) and the modeled sparse-tensor-core step speedup (the hardware
//! itself is gated — DESIGN.md §2 — but the mask-format invariant is
//! enforced for real and property-tested).

use taskedge::coordinator::TrainConfig;
use taskedge::edge::NmSpeedupModel;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };
    let model = NmSpeedupModel::default();

    let variants: Vec<(String, Strategy, Option<(usize, usize)>)> = vec![
        ("unstructured k=2".into(), Strategy::TaskEdge { k: 2 }, None),
        ("2:4 structured".into(), Strategy::TaskEdgeNM { n: 2, m: 4 }, Some((2, 4))),
        ("1:4 structured".into(), Strategy::TaskEdgeNM { n: 1, m: 4 }, Some((1, 4))),
        ("2:8 structured".into(), Strategy::TaskEdgeNM { n: 2, m: 8 }, Some((2, 8))),
    ];

    let mut table = Table::new(
        "Ablation B: unstructured vs N:M (syn-caltech101)",
        &["variant", "top1", "params %", "N:M valid", "modeled step speedup"],
    );
    for (label, strategy, nm) in variants {
        let res = exp.run_task("caltech101", strategy, tcfg.clone(),
                               scale.n_train, scale.n_eval)?;
        // Check the N:M invariant on every backbone mask, in PAPER layout:
        // groups run along the input dim = down columns of the stored
        // (d_in, d_out) mask, i.e. along rows of its transpose.
        let nm_ok = match nm {
            None => "-".to_string(),
            Some((n, m)) => {
                let ok = res.masks.iter().all(|(name, mask)| {
                    if name.starts_with("head.") || mask.shape.len() != 2 {
                        return true;
                    }
                    let (d_in, d_out) = (mask.shape[0], mask.shape[1]);
                    if d_in % m != 0 {
                        return true; // tensor skipped by allocator
                    }
                    (0..d_out).all(|c| {
                        (0..d_in / m).all(|g| {
                            let ones: usize = (0..m)
                                .filter(|j| mask.data[(g * m + j) * d_out + c] == 1.0)
                                .count();
                            ones == n
                        })
                    })
                });
                ok.to_string()
            }
        };
        let density = res.trainable_frac;
        let speedup = match nm {
            Some((n, m)) => model.step_speedup(n, m, density),
            None => model.step_speedup(4, 4, density),
        };
        table.row(vec![
            label,
            format!("{:.3}", res.record.best_top1()),
            format!("{:.4}", res.trainable_frac * 100.0),
            nm_ok,
            format!("{:.2}x", speedup),
        ]);
    }
    table.print();
    println!(
        "\npaper claim: N:M keeps accuracy close to unstructured while \
         enabling sparse-tensor-core acceleration (modeled here; the mask \
         layout invariant is enforced exactly)."
    );
    Ok(())
}
