//! Paper Fig. 2: trainable parameters vs accuracy on the Caltech-101 and
//! DTD analogs.
//!
//! Sweeps the per-neuron budget K (and thus the trainable fraction) and
//! reports best top-1/top-5 per budget.
//!
//! Expected shape (paper): accuracy *decreases* as trainable parameters
//! grow past the sweet spot — the small train set overfits; TaskEdge's
//! selection keeps accuracy high at tiny budgets.

use taskedge::coordinator::TrainConfig;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs.max(4), lr: 1e-3, seed: 42,
                             ..Default::default() };
    let ks: &[usize] = if taskedge::harness::full_scale() {
        &[1, 2, 4, 8, 16, 32, 48]
    } else {
        &[1, 4, 16, 48]
    };

    for task in ["caltech101", "dtd"] {
        let mut table = Table::new(
            &format!("Fig. 2: trainable params vs accuracy, syn-{task}"),
            &["k", "trainable", "params %", "top1", "top5"],
        );
        for &k in ks {
            let res = exp.run_task(task, Strategy::TaskEdge { k },
                                   tcfg.clone(), scale.n_train, scale.n_eval)?;
            table.row(vec![
                k.to_string(),
                res.trainable_params.to_string(),
                format!("{:.4}", res.trainable_frac * 100.0),
                format!("{:.3}", res.record.best_top1()),
                format!("{:.3}", res.record.best_top5()),
            ]);
        }
        table.print();
        println!();
    }
    println!(
        "paper shape: the curve is NOT monotone in parameters — mid/small \
         budgets match or beat large ones on the 1k-example tasks."
    );
    Ok(())
}
