//! Paper Fig. 1: epochs vs top-1 / top-5 accuracy for five mask ratios on
//! the Caltech-101 analog.
//!
//! Paper mask ratios: 91.06, 95.52, 99.55, 99.90, 99.98 % (masked = frozen).
//! We realize each ratio with the per-neuron budget K that hits the same
//! backbone density, then print the full per-epoch accuracy series.
//!
//! Expected shape (paper): convergence by ~20 epochs; ratios around 99 %
//! peak highest; very dense (low ratio) overfits; extremely sparse
//! (99.98 %) underfits slightly.

use taskedge::coordinator::TrainConfig;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let cfg = exp.rt.manifest().config(&exp.config)?.clone();
    let epochs = if taskedge::harness::full_scale() { 20 } else { scale.epochs.max(4) };
    let tcfg = TrainConfig { epochs, lr: 1e-3, seed: 42, eval_every: 1,
                             ..Default::default() };

    // K values spanning dense -> extremely sparse per-neuron budgets; the
    // realized mask ratio is computed from the actual masks.
    let ks = [32usize, 16, 8, 2, 1];
    let mut series = Vec::new();
    for &k in &ks {
        let res = exp.run_task("caltech101", Strategy::TaskEdge { k },
                               tcfg.clone(), scale.n_train, scale.n_eval)?;
        let total: usize = res.masks.values().map(|m| m.numel()).sum();
        let ones: usize = res.masks.values().map(|m| m.count_ones()).sum();
        let ratio = 100.0 * (1.0 - ones as f64 / total as f64);
        series.push((k, ratio, res));
    }

    for (metric, get) in [
        ("top-1", Box::new(|e: &taskedge::metrics::EpochMetrics| e.eval_top1)
            as Box<dyn Fn(&taskedge::metrics::EpochMetrics) -> f64>),
        ("top-5", Box::new(|e: &taskedge::metrics::EpochMetrics| e.eval_top5)),
    ] {
        let mut headers = vec!["epoch".to_string()];
        for (k, ratio, _) in &series {
            headers.push(format!("k={k} (mask {ratio:.2}%)"));
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            &format!("Fig. 1 ({metric}): epochs vs accuracy, syn-caltech101"),
            &header_refs,
        );
        for epoch in 0..epochs {
            let mut row = vec![epoch.to_string()];
            for (_, _, res) in &series {
                let v = res.record.curve.get(epoch).map(&get).unwrap_or(f64::NAN);
                row.push(format!("{v:.3}"));
            }
            table.row(row);
        }
        table.print();
        println!();
    }

    println!(
        "paper shape: mid-high mask ratios (~99%) should reach the best \
         accuracy; the densest setting trails due to 1k-example overfitting. \
         backbone = {} params.",
        cfg.num_params
    );
    Ok(())
}
