//! Paper Table I: VTAB accuracy × strategy × params %.
//!
//! Scaled-down grid by default (subset of tasks, few epochs) so `cargo
//! bench` completes quickly; TASKEDGE_FULL=1 runs closer to paper scale.
//! `examples/table1_full.rs` runs all 19 tasks.
//!
//! Expected *shape* (paper, ViT-B/16 on real VTAB): TaskEdge matches or
//! beats the dense baselines on most Natural/Specialized tasks with ~10x
//! fewer trainable params than LoRA (0.09 % vs 0.90 %), and Full
//! fine-tuning overfits the 1k-example regime.

use taskedge::coordinator::TrainConfig;
use taskedge::data::task_by_name;
use taskedge::harness::{bench_scale, Experiment};
use taskedge::metrics::Summary;
use taskedge::peft::Strategy;
use taskedge::util::bench::Table;

/// Paper Table I reference rows (mean over the 19 VTAB tasks, params %).
const PAPER_REFERENCE: &[(&str, f64, f64)] = &[
    ("Full", 65.6, 100.0),
    ("Linear", 52.7, 0.05),
    ("Bias", 62.1, 0.16),
    ("Adapter", 55.8, 0.31),
    ("LoRA", 72.4, 0.90),
    ("VPT-Shallow", 64.9, 0.13),
    ("VPT-Deep", 69.4, 0.70),
    ("TaskEdge", 64.4, 0.09),
];

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let exp = Experiment::setup(
        &Experiment::default_artifacts(),
        "micro",
        scale.pretrain_steps,
        42,
    )?;
    let tcfg = TrainConfig { epochs: scale.epochs, lr: 1e-3, seed: 42,
                             ..Default::default() };

    // one task per VTAB group keeps the bench fast while preserving the
    // group structure of the paper's table
    let tasks = if taskedge::harness::full_scale() {
        vec!["caltech101", "dtd", "pets", "eurosat", "resisc45",
             "clevr/count", "dsprites/ori"]
    } else {
        vec!["caltech101", "eurosat", "clevr/count"]
    };
    let strategies: Vec<Strategy> = vec![
        Strategy::Full,
        Strategy::Linear,
        Strategy::BitFit,
        Strategy::Adapter,
        Strategy::Lora,
        Strategy::Vpt,
        Strategy::Magnitude { k: 2 },
        Strategy::TaskEdge { k: 2 },
    ];

    let mut table = Table::new(
        "Table I (scaled): SynthVTAB accuracy by strategy",
        &{
            let mut h = vec!["strategy"];
            h.extend(tasks.iter().copied());
            h.extend(["mean", "params %"]);
            h
        },
    );

    for strategy in &strategies {
        let mut cells = vec![strategy.name()];
        let mut mean = Summary::default();
        let mut frac = Summary::default();
        // additive/reparameterized methods train fresh parameters and need
        // the higher lr typical of PEFT recipes; selective methods fine-tune
        // pretrained weights at the lower lr (paper §IV-B tunes per method)
        let mut cfg_s = tcfg.clone();
        if matches!(strategy.family(),
                    taskedge::peft::Family::Lora
                    | taskedge::peft::Family::Vpt
                    | taskedge::peft::Family::Adapter) {
            cfg_s.lr = 5e-3;
        }
        for t in &tasks {
            let task = task_by_name(t)?;
            let res = exp.run_task(task.name, strategy.clone(), cfg_s.clone(),
                                   scale.n_train, scale.n_eval)?;
            let top1 = res.record.best_top1();
            mean.add(top1);
            frac.add(res.trainable_frac);
            cells.push(format!("{:.3}", top1));
        }
        cells.push(format!("{:.3}", mean.mean()));
        cells.push(format!("{:.4}", frac.mean() * 100.0));
        table.row(cells);
    }
    table.print();

    println!("\npaper reference (ViT-B/16, real VTAB-1k, mean over 19 tasks):");
    let mut ref_table = Table::new("Table I (paper)", &["method", "mean acc",
                                                        "params %"]);
    for (m, acc, p) in PAPER_REFERENCE {
        ref_table.row(vec![m.to_string(), format!("{acc:.1}"),
                           format!("{p:.2}")]);
    }
    ref_table.print();
    println!(
        "\nshape check: TaskEdge should sit near the top of the accuracy \
         ordering at the LOWEST selective params %, Linear lowest accuracy, \
         Full not best (1k-example overfitting)."
    );
    Ok(())
}
