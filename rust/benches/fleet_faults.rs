//! Chaos bench for the fleet round engine: drive [`run_round`] through a
//! deterministic fault storm — ≥30% of first attempts panic, one device
//! stalls past the straggler timeout, uploads arrive corrupted, one
//! device dies entering Train — and assert the robustness contract: the
//! round completes, every job is terminally accounted for, and quorum is
//! met. Then truncate the journal mid-Train (the crash resume exists for)
//! and prove `resume` replays the completed prefix bit-identically
//! instead of re-running it.
//!
//! Three rounds, all on [`SimRunner`] (no artifacts, no PJRT — this bench
//! measures the coordinator, not the compiler):
//!   clean  — no faults, no journal: the zero-cost-default baseline
//!   chaos  — the fault storm above, drained to disk with a journal
//!   resume — journal truncated after half the accepts, `resume: true`
//!
//! Results land in `BENCH_fleet.json`.
//!
//!   cargo bench --bench fleet_faults

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use taskedge::coordinator::fleet::{Job, JobStatus};
use taskedge::coordinator::rounds::JOURNAL_FILE;
use taskedge::coordinator::{
    run_round, FaultPlan, RoundConfig, RoundReport, SimRunner, TrainConfig,
};
use taskedge::data::task_by_name;
use taskedge::edge::profiles::profile_by_name;
use taskedge::edge::DeviceProfile;
use taskedge::util::json::Json;

const SEED: u64 = 42;

/// One strategy per PEFT family plus the paper's headline strategy, so
/// the fault storm crosses every delta shape the admission checker knows.
const STRATEGIES: [&str; 4] = ["taskedge:k=2", "lora", "vpt", "adapter"];

const TASKS: [&str; 6] =
    ["pets", "dtd", "eurosat", "caltech101", "flowers102", "svhn"];

const DEVICES: [&str; 4] =
    ["jetson-orin-nano", "jetson-nano", "phone-flagship", "rtx4090-edge-server"];

/// The storm: 35% transient first-attempt panics, 20% corrupted first
/// uploads, jetson-nano stalls past the straggler timeout on every
/// attempt, phone-flagship dies the moment Train starts.
const FAULT_SPEC: &str =
    "panic=0.35,corrupt=0.2,stall=jetson-nano:600,die=phone-flagship@train";

fn jobs() -> Result<Vec<Job>> {
    let mut jobs = Vec::new();
    for t in TASKS {
        let task = task_by_name(t)?;
        for s in STRATEGIES {
            jobs.push(Job {
                task: task.clone(),
                strategy: taskedge::peft::Strategy::parse(s)?,
                train_cfg: TrainConfig { seed: SEED, ..Default::default() },
                n_train: 32,
                n_eval: 16,
            });
        }
    }
    Ok(jobs)
}

fn devices() -> Result<Vec<&'static DeviceProfile>> {
    DEVICES
        .iter()
        .map(|n| profile_by_name(n).with_context(|| format!("device {n:?}")))
        .collect()
}

/// Digest per (task, strategy) — the identity resume must preserve.
fn digests(r: &RoundReport) -> BTreeMap<(String, String), String> {
    r.reports
        .iter()
        .filter_map(|r| {
            r.delta_digest
                .clone()
                .map(|d| ((r.task.clone(), r.strategy.clone()), d))
        })
        .collect()
}

fn round_json(label: &str, r: &RoundReport) -> Json {
    let s = &r.summary;
    Json::obj(vec![
        ("round", label.into()),
        ("jobs", r.reports.len().into()),
        ("accepted", s.accepted.into()),
        ("dropped", s.dropped.into()),
        ("not_admitted", s.not_admitted.into()),
        ("replayed", s.replayed.into()),
        ("retried", (s.retries as usize).into()),
        ("reassigned", (s.reassigned as usize).into()),
        ("rejected_uploads", (s.rejected_uploads as usize).into()),
        ("panics", (s.panics as usize).into()),
        ("late_results", (s.late_results as usize).into()),
        ("quorum_met", s.quorum_met.into()),
        ("quorum_required", s.quorum_required.into()),
        ("dead_devices", Json::Arr(
            s.dead_devices.iter().map(|d| Json::Str(d.clone())).collect(),
        )),
        ("wall_ms", s.wall_ms.into()),
        ("phases", Json::Arr(
            s.phase_ms
                .iter()
                .map(|(name, ms)| {
                    Json::obj(vec![("phase", (*name).into()), ("ms", (*ms).into())])
                })
                .collect(),
        )),
    ])
}

/// Every job must end in exactly one terminal state, and accepted drained
/// jobs must carry a delta file + digest.
fn assert_accounted(label: &str, r: &RoundReport, n_jobs: usize, drained: bool) {
    assert_eq!(r.reports.len(), n_jobs, "{label}: one report per job");
    let s = &r.summary;
    assert_eq!(
        s.accepted + s.dropped + s.not_admitted,
        n_jobs,
        "{label}: every job terminally accounted for"
    );
    for rep in &r.reports {
        match rep.status {
            JobStatus::Accepted => {
                assert!(rep.admitted && rep.attempts >= 1 && rep.delta_bytes > 0);
                if drained {
                    assert!(
                        rep.delta_path.is_some() && rep.delta_digest.is_some(),
                        "{label}: drained accept must record file + digest"
                    );
                    assert!(rep.delta.is_none(), "{label}: drain keeps no copy");
                } else {
                    assert!(rep.delta.is_some());
                }
            }
            JobStatus::Dropped | JobStatus::NotAdmitted => {
                assert!(rep.delta.is_none() && rep.error.is_some());
            }
        }
    }
}

/// Truncate the journal right after the `keep`-th accept entry — the
/// mid-Train power cut the resume path exists for.
fn truncate_after_accepts(path: &Path, keep: usize) -> Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let mut kept = Vec::new();
    let mut accepts = 0;
    for line in text.lines() {
        kept.push(line);
        if Json::parse(line)
            .ok()
            .and_then(|j| j.get("kind").and_then(|k| k.as_str().map(String::from)))
            .as_deref()
            == Some("accept")
        {
            accepts += 1;
            if accepts == keep {
                break;
            }
        }
    }
    std::fs::write(path, format!("{}\n", kept.join("\n")))?;
    Ok(accepts)
}

fn main() -> Result<()> {
    let runner = SimRunner::new(SEED)?;
    let jobs = jobs()?;
    let devices = devices()?;
    let n_jobs = jobs.len();
    let dir = std::env::temp_dir().join(format!(
        "taskedge_fleet_faults_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    println!(
        "fleet chaos bench: {n_jobs} jobs x {} devices, faults [{FAULT_SPEC}]",
        devices.len()
    );

    // ---- round 1: clean (no faults, no journal) -------------------------
    let clean_cfg = RoundConfig { seed: SEED, ..RoundConfig::default() };
    assert!(clean_cfg.faults.is_noop(), "default plan must inject nothing");
    let clean = run_round(runner.manifest(), &devices, &jobs, &runner, &clean_cfg)?;
    assert_accounted("clean", &clean, n_jobs, false);
    let cs = &clean.summary;
    assert_eq!(cs.accepted, n_jobs, "clean round accepts everything");
    assert_eq!(
        (cs.retries, cs.rejected_uploads, cs.panics, cs.reassigned),
        (0, 0, 0, 0),
        "no-fault round must be fault-free"
    );
    println!(
        "clean : {} accepted in {:.0} ms ({} devices joined)",
        cs.accepted,
        cs.wall_ms,
        cs.joined_devices.len()
    );

    // ---- round 2: the fault storm, drained to disk ----------------------
    let chaos_cfg = RoundConfig {
        seed: SEED,
        faults: FaultPlan::parse(FAULT_SPEC, SEED)?,
        delta_dir: Some(dir.clone()),
        job_timeout_ms: 200,
        max_attempts: 4,
        backoff_ms: 10,
        quorum: 0.5,
        ..RoundConfig::default()
    };
    let chaos = run_round(runner.manifest(), &devices, &jobs, &runner, &chaos_cfg)?;
    assert_accounted("chaos", &chaos, n_jobs, true);
    let hs = &chaos.summary;
    assert!(hs.panics >= 1, "35% panic rate must hit at least one job");
    assert!(hs.retries >= 1, "panics/rejects must drive retries");
    assert!(hs.rejected_uploads >= 1, "corrupt uploads must be rejected");
    assert!(hs.reassigned >= 1, "the stalled/dead device must force reassignment");
    assert!(
        hs.dead_devices.iter().any(|d| d == "phone-flagship"),
        "phone-flagship dies entering Train"
    );
    assert!(
        hs.quorum_met,
        "transient faults must not break quorum ({} accepted, {} required)",
        hs.accepted,
        hs.quorum_required
    );
    println!(
        "chaos : {} accepted / {} dropped | {} retries, {} reassigned, {} \
         rejected uploads, {} panics, {} late | {:.0} ms",
        hs.accepted,
        hs.dropped,
        hs.retries,
        hs.reassigned,
        hs.rejected_uploads,
        hs.panics,
        hs.late_results,
        hs.wall_ms
    );

    // ---- round 3: crash mid-Train, resume from the journal --------------
    let chaos_digests = digests(&chaos);
    let keep = (hs.accepted / 2).max(1);
    let kept = truncate_after_accepts(&dir.join(JOURNAL_FILE), keep)?;
    let resume_cfg = RoundConfig { resume: true, ..chaos_cfg.clone() };
    let resumed =
        run_round(runner.manifest(), &devices, &jobs, &runner, &resume_cfg)?;
    assert_accounted("resume", &resumed, n_jobs, true);
    let rs = &resumed.summary;
    assert_eq!(
        rs.replayed, kept,
        "every accept surviving the truncation must replay, not re-run"
    );
    let resumed_digests = digests(&resumed);
    assert_eq!(
        chaos_digests, resumed_digests,
        "resumed round must reproduce every delta digest bit-identically"
    );
    println!(
        "resume: replayed {} of {} accepts from the truncated journal, \
         re-ran the rest to {} accepted | {:.0} ms (chaos round took {:.0} ms)",
        rs.replayed, hs.accepted, rs.accepted, rs.wall_ms, hs.wall_ms
    );

    // ---- report ---------------------------------------------------------
    let report = Json::obj(vec![
        ("bench", "fleet".into()),
        ("rounds", 3.into()),
        ("jobs", n_jobs.into()),
        ("devices", devices.len().into()),
        ("fault_spec", FAULT_SPEC.into()),
        // headline fields (the chaos round) + replay proof, kept flat for
        // the CI smoke job's assertions
        ("accepted", hs.accepted.into()),
        ("dropped", hs.dropped.into()),
        ("retried", (hs.retries as usize).into()),
        ("reassigned", (hs.reassigned as usize).into()),
        ("rejected_uploads", (hs.rejected_uploads as usize).into()),
        ("panics", (hs.panics as usize).into()),
        ("quorum_met", hs.quorum_met.into()),
        ("replayed", rs.replayed.into()),
        ("clean", round_json("clean", &clean)),
        ("chaos", round_json("chaos", &chaos)),
        ("resume", round_json("resume", &resumed)),
    ]);
    std::fs::write("BENCH_fleet.json", format!("{report}\n"))?;
    println!("wrote BENCH_fleet.json");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
