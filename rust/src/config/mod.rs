//! Experiment configuration files (JSON): a declarative way to run
//! pretrain + job grids without long CLI invocations. Used by the
//! `taskedge run --config <file.json>` subcommand; presets live under
//! `configs/`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::{PretrainConfig, TrainConfig};
use crate::peft::Strategy;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct JobSpec {
    pub task: String,
    pub strategy: Strategy,
}

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub model: String,
    pub seed: u64,
    pub pretrain: PretrainConfig,
    pub corpus_size: usize,
    pub train: TrainConfig,
    pub n_train: usize,
    pub n_eval: usize,
    pub jobs: Vec<JobSpec>,
    pub devices: Vec<String>,
    pub log_path: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "micro".into(),
            seed: 42,
            pretrain: PretrainConfig::default(),
            corpus_size: 2048,
            train: TrainConfig::default(),
            n_train: 256,
            n_eval: 96,
            jobs: Vec::new(),
            devices: vec!["jetson-orin-nano".into()],
            log_path: None,
        }
    }
}

fn get_f32(j: &Json, key: &str, d: f32) -> f32 {
    j.get(key).and_then(|v| v.as_f64()).map(|v| v as f32).unwrap_or(d)
}

fn get_bool(j: &Json, key: &str, d: bool) -> bool {
    j.get(key).and_then(|v| v.as_bool()).unwrap_or(d)
}

fn get_usize(j: &Json, key: &str, d: usize) -> usize {
    j.get(key).and_then(|v| v.as_usize()).unwrap_or(d)
}

impl ExperimentConfig {
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let j = Json::parse(text).context("experiment config parse error")?;
        let mut cfg = ExperimentConfig {
            model: j.get("model").and_then(|v| v.as_str()).unwrap_or("micro").into(),
            seed: j.get("seed").and_then(|v| v.as_i64()).unwrap_or(42) as u64,
            ..Default::default()
        };
        if let Some(p) = j.get("pretrain") {
            cfg.pretrain = PretrainConfig {
                steps: get_usize(p, "steps", 2000),
                lr: get_f32(p, "lr", 0.05),
                weight_decay: get_f32(p, "weight_decay", 1e-4),
                warmup_frac: get_f32(p, "warmup_frac", 0.1),
                seed: cfg.seed,
                log_every: get_usize(p, "log_every", 50),
            };
            cfg.corpus_size = get_usize(p, "corpus_size", 2048);
        }
        if let Some(t) = j.get("train") {
            cfg.train = TrainConfig {
                epochs: get_usize(t, "epochs", 10),
                lr: get_f32(t, "lr", 1e-3),
                weight_decay: get_f32(t, "weight_decay", 1e-4),
                warmup_frac: get_f32(t, "warmup_frac", 0.1),
                seed: cfg.seed,
                calib_batches: get_usize(t, "calib_batches", 8),
                eval_every: get_usize(t, "eval_every", 1),
                prepared_io: get_bool(t, "prepared_io", true),
            };
            cfg.n_train = get_usize(t, "n_train", 256);
            cfg.n_eval = get_usize(t, "n_eval", 96);
        }
        let jobs = j
            .get("jobs")
            .and_then(|v| v.as_arr())
            .context("config requires a `jobs` array")?;
        for job in jobs {
            let strategy = Strategy::parse(
                job.req("strategy")?.as_str().context("strategy")?,
            )?;
            // allow "task": "x" or "tasks": ["x", "y"] per job entry
            if let Some(tasks) = job.get("tasks").and_then(|v| v.as_arr()) {
                for t in tasks {
                    cfg.jobs.push(JobSpec {
                        task: t.as_str().context("task name")?.into(),
                        strategy: strategy.clone(),
                    });
                }
            } else {
                cfg.jobs.push(JobSpec {
                    task: job.req("task")?.as_str().context("task")?.into(),
                    strategy,
                });
            }
        }
        if cfg.jobs.is_empty() {
            bail!("config declares no jobs");
        }
        if let Some(d) = j.get("devices").and_then(|v| v.as_arr()) {
            cfg.devices = d
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect();
        }
        cfg.log_path = j.get("log").and_then(|v| v.as_str()).map(String::from);
        // validate devices + tasks eagerly so errors surface before work
        for d in &cfg.devices {
            if crate::edge::profiles::profile_by_name(d).is_none() {
                bail!("unknown device profile {d:?}");
            }
        }
        for job in &cfg.jobs {
            crate::data::task_by_name(&job.task)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "micro", "seed": 7,
      "pretrain": {"steps": 100, "lr": 0.02, "corpus_size": 512},
      "train": {"epochs": 3, "lr": 0.002, "n_train": 128, "n_eval": 64},
      "jobs": [
        {"task": "caltech101", "strategy": "taskedge:k=4"},
        {"tasks": ["dtd", "pets"], "strategy": "linear"}
      ],
      "devices": ["jetson-nano"],
      "log": "runs.jsonl"
    }"#;

    #[test]
    fn parses_sample() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.model, "micro");
        assert_eq!(c.seed, 7);
        assert_eq!(c.pretrain.steps, 100);
        assert_eq!(c.corpus_size, 512);
        assert_eq!(c.train.epochs, 3);
        assert_eq!(c.n_train, 128);
        assert_eq!(c.jobs.len(), 3);
        assert_eq!(c.jobs[1].task, "dtd");
        assert_eq!(c.devices, vec!["jetson-nano".to_string()]);
        assert_eq!(c.log_path.as_deref(), Some("runs.jsonl"));
    }

    #[test]
    fn rejects_bad_task_device_strategy() {
        assert!(ExperimentConfig::parse(
            r#"{"jobs": [{"task": "nope", "strategy": "linear"}]}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"jobs": [{"task": "dtd", "strategy": "bogus"}]}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"jobs": [{"task": "dtd", "strategy": "linear"}],
                "devices": ["warpdrive"]}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(r#"{"jobs": []}"#).is_err());
    }
}
