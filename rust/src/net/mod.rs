//! Networked fleet transport: the round engine over real TCP.
//!
//! PR 8's phased round engine ran participants as in-process threads; this
//! module splits them into separate processes speaking a length-prefixed,
//! checksummed frame protocol ([`wire`]):
//!
//! - [`server`] — the coordinator daemon (`taskedge fleet-serve`): a TCP
//!   listener with a participant registry (join/rendezvous), heartbeat
//!   liveness with deadline eviction, one-time backbone streaming, and
//!   [`server::NetRunner`] — a [`JobRunner`](crate::coordinator::JobRunner)
//!   that routes each device's work to the remote participant claiming
//!   that device name. The round engine keeps owning retries, stragglers,
//!   quorum, and the append-only journal, so the journal doubles as the
//!   crash-safe wire log: a coordinator restart with `--resume` replays
//!   accepted uploads bit-identically while participants re-attach.
//! - [`participant`] — the mostly-stateless remote worker
//!   (`taskedge participate`): a reconnect loop over the shared seeded
//!   backoff, idempotent digest-tagged `TEDL` uploads, and resume of an
//!   in-flight round after a disconnect. On primary loss it re-targets
//!   the standby address learned from welcome frames, and it refuses to
//!   fall back to a coordinator announcing a stale generation.
//! - [`standby`] — the hot-standby coordinator (`taskedge standby`):
//!   attaches to the primary, persists a snapshot plus a live stream of
//!   every journal entry (acked only after fsync — the primary blocks
//!   accepts on that ack), and promotes itself through the engine's
//!   `--resume` replay when the primary's lease expires.
//!
//! The wire-admission invariant (docs/contracts.md): no delta reaches the
//! journal without passing `taskedge::analysis` — uploads are parsed from
//! untrusted bytes here, but acceptance happens exclusively inside the
//! round engine's `accept_upload`, the same path local runs take.

pub mod participant;
pub mod server;
pub mod standby;
pub mod wire;

pub use participant::{
    participate, ParticipantOpts, ParticipantStats, WelcomeInfo,
};
pub use server::{FleetServer, NetConfig, NetRunner, NetState};
pub use standby::{
    install_shipped_journal, stand_by, StandbyOpts, StandbyReport,
};

use anyhow::{Context, Result};

use crate::coordinator::fleet::Job;
use crate::coordinator::session::TrainConfig;
use crate::data::task_by_name;
use crate::peft::Strategy;
use crate::util::json::Json;

/// The head fields describing one [`Job`] (shared by `assign` frames and
/// the `warmup` job list). Seeds travel as strings, like the journal.
pub fn job_fields(job: &Job) -> Vec<(&'static str, Json)> {
    vec![
        ("task", job.task.name.into()),
        ("strategy", job.strategy.name().into()),
        ("n_train", job.n_train.into()),
        ("n_eval", job.n_eval.into()),
        ("epochs", job.train_cfg.epochs.into()),
        ("lr", (job.train_cfg.lr as f64).into()),
        ("weight_decay", (job.train_cfg.weight_decay as f64).into()),
        ("warmup_frac", (job.train_cfg.warmup_frac as f64).into()),
        ("train_seed", job.train_cfg.seed.to_string().into()),
        ("calib_batches", job.train_cfg.calib_batches.into()),
        ("eval_every", job.train_cfg.eval_every.into()),
        ("prepared_io", job.train_cfg.prepared_io.into()),
    ]
}

/// Serialize a job as a JSON object (the `warmup` frame's job list).
pub fn job_to_json(job: &Job) -> Json {
    Json::obj(job_fields(job))
}

/// Reconstruct a [`Job`] from wire JSON. Tasks resolve against the local
/// synthetic-task registry by name — an unknown task is a hard error, not
/// a guess.
pub fn job_from_json(j: &Json) -> Result<Job> {
    let text = |k: &str| -> Result<&str> {
        j.req(k)?
            .as_str()
            .with_context(|| format!("job field {k:?} is not a string"))
    };
    let num = |k: &str| -> Result<f64> {
        j.req(k)?
            .as_f64()
            .with_context(|| format!("job field {k:?} is not a number"))
    };
    let int = |k: &str| -> Result<usize> {
        j.req(k)?
            .as_usize()
            .with_context(|| format!("job field {k:?} is not an integer"))
    };
    let task = task_by_name(text("task")?)?.clone();
    let strategy = Strategy::parse(text("strategy")?)?;
    let train_cfg = TrainConfig {
        epochs: int("epochs")?,
        lr: num("lr")? as f32,
        weight_decay: num("weight_decay")? as f32,
        warmup_frac: num("warmup_frac")? as f32,
        seed: text("train_seed")?
            .parse()
            .context("job field \"train_seed\" is not a u64 string")?,
        calib_batches: int("calib_batches")?,
        eval_every: int("eval_every")?,
        prepared_io: j
            .req("prepared_io")?
            .as_bool()
            .context("job field \"prepared_io\" is not a bool")?,
    };
    Ok(Job {
        task,
        strategy,
        train_cfg,
        n_train: int("n_train")?,
        n_eval: int("n_eval")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_round_trips_through_json() {
        let job = Job {
            task: task_by_name("syn-pets").unwrap().clone(),
            strategy: Strategy::parse("taskedge:k=2").unwrap(),
            train_cfg: TrainConfig {
                seed: u64::MAX - 3,
                epochs: 7,
                ..TrainConfig::default()
            },
            n_train: 96,
            n_eval: 32,
        };
        let j = job_to_json(&job);
        let back = job_from_json(&j).unwrap();
        assert_eq!(back.task.name, "syn-pets");
        assert_eq!(back.strategy.name(), job.strategy.name());
        assert_eq!(back.train_cfg.seed, u64::MAX - 3);
        assert_eq!(back.train_cfg.epochs, 7);
        assert_eq!(back.n_train, 96);
        assert_eq!(back.n_eval, 32);
        // and the re-serialization is identical (field order is sorted
        // by Json::obj, so this pins wire stability)
        assert_eq!(job_to_json(&back).to_string(), j.to_string());
    }

    #[test]
    fn unknown_task_or_strategy_is_a_hard_error() {
        let job = Job {
            task: task_by_name("syn-pets").unwrap().clone(),
            strategy: Strategy::parse("lora").unwrap(),
            train_cfg: TrainConfig::default(),
            n_train: 8,
            n_eval: 8,
        };
        let good = job_to_json(&job).to_string();
        let bad_task = good.replace("syn-pets", "syn-nonexistent");
        assert!(job_from_json(&Json::parse(&bad_task).unwrap()).is_err());
        let bad_strategy = good.replace("\"lora\"", "\"hypnosis\"");
        assert!(job_from_json(&Json::parse(&bad_strategy).unwrap()).is_err());
    }
}
