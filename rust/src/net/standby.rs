//! The hot-standby coordinator role (`taskedge standby`).
//!
//! A standby attaches to the primary over the same TEWF wire protocol
//! participants use (`join` with `role: "standby"`), receives a snapshot
//! of the round journal so far (`jsnap`) plus a live stream of every new
//! entry (`jship`), and persists each to its own journal file — fsynced
//! before the ack, because the primary blocks the originating journal
//! write on that ack: with a standby attached, no accept is acknowledged
//! that the standby has not made durable.
//!
//! Lease semantics: every frame from the primary (heartbeats included)
//! renews the lease. When the primary goes silent — and stays silent
//! through reconnect attempts — for [`StandbyOpts::lease_ms`], the lease
//! has expired and [`stand_by`] returns `promoted: true`. The caller then
//! completes the failover: install the shipped journal over the round's
//! delta directory ([`install_shipped_journal`]), bind the advertised
//! service address, and resume the round through the engine's `--resume`
//! replay with generation bumped past the primary's — participants
//! re-target from the welcome frame they saw earlier, and their
//! idempotent digest-tagged uploads make the handover exactly-once.
//!
//! A clean `shutdown` from the primary (frame or handshake reject) ends
//! the watch with `promoted: false`: a deliberately stopped primary is
//! not a failure to recover from.

use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::rounds::{seeded_backoff_ms, JOURNAL_FILE};

use super::wire::{self, Frame};

/// How the standby reaches the primary and what it does on takeover.
#[derive(Debug, Clone)]
pub struct StandbyOpts {
    /// The primary coordinator's address (`host:port`).
    pub primary: String,
    /// The service address this standby binds if it promotes. The primary
    /// forwards it to participants in welcome frames, so it must be
    /// reachable by them.
    pub advertise: String,
    /// Where the shipped journal is persisted (the standby's own copy).
    pub journal_path: PathBuf,
    /// Primary silent (through reconnect attempts) for this long → the
    /// lease is expired and the standby promotes.
    pub lease_ms: u64,
    /// Base backoff between reconnect attempts.
    pub backoff_ms: u64,
    /// Seed for the reconnect backoff jitter.
    pub seed: u64,
}

/// What a finished watch reports back to the promotion harness.
#[derive(Debug, Clone, Default)]
pub struct StandbyReport {
    /// The lease expired: bind, replay, resume. `false` means the
    /// primary shut down cleanly and there is nothing to take over.
    pub promoted: bool,
    /// Live entries persisted (`jship` frames acked).
    pub entries: u64,
    /// Snapshot catch-ups received (one per successful attach).
    pub snapshots: u64,
    /// Reconnect attempts made.
    pub reconnects: u64,
    /// Round identity learned from the primary's welcome, for the
    /// promoted coordinator to reuse.
    pub seed: u64,
    pub config: String,
    /// The primary's generation; a promoted standby announces
    /// `generation + 1` so participants can reject the stale primary if
    /// it returns (split-brain prevention).
    pub generation: u64,
}

/// What the primary's welcome taught us.
struct Lease {
    seed: u64,
    config: String,
    generation: u64,
}

/// Why one attached session ended.
enum SessionEnd {
    /// Clean shutdown — do not promote.
    Shutdown,
    /// Connection lost; reconnect and keep the lease ticking.
    Lost,
    /// Nothing arrived within the remaining lease.
    LeaseExpired,
}

/// Watch the primary until it shuts down cleanly or its lease expires.
/// Blocking; returns only at one of those two ends.
pub fn stand_by(opts: &StandbyOpts) -> Result<StandbyReport> {
    let mut report = StandbyReport::default();
    let mut last_contact: Option<Instant> = None;
    let mut failures: u32 = 0;

    loop {
        let deadline = last_contact
            .map(|t| t + Duration::from_millis(opts.lease_ms.max(1)));
        match attach(opts) {
            Ok((stream, lease)) => {
                failures = 0;
                last_contact = Some(Instant::now());
                report.seed = lease.seed;
                report.config = lease.config.clone();
                report.generation = lease.generation;
                match serve_session(opts, stream, &mut report, &mut last_contact)?
                {
                    SessionEnd::Shutdown => return Ok(report),
                    SessionEnd::LeaseExpired => {
                        report.promoted = true;
                        return Ok(report);
                    }
                    SessionEnd::Lost => report.reconnects += 1,
                }
            }
            Err(AttachEnd::Shutdown) => return Ok(report),
            Err(AttachEnd::Failed(e)) => {
                // before first contact there is nothing to take over; a
                // primary we never reached within one lease is an error
                let Some(deadline) = deadline else {
                    if failures as u64 * opts.backoff_ms.max(1)
                        > opts.lease_ms.max(1)
                    {
                        return Err(e.context(format!(
                            "standby never reached the primary at {}",
                            opts.primary
                        )));
                    }
                    failures += 1;
                    std::thread::sleep(Duration::from_millis(
                        seeded_backoff_ms(
                            opts.seed,
                            opts.backoff_ms,
                            "standby-reconnect",
                            failures,
                        ),
                    ));
                    continue;
                };
                if Instant::now() >= deadline {
                    report.promoted = true;
                    return Ok(report);
                }
                failures += 1;
                report.reconnects += 1;
                let wait = Duration::from_millis(seeded_backoff_ms(
                    opts.seed,
                    opts.backoff_ms,
                    "standby-reconnect",
                    failures,
                ));
                let remaining = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(wait.min(remaining));
            }
        }
    }
}

enum AttachEnd {
    /// The primary rejected us because it is shutting down.
    Shutdown,
    Failed(anyhow::Error),
}

/// One connect + handshake. `Err(Shutdown)` is the primary's clean
/// refusal; `Err(Failed)` feeds the reconnect loop.
fn attach(opts: &StandbyOpts) -> Result<(TcpStream, Lease), AttachEnd> {
    let fail = AttachEnd::Failed;
    let stream = TcpStream::connect(&opts.primary)
        .with_context(|| format!("connecting to primary {}", opts.primary))
        .map_err(fail)?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(opts.lease_ms.max(1))))
        .context("setting standby read timeout")
        .map_err(fail)?;
    let mut w = stream.try_clone().context("cloning stream").map_err(fail)?;
    let join = Frame::new(
        wire::JOIN,
        vec![
            ("role", "standby".into()),
            ("advertise", opts.advertise.as_str().into()),
        ],
    );
    join.write_to(&mut w).context("sending standby join").map_err(fail)?;
    let mut r = std::io::BufReader::new(
        stream.try_clone().context("cloning stream").map_err(fail)?,
    );
    let welcome =
        Frame::read_from(&mut r).context("reading welcome").map_err(fail)?;
    match welcome.kind() {
        wire::WELCOME => {}
        wire::REJECT => {
            let why = welcome
                .head
                .get("error")
                .and_then(crate::util::json::Json::as_str)
                .unwrap_or("unspecified");
            if why.contains("shutting down") {
                return Err(AttachEnd::Shutdown);
            }
            return Err(fail(anyhow::anyhow!("primary rejected standby: {why}")));
        }
        other => {
            return Err(fail(anyhow::anyhow!(
                "expected welcome, primary sent {other:?}"
            )));
        }
    }
    let lease = Lease {
        seed: welcome.u64_str_field("seed").map_err(fail)?,
        config: welcome.str_field("config").map_err(fail)?.to_string(),
        generation: welcome
            .head
            .get("generation")
            .and_then(crate::util::json::Json::as_usize)
            .unwrap_or(1) as u64,
    };
    Ok((stream, lease))
}

/// Serve one attached session: persist snapshots and live entries
/// (fsynced before the ack), renew the lease on every frame, and decide
/// how the session ended.
fn serve_session(
    opts: &StandbyOpts,
    stream: TcpStream,
    report: &mut StandbyReport,
    last_contact: &mut Option<Instant>,
) -> Result<SessionEnd> {
    let mut r = std::io::BufReader::new(
        stream.try_clone().context("cloning stream for reads")?,
    );
    let mut w = stream;
    let mut journal: Option<std::fs::File> = None;
    loop {
        let deadline = last_contact.unwrap_or_else(Instant::now)
            + Duration::from_millis(opts.lease_ms.max(1));
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Ok(SessionEnd::LeaseExpired);
        }
        w.set_read_timeout(Some(remaining))
            .context("renewing standby read timeout")?;
        let frame = match Frame::read_from(&mut r) {
            Ok(f) => f,
            Err(e) => {
                let timeout = e
                    .root_cause()
                    .downcast_ref::<std::io::Error>()
                    .is_some_and(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        )
                    });
                return Ok(if timeout {
                    SessionEnd::LeaseExpired
                } else {
                    SessionEnd::Lost
                });
            }
        };
        *last_contact = Some(Instant::now());
        match frame.kind() {
            wire::HEARTBEAT => {}
            wire::SHUTDOWN => return Ok(SessionEnd::Shutdown),
            wire::JSNAP => {
                // wholesale replacement: the snapshot is the journal
                let f = replace_journal(&opts.journal_path, &frame.body)?;
                journal = Some(f);
                report.snapshots += 1;
                // a failed ack is a dead link, not a standby failure —
                // the primary detaches us and a re-attach re-syncs
                if ack(&mut w, &frame).is_err() {
                    return Ok(SessionEnd::Lost);
                }
            }
            wire::JSHIP => {
                if journal.is_none() {
                    // live entry before any snapshot (shouldn't happen —
                    // the attach protocol snapshots first); open append
                    // so nothing is lost
                    journal = Some(open_append(&opts.journal_path)?);
                }
                if let Some(f) = &mut journal {
                    f.write_all(&frame.body).context("journal append")?;
                    f.write_all(b"\n").context("journal append")?;
                    f.sync_all().context("journal fsync")?;
                }
                report.entries += 1;
                if ack(&mut w, &frame).is_err() {
                    return Ok(SessionEnd::Lost);
                }
            }
            other => {
                crate::debug!("[standby] ignoring unexpected {other:?} frame");
            }
        }
    }
}

/// Ack a shipped frame by echoing its kind and `seq` back.
fn ack(w: &mut TcpStream, frame: &Frame) -> Result<()> {
    let seq = frame.usize_field("seq").unwrap_or(0);
    Frame::new(frame.kind(), vec![("seq", seq.into())])
        .write_to(w)
        .context("acking shipped entry")
}

fn replace_journal(path: &Path, body: &[u8]) -> Result<std::fs::File> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| {
                format!("creating journal dir {}", dir.display())
            })?;
        }
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .write(true)
        .truncate(true)
        .open(path)
        .with_context(|| format!("opening journal {}", path.display()))?;
    f.write_all(body).context("writing journal snapshot")?;
    f.sync_all().context("journal fsync")?;
    Ok(f)
}

fn open_append(path: &Path) -> Result<std::fs::File> {
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening journal {}", path.display()))
}

/// Promotion step 1: install the shipped journal as the round's
/// `round.journal` so the engine's `--resume` replay reads exactly what
/// the standby holds. Entries the primary journaled but never shipped
/// (e.g. under `shipdrop`) are absent by design — those jobs re-run and,
/// by the determinism contract, reproduce bit-identical deltas. Returns
/// the installed path.
pub fn install_shipped_journal(
    journal_path: &Path,
    delta_dir: &Path,
) -> Result<PathBuf> {
    std::fs::create_dir_all(delta_dir).with_context(|| {
        format!("creating delta dir {}", delta_dir.display())
    })?;
    let target = delta_dir.join(JOURNAL_FILE);
    if target != journal_path {
        std::fs::copy(journal_path, &target).with_context(|| {
            format!(
                "installing shipped journal {} -> {}",
                journal_path.display(),
                target.display()
            )
        })?;
    }
    Ok(target)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_copies_the_shipped_journal_into_place() {
        let dir = std::env::temp_dir()
            .join(format!("taskedge-standby-install-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let shipped = dir.join("shipped.journal");
        std::fs::write(&shipped, b"{\"kind\":\"header\"}\n").unwrap();
        let delta_dir = dir.join("deltas");
        let installed =
            install_shipped_journal(&shipped, &delta_dir).unwrap();
        assert_eq!(installed, delta_dir.join(JOURNAL_FILE));
        assert_eq!(
            std::fs::read(&installed).unwrap(),
            b"{\"kind\":\"header\"}\n"
        );
        // installing onto itself is a no-op, not a truncation
        let again =
            install_shipped_journal(&installed, &delta_dir).unwrap();
        assert_eq!(
            std::fs::read(&again).unwrap(),
            b"{\"kind\":\"header\"}\n"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
