//! The remote participant (`taskedge participate`): a mostly-stateless
//! worker that joins a coordinator, streams the backbone once, runs
//! assigned jobs, and uploads digest-tagged `TEDL` deltas.
//!
//! Robustness model: the process keeps only what determinism lets it keep
//! across reconnects — the streamed backbone (keyed by digest), the built
//! runner (keyed by `seed|config|digest`), completed uploads (deltas are a
//! pure function of `(job, seed)`, so a re-assign after a coordinator
//! restart re-sends cached bytes instead of re-training), and the one
//! not-yet-acked upload frame, re-sent verbatim on re-attach. Everything
//! else — scheduling, retries, quorum, the journal — lives coordinator-side.
//!
//! TCP is the retransmission layer: a lost `upload_ok` can only mean the
//! connection died, so the reconnect handshake (resend `unacked`) is the
//! only resend path needed; there is no timer-based retry.

use std::collections::{BTreeSet, HashMap};
use std::io::BufReader;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::faults::FaultPlan;
use crate::coordinator::rounds::{seeded_backoff_ms, JobRunner, RoundState};
use crate::edge::profiles::profile_by_name;
use crate::edge::DeviceProfile;
use crate::util::hash::fnv1a64_hex;
use crate::util::json::Json;
use crate::util::signal;

use super::job_from_json;
use super::wire::{self, Frame};

/// How long the participant waits for the `welcome` after sending `join`.
const HANDSHAKE_TIMEOUT_MS: u64 = 5_000;
/// Heartbeat-thread poll granularity (so it notices `alive` flips fast).
const POLL_MS: u64 = 20;

pub struct ParticipantOpts {
    /// Coordinator address, e.g. `127.0.0.1:7700`.
    pub addr: String,
    /// Device profile name this participant claims (must exist in the
    /// local *and* coordinator profile tables).
    pub device: String,
    /// Seed for the reconnect backoff jitter (shared helper with the
    /// round engine, so backoff sequences are reproducible).
    pub seed: u64,
    /// Base reconnect backoff in ms (exponential, seeded jitter).
    pub backoff_ms: u64,
    /// Consecutive failed connection attempts before giving up. A
    /// successful attach resets the counter — a flaky-but-reachable
    /// coordinator never exhausts it.
    pub max_reconnects: u32,
    /// Exit after the first completed round (`done` frame) instead of
    /// waiting for the next one.
    pub once: bool,
    /// Heartbeat period override in ms; 0 means "use what the welcome
    /// frame suggests" (a third of the coordinator's eviction deadline).
    pub heartbeat_ms: u64,
    /// Participant-side fault injection: `disconnect=DEV@PHASE` clauses
    /// drop the connection once when the named phase is announced.
    pub faults: FaultPlan,
}

impl Default for ParticipantOpts {
    fn default() -> Self {
        ParticipantOpts {
            addr: "127.0.0.1:7700".to_string(),
            device: String::new(),
            seed: 42,
            backoff_ms: 200,
            max_reconnects: 8,
            once: false,
            heartbeat_ms: 0,
            faults: FaultPlan::default(),
        }
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct ParticipantStats {
    /// Deltas trained and uploaded.
    pub uploads: usize,
    /// Assigns answered from the upload cache (no re-training).
    pub reuploads: usize,
    /// Connection attempts after the first.
    pub reconnects: usize,
    /// Warmup requests served.
    pub warmups: usize,
    /// Assigned attempts that failed locally (reported via `runfail`).
    pub failures: usize,
    /// `done` frames seen (completed rounds).
    pub rounds: usize,
}

/// What the coordinator's `welcome` frame told us.
pub struct WelcomeInfo {
    pub seed: u64,
    pub config: String,
    pub backbone_digest: String,
    pub phase: RoundState,
    pub heartbeat_ms: u64,
    /// Coordinator generation (fresh primary starts at 1; a promoted
    /// standby announces the old primary's generation + 1). Absent in
    /// pre-HA welcomes, which parse as generation 1.
    pub generation: u64,
    /// Failover target advertised by the coordinator, if a hot standby
    /// is attached. Learned lazily — a standby attaching mid-round is
    /// announced by a broadcast `welcome` refresh.
    pub standby_addr: Option<String>,
}

/// The participant's view of the coordinator fleet, surviving reconnects:
/// which addresses are worth dialing and the highest generation witnessed.
/// On a connection failure the loop rotates to the next target, so losing
/// the primary re-targets the standby within one backoff period; a
/// coordinator announcing a generation *below* the maximum seen is a
/// stale, not-yet-dead ex-primary and is rejected (split-brain guard).
struct FleetView {
    targets: Vec<String>,
    next: usize,
    max_generation: u64,
}

impl FleetView {
    fn new(primary: &str) -> Self {
        FleetView {
            targets: vec![primary.to_string()],
            next: 0,
            max_generation: 0,
        }
    }

    /// The address the next connection attempt should dial.
    fn target(&self) -> &str {
        &self.targets[self.next % self.targets.len()]
    }

    /// A connection failed; dial the next known coordinator.
    fn rotate(&mut self) {
        self.next = (self.next + 1) % self.targets.len();
    }

    /// Absorb what a welcome told us: remember the advertised standby as
    /// a dial target and ratchet the generation floor. Fails if the
    /// welcome's generation is below that floor — the peer is a stale
    /// coordinator that lost a completed failover.
    fn absorb(&mut self, welcome: &WelcomeInfo, addr: &str) -> Result<()> {
        if welcome.generation < self.max_generation {
            bail!(
                "coordinator {addr} announces stale generation {} (fleet \
                 is at {}); refusing to attach",
                welcome.generation,
                self.max_generation
            );
        }
        self.max_generation = welcome.generation;
        if let Some(s) = &welcome.standby_addr {
            if !s.is_empty() && !self.targets.iter().any(|t| t == s) {
                self.targets.push(s.clone());
            }
        }
        Ok(())
    }
}

/// Why one connection ended.
enum Exit {
    /// Round complete and `once` was set.
    Done,
    /// Coordinator announced a graceful shutdown.
    Shutdown,
    /// An injected `disconnect=` fault fired; reconnect immediately.
    Reconnect,
    /// Coordinator refused the join — terminal, retrying cannot help.
    Rejected(String),
}

/// State that survives reconnects (see the module docs for why each piece
/// is safe to keep).
struct Session {
    /// `(digest, bytes)` of the streamed backbone.
    backbone: Option<(String, Vec<u8>)>,
    /// Runner keyed by the welcome identity `seed|config|digest`.
    runner: Option<(String, Box<dyn JobRunner>)>,
    /// Completed uploads by `task|strategy` — attempt-independent by the
    /// determinism contract.
    cache: HashMap<String, CachedUpload>,
    /// The last upload/runfail not yet acked, re-sent verbatim on attach.
    unacked: Option<Unacked>,
    /// Phase names whose `disconnect=` fault already fired (once per
    /// process, or reconnecting would re-trigger it forever).
    fired: BTreeSet<String>,
}

struct Unacked {
    task: String,
    strategy: String,
    attempt: usize,
    frame: Frame,
}

struct CachedUpload {
    digest: String,
    bytes: Vec<u8>,
    top1: f64,
    top5: f64,
    trainable_frac: f64,
    sim_energy_j: f64,
    sim_step_ms: f64,
}

fn cache_key(task: &str, strategy: &str) -> String {
    format!("{task}|{strategy}")
}

/// Build the idempotent `upload` frame for a cached result. The digest in
/// the head is the FNV-1a of the body, checked end-to-end by the server.
fn upload_frame(
    task: &str,
    strategy: &str,
    attempt: usize,
    c: &CachedUpload,
) -> Frame {
    Frame::with_body(
        wire::UPLOAD,
        vec![
            ("task", task.into()),
            ("strategy", strategy.into()),
            ("attempt", attempt.into()),
            ("digest", c.digest.as_str().into()),
            ("top1", c.top1.into()),
            ("top5", c.top5.into()),
            ("trainable_frac", c.trainable_frac.into()),
            ("sim_energy_j", c.sim_energy_j.into()),
            ("sim_step_ms", c.sim_step_ms.into()),
        ],
        c.bytes.clone(),
    )
}

fn parse_welcome(f: &Frame) -> Result<WelcomeInfo> {
    Ok(WelcomeInfo {
        seed: f.u64_str_field("seed")?,
        config: f.str_field("config")?.to_string(),
        backbone_digest: f.str_field("backbone_digest")?.to_string(),
        phase: RoundState::parse(f.str_field("phase")?)?,
        heartbeat_ms: f.usize_field("heartbeat_ms")? as u64,
        generation: f.usize_field("generation").unwrap_or(1) as u64,
        standby_addr: f.str_field("standby").ok().map(str::to_string),
    })
}

/// Serialize a frame onto the shared write half. The heartbeat thread and
/// the dispatch loop both write, so the stream sits behind a mutex. A
/// poisoned lock means the other writer panicked mid-frame — the stream
/// may hold a torn frame, so surface it as a connection failure (feeding
/// the reconnect loop) instead of cascading the panic.
fn send(wire: &Mutex<TcpStream>, frame: &Frame) -> Result<()> {
    let mut wire = match wire.lock() {
        Ok(w) => w,
        Err(_) => bail!("wire write lock poisoned; dropping the connection"),
    };
    frame.write_to(&mut *wire)
}

/// Run the participant loop until the coordinator finishes or shuts down.
///
/// `make_runner` is called (rarely — only when the welcome identity
/// `seed|config|backbone_digest` changes) to build the local [`JobRunner`];
/// `taskedge participate` passes a closure producing either a `SimRunner`
/// or a real `SessionRunner` over the streamed backbone.
pub fn participate<F>(
    opts: &ParticipantOpts,
    mut make_runner: F,
) -> Result<ParticipantStats>
where
    F: FnMut(&WelcomeInfo, Option<&[u8]>) -> Result<Box<dyn JobRunner>>,
{
    let dev = profile_by_name(&opts.device).with_context(|| {
        format!("unknown device profile {:?}", opts.device)
    })?;
    let mut stats = ParticipantStats::default();
    let mut sess = Session {
        backbone: None,
        runner: None,
        cache: HashMap::new(),
        unacked: None,
        fired: BTreeSet::new(),
    };
    let mut view = FleetView::new(&opts.addr);
    let mut failures: u32 = 0;
    let mut first = true;
    loop {
        if signal::stop_requested() {
            crate::info!("[participant] stop requested; exiting");
            return Ok(stats);
        }
        if !first {
            stats.reconnects += 1;
            let ms = seeded_backoff_ms(
                opts.seed,
                opts.backoff_ms,
                "reconnect",
                failures.max(1),
            );
            std::thread::sleep(Duration::from_millis(ms));
        }
        first = false;
        match serve_connection(
            opts,
            dev,
            &mut sess,
            &mut view,
            &mut make_runner,
            &mut stats,
            &mut failures,
        ) {
            Ok(Exit::Done) | Ok(Exit::Shutdown) => return Ok(stats),
            Ok(Exit::Rejected(why)) => {
                // a re-join racing the coordinator's shutdown is a clean
                // end of service, not a terminal error — same contract the
                // standby applies to its own handshake
                if why.contains("shutting down") {
                    crate::info!(
                        "[participant] {}: coordinator is shutting down; \
                         exiting",
                        opts.device
                    );
                    return Ok(stats);
                }
                bail!("coordinator rejected this participant: {why}")
            }
            Ok(Exit::Reconnect) => {
                failures = 0;
                crate::info!(
                    "[participant] {}: injected disconnect; reconnecting",
                    opts.device
                );
            }
            Err(e) => {
                failures += 1;
                if failures > opts.max_reconnects {
                    return Err(e.context(format!(
                        "giving up after {} consecutive failed connections",
                        failures
                    )));
                }
                // a dead or stale coordinator is not coming back soon —
                // rotate so the next attempt dials the advertised standby
                view.rotate();
                crate::info!(
                    "[participant] {}: connection ended ({e:#}); retry \
                     {failures}/{} against {}",
                    opts.device,
                    opts.max_reconnects,
                    view.target()
                );
            }
        }
    }
}

/// One connection: handshake, backbone sync, then serve frames until the
/// coordinator finishes, dies, or an injected fault cuts the link.
#[allow(clippy::too_many_arguments)]
fn serve_connection<F>(
    opts: &ParticipantOpts,
    dev: &'static DeviceProfile,
    sess: &mut Session,
    view: &mut FleetView,
    make_runner: &mut F,
    stats: &mut ParticipantStats,
    failures: &mut u32,
) -> Result<Exit>
where
    F: FnMut(&WelcomeInfo, Option<&[u8]>) -> Result<Box<dyn JobRunner>>,
{
    let addr = view.target().to_string();
    let stream = TcpStream::connect(&addr)
        .with_context(|| format!("connecting to coordinator {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(
        stream.try_clone().context("cloning stream for reads")?,
    );
    let wire = Arc::new(Mutex::new(
        stream.try_clone().context("cloning stream for writes")?,
    ));

    send(
        &wire,
        &Frame::new(wire::JOIN, vec![("device", opts.device.as_str().into())]),
    )
    .context("sending join")?;
    stream
        .set_read_timeout(Some(Duration::from_millis(HANDSHAKE_TIMEOUT_MS)))
        .context("setting handshake timeout")?;
    let hello = Frame::read_from(&mut reader).context("reading welcome")?;
    if hello.kind() == wire::REJECT {
        let why = hello.str_field("error").unwrap_or("unspecified").to_string();
        return Ok(Exit::Rejected(why));
    }
    if hello.kind() != wire::WELCOME {
        bail!("expected welcome, got {:?}", hello.kind());
    }
    let welcome = parse_welcome(&hello).context("malformed welcome")?;
    // generation gate first: a stale ex-primary that lost a failover must
    // not be attached to, even if its welcome is otherwise well-formed
    view.absorb(&welcome, &addr)?;
    // the handshake landed: `max_reconnects` bounds *consecutive* failed
    // connections, so a participant surviving many coordinator restarts
    // over a long campaign never spuriously gives up
    *failures = 0;
    stream
        .set_read_timeout(None)
        .context("clearing handshake timeout")?;

    // heartbeat thread: keeps this participant out of the coordinator's
    // eviction sweep while the dispatch loop is busy training
    let hb_ms = if opts.heartbeat_ms > 0 {
        opts.heartbeat_ms
    } else {
        welcome.heartbeat_ms.max(POLL_MS)
    };
    let alive = Arc::new(AtomicBool::new(true));
    let hb = std::thread::spawn({
        let wire = wire.clone();
        let alive = alive.clone();
        move || {
            while alive.load(Ordering::SeqCst) {
                let mut slept = 0u64;
                while slept < hb_ms && alive.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(POLL_MS));
                    slept += POLL_MS;
                }
                if !alive.load(Ordering::SeqCst) {
                    break;
                }
                if send(&wire, &Frame::new(wire::HEARTBEAT, vec![])).is_err() {
                    break;
                }
            }
        }
    });

    let result = serve_frames(
        opts, dev, sess, view, make_runner, stats, &welcome, &mut reader,
        &wire,
    );

    alive.store(false, Ordering::SeqCst);
    let _ = stream.shutdown(Shutdown::Both);
    let _ = hb.join();
    result
}

/// Should an injected `disconnect=` fault fire for this phase? Fires at
/// most once per process per phase, or every reconnect would re-trigger it.
fn disconnect_fires(
    opts: &ParticipantOpts,
    sess: &mut Session,
    phase: RoundState,
) -> bool {
    opts.faults.disconnects_at(&opts.device, phase)
        && sess.fired.insert(phase.name().to_string())
}

#[allow(clippy::too_many_arguments)]
fn serve_frames<F>(
    opts: &ParticipantOpts,
    dev: &'static DeviceProfile,
    sess: &mut Session,
    view: &mut FleetView,
    make_runner: &mut F,
    stats: &mut ParticipantStats,
    welcome: &WelcomeInfo,
    reader: &mut impl std::io::Read,
    wire: &Mutex<TcpStream>,
) -> Result<Exit>
where
    F: FnMut(&WelcomeInfo, Option<&[u8]>) -> Result<Box<dyn JobRunner>>,
{
    // a late joiner may attach mid-phase; the injected disconnect must
    // still fire exactly once even if the phase broadcast already happened
    if disconnect_fires(opts, sess, welcome.phase) {
        return Ok(Exit::Reconnect);
    }

    // --- backbone sync: fetch once per digest, keep across reconnects ---
    let mut queued: Vec<Frame> = Vec::new();
    if welcome.backbone_digest != super::server::NO_BACKBONE {
        let have = sess
            .backbone
            .as_ref()
            .is_some_and(|(d, _)| *d == welcome.backbone_digest);
        if !have {
            send(wire, &Frame::new(wire::NEED_BACKBONE, vec![]))
                .context("requesting backbone")?;
            loop {
                let f = Frame::read_from(reader).context("streaming backbone")?;
                if f.kind() != wire::BACKBONE {
                    // broadcasts can interleave with the stream; replay later
                    queued.push(f);
                    continue;
                }
                let got = fnv1a64_hex(&f.body);
                if got != welcome.backbone_digest {
                    bail!(
                        "backbone digest mismatch: welcome promised {}, \
                         stream hashes to {got}",
                        welcome.backbone_digest
                    );
                }
                sess.backbone = Some((got, f.body));
                break;
            }
        }
    }

    // --- runner: rebuild only when the round identity changed ---
    let ident = format!(
        "{}|{}|{}",
        welcome.seed, welcome.config, welcome.backbone_digest
    );
    if sess.runner.as_ref().map(|(i, _)| i.as_str()) != Some(ident.as_str()) {
        let bytes = sess.backbone.as_ref().map(|(_, b)| b.as_slice());
        let runner = make_runner(welcome, bytes).context("building the runner")?;
        sess.runner = Some((ident, runner));
        // cached deltas are a function of (job, seed, backbone): a new
        // round identity invalidates them, and any unacked upload with it
        sess.cache.clear();
        sess.unacked = None;
    }

    // --- resume: re-send the unacked upload from before the disconnect ---
    if let Some(u) = &sess.unacked {
        send(wire, &u.frame).context("re-sending unacked upload")?;
        crate::info!(
            "[participant] {}: re-sent unacked upload {}/{} attempt {}",
            opts.device,
            u.task,
            u.strategy,
            u.attempt
        );
    }

    // --- dispatch ---
    loop {
        let frame = if queued.is_empty() {
            Frame::read_from(reader).context("reading from coordinator")?
        } else {
            queued.remove(0)
        };
        match frame.kind() {
            wire::PHASE => {
                let phase = RoundState::parse(frame.str_field("phase")?)?;
                if disconnect_fires(opts, sess, phase) {
                    return Ok(Exit::Reconnect);
                }
            }
            wire::WARMUP => {
                let jobs = frame
                    .head
                    .get("jobs")
                    .and_then(Json::as_arr)
                    .context("warmup frame has no job list")?
                    .iter()
                    .map(job_from_json)
                    .collect::<Result<Vec<_>>>()
                    .context("warmup frame carries a malformed job")?;
                let error = match &sess.runner {
                    Some((_, runner)) => {
                        runner.warmup(dev, &jobs).err().map(|e| format!("{e:#}"))
                    }
                    None => Some("participant has no runner".to_string()),
                };
                stats.warmups += 1;
                let mut fields: Vec<(&str, Json)> =
                    vec![("device", opts.device.as_str().into())];
                if let Some(e) = &error {
                    fields.push(("error", e.as_str().into()));
                }
                send(wire, &Frame::new(wire::WARMED, fields))
                    .context("sending warmup ack")?;
            }
            wire::ASSIGN => {
                let job = job_from_json(&frame.head)
                    .context("assign frame carries a malformed job")?;
                let attempt = frame.usize_field("attempt")?;
                let task = job.task.name.to_string();
                let strategy = job.strategy.name();
                let key = cache_key(&task, &strategy);
                if !sess.cache.contains_key(&key) {
                    let ran = match &sess.runner {
                        Some((_, runner)) => runner.run(&job, dev, attempt as u32),
                        None => Err(anyhow::anyhow!("participant has no runner")),
                    };
                    match ran {
                        Ok(out) => {
                            let bytes = out.delta.to_bytes()?;
                            let digest = fnv1a64_hex(&bytes);
                            sess.cache.insert(
                                key.clone(),
                                CachedUpload {
                                    digest,
                                    bytes,
                                    top1: out.top1,
                                    top5: out.top5,
                                    trainable_frac: out.trainable_frac,
                                    sim_energy_j: out.sim_energy_j,
                                    sim_step_ms: out.sim_step_ms,
                                },
                            );
                            stats.uploads += 1;
                        }
                        Err(e) => {
                            stats.failures += 1;
                            send(
                                wire,
                                &Frame::new(
                                    wire::RUNFAIL,
                                    vec![
                                        ("task", task.as_str().into()),
                                        ("strategy", strategy.as_str().into()),
                                        ("attempt", attempt.into()),
                                        (
                                            "error",
                                            format!("{e:#}").as_str().into(),
                                        ),
                                    ],
                                ),
                            )
                            .context("reporting a failed attempt")?;
                            continue;
                        }
                    }
                } else {
                    // deterministic re-assign (coordinator restart or
                    // retry): answer from cache, no re-training
                    stats.reuploads += 1;
                }
                let cached = sess
                    .cache
                    .get(&key)
                    .context("upload cache lost a just-inserted entry")?;
                // injected `stall=DEV:MS` delays the *send*, not the
                // training: the window where the coordinator's heartbeat
                // sweeper can evict us while an upload is still in hand
                let stall = opts.faults.stall_ms(&opts.device);
                if stall > 0 {
                    std::thread::sleep(Duration::from_millis(stall));
                }
                let up = upload_frame(&task, &strategy, attempt, cached);
                send(wire, &up).context("uploading delta")?;
                sess.unacked =
                    Some(Unacked { task, strategy, attempt, frame: up });
            }
            wire::UPLOAD_OK => {
                let acked = sess.unacked.as_ref().is_some_and(|u| {
                    frame.str_field("task").is_ok_and(|t| t == u.task)
                        && frame
                            .str_field("strategy")
                            .is_ok_and(|s| s == u.strategy)
                        && frame
                            .usize_field("attempt")
                            .is_ok_and(|a| a == u.attempt)
                });
                if acked {
                    sess.unacked = None;
                }
            }
            wire::DONE => {
                stats.rounds += 1;
                if opts.once {
                    return Ok(Exit::Done);
                }
            }
            wire::SHUTDOWN => return Ok(Exit::Shutdown),
            wire::WELCOME => {
                // broadcast refresh: a standby attached (or detached) —
                // learn the failover target and the generation floor
                let refreshed =
                    parse_welcome(&frame).context("malformed welcome")?;
                view.absorb(&refreshed, "the attached coordinator")?;
            }
            wire::BACKBONE => {} // duplicate stream tail; ignore
            other => {
                crate::debug!(
                    "[participant] {}: ignoring unexpected {other:?} frame",
                    opts.device
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CachedUpload {
        CachedUpload {
            digest: fnv1a64_hex(b"delta-bytes"),
            bytes: b"delta-bytes".to_vec(),
            top1: 0.625,
            top5: 0.875,
            trainable_frac: 0.0125,
            sim_energy_j: 1.5,
            sim_step_ms: 12.0,
        }
    }

    #[test]
    fn upload_frame_digest_matches_body() {
        let f = upload_frame("syn-pets", "lora", 3, &sample());
        assert_eq!(f.kind(), wire::UPLOAD);
        assert_eq!(f.str_field("digest").unwrap(), fnv1a64_hex(&f.body));
        assert_eq!(f.str_field("task").unwrap(), "syn-pets");
        assert_eq!(f.usize_field("attempt").unwrap(), 3);
        assert_eq!(f.f64_field("top1").unwrap(), 0.625);
    }

    #[test]
    fn upload_frames_are_attempt_tagged_but_byte_stable_otherwise() {
        let a = upload_frame("syn-pets", "lora", 1, &sample());
        let b = upload_frame("syn-pets", "lora", 1, &sample());
        assert_eq!(a.encode().unwrap(), b.encode().unwrap());
        let c = upload_frame("syn-pets", "lora", 2, &sample());
        assert_ne!(a.encode().unwrap(), c.encode().unwrap());
    }

    #[test]
    fn welcome_round_trips() {
        let f = Frame::new(
            wire::WELCOME,
            vec![
                ("seed", (u64::MAX - 11).to_string().as_str().into()),
                ("config", "vit-s16".into()),
                ("backbone_digest", "abc123".into()),
                ("phase", "warmup".into()),
                ("heartbeat_ms", 250usize.into()),
            ],
        );
        let w = parse_welcome(&f).unwrap();
        assert_eq!(w.seed, u64::MAX - 11);
        assert_eq!(w.config, "vit-s16");
        assert_eq!(w.backbone_digest, "abc123");
        assert_eq!(w.phase, RoundState::Warmup);
        assert_eq!(w.heartbeat_ms, 250);
        // pre-HA welcome: generation defaults, no standby advertised
        assert_eq!(w.generation, 1);
        assert!(w.standby_addr.is_none());
    }

    #[test]
    fn welcome_carries_generation_and_standby() {
        let f = Frame::new(
            wire::WELCOME,
            vec![
                ("seed", "7".into()),
                ("config", "vit-s16".into()),
                ("backbone_digest", "abc123".into()),
                ("phase", "join".into()),
                ("heartbeat_ms", 250usize.into()),
                ("generation", 3usize.into()),
                ("standby", "127.0.0.1:7711".into()),
            ],
        );
        let w = parse_welcome(&f).unwrap();
        assert_eq!(w.generation, 3);
        assert_eq!(w.standby_addr.as_deref(), Some("127.0.0.1:7711"));
    }

    fn welcome_at(generation: u64, standby: Option<&str>) -> WelcomeInfo {
        WelcomeInfo {
            seed: 7,
            config: "vit-s16".to_string(),
            backbone_digest: "abc123".to_string(),
            phase: RoundState::Join,
            heartbeat_ms: 250,
            generation,
            standby_addr: standby.map(str::to_string),
        }
    }

    #[test]
    fn fleet_view_learns_standby_and_rotates_on_failure() {
        let mut v = FleetView::new("primary:1");
        assert_eq!(v.target(), "primary:1");
        v.absorb(&welcome_at(1, Some("standby:2")), "primary:1").unwrap();
        assert_eq!(v.targets, vec!["primary:1", "standby:2"]);
        // learning the same standby twice does not duplicate it
        v.absorb(&welcome_at(1, Some("standby:2")), "primary:1").unwrap();
        assert_eq!(v.targets.len(), 2);
        // still attached to the primary until a failure rotates us
        assert_eq!(v.target(), "primary:1");
        v.rotate();
        assert_eq!(v.target(), "standby:2");
        v.rotate();
        assert_eq!(v.target(), "primary:1");
    }

    #[test]
    fn fleet_view_rejects_stale_generations() {
        let mut v = FleetView::new("primary:1");
        v.absorb(&welcome_at(2, Some("standby:2")), "standby:2").unwrap();
        assert_eq!(v.max_generation, 2);
        // the old primary comes back announcing its pre-failover
        // generation: refuse, or two coordinators would run the round
        let err = v.absorb(&welcome_at(1, None), "primary:1").unwrap_err();
        assert!(err.to_string().contains("stale generation"), "{err:#}");
        // equal or newer generations are fine
        v.absorb(&welcome_at(2, None), "standby:2").unwrap();
        v.absorb(&welcome_at(3, None), "standby:2").unwrap();
        assert_eq!(v.max_generation, 3);
    }
}
