//! The TaskEdge wire format: length-prefixed, checksummed binary frames.
//!
//! Every message between the coordinator daemon and a participant is one
//! frame:
//!
//! ```text
//! b"TEWF" | u16 version | u32 payload_len | u64 fnv1a64(payload) | payload
//! payload = u32 head_len | UTF-8 JSON head | raw binary body
//! ```
//!
//! The JSON head carries the message kind (`"kind"` field) and small
//! metadata; bulk bytes (a `TEPT` backbone checkpoint, a `TEDL` delta
//! upload) ride in the body untouched, so the bytes a participant uploads
//! are byte-identical to what it would have written to disk — which is
//! what lets the round journal vouch for network uploads with the same
//! digest it uses for local drains.
//!
//! Robustness rules, pinned by the tests below:
//!
//! - `payload_len` is validated against [`MAX_FRAME`] *before* any
//!   allocation — a hostile or corrupted length prefix fails cleanly.
//! - The checksum covers the whole payload. A mismatch (or bad magic, or
//!   an unknown version) is **connection-fatal**: framing is lost, so the
//!   only safe recovery is to drop the connection and reconnect. Both
//!   sides treat it that way.
//! - Seeds travel as strings (`u64` does not survive a round-trip through
//!   JSON `f64`), matching the round journal's convention.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::hash::fnv1a64;
use crate::util::json::Json;

pub const MAGIC: &[u8; 4] = b"TEWF"; // TaskEdge Wire Frame
pub const VERSION: u16 = 1;

/// Hard cap on a frame payload. The largest legitimate frame is a
/// backbone checkpoint (tens of MB for the paper-scale ViT); 256 MiB
/// leaves headroom without letting a corrupted length prefix drive an
/// unbounded allocation.
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Fixed-size prefix before the payload: magic + version + len + checksum.
pub const HEADER_LEN: usize = 4 + 2 + 4 + 8;

// -- message kinds (the head's "kind" field) --------------------------------

/// participant → coordinator: claim a device slot (`device`, `token`).
pub const JOIN: &str = "join";
/// coordinator → participant: join accepted (`seed`, `config`,
/// `backbone_digest`, `phase`).
pub const WELCOME: &str = "welcome";
/// coordinator → participant: join refused (`error`); connection closes.
pub const REJECT: &str = "reject";
/// participant → coordinator: cached backbone digest mismatch — stream it.
pub const NEED_BACKBONE: &str = "need_backbone";
/// coordinator → participant: body is a `TEPT` checkpoint (`digest`).
pub const BACKBONE: &str = "backbone";
/// coordinator → participant: round phase broadcast (`phase`).
pub const PHASE: &str = "phase";
/// coordinator → participant: run warmup for the round's strategies.
pub const WARMUP: &str = "warmup";
/// participant → coordinator: warmup finished (`error` present on failure).
pub const WARMED: &str = "warmed";
/// participant → coordinator: liveness beacon (`device`).
pub const HEARTBEAT: &str = "heartbeat";
/// coordinator → participant: run one attempt (`task`, `strategy`,
/// `attempt`, `n_train`, `n_eval`, `seed`, train-config fields).
pub const ASSIGN: &str = "assign";
/// participant → coordinator: body is the `TEDL` delta for an assign
/// (`task`, `strategy`, `attempt`, `digest`, metric fields).
pub const UPLOAD: &str = "upload";
/// coordinator → participant: upload delivered intact (`task`,
/// `strategy`, `attempt`). Transport-level only — admission happens in
/// the round engine, and a rejected delta comes back as a fresh assign.
pub const UPLOAD_OK: &str = "upload_ok";
/// participant → coordinator: an attempt failed locally (`task`,
/// `strategy`, `attempt`, `error`).
pub const RUNFAIL: &str = "runfail";
/// coordinator → participant: round over; disconnect or await the next.
pub const DONE: &str = "done";
/// coordinator → participant: daemon is shutting down for good.
pub const SHUTDOWN: &str = "shutdown";
/// coordinator → standby: snapshot catch-up on attach (`entries`; body is
/// the journal shipped so far, newline-delimited). The standby replaces
/// its local copy wholesale and acks with the same `seq`.
pub const JSNAP: &str = "jsnap";
/// coordinator → standby: one live round-journal line (`seq`; body is the
/// JSONL line bytes). Shipped synchronously **before** the originating
/// journal write returns to the round engine, so — with a standby
/// attached — no accept is acknowledged that the standby has not
/// persisted. The standby appends, fsyncs, and acks with the same `seq`.
pub const JSHIP: &str = "jship";

/// One wire message: a JSON head plus an opaque binary body.
#[derive(Debug, Clone)]
pub struct Frame {
    pub head: Json,
    pub body: Vec<u8>,
}

impl Frame {
    /// A body-less frame of `kind` with the given head fields.
    pub fn new(kind: &str, fields: Vec<(&str, Json)>) -> Frame {
        Frame::with_body(kind, fields, Vec::new())
    }

    /// A frame of `kind` carrying bulk `body` bytes.
    pub fn with_body(
        kind: &str,
        mut fields: Vec<(&str, Json)>,
        body: Vec<u8>,
    ) -> Frame {
        fields.insert(0, ("kind", kind.into()));
        Frame { head: Json::obj(fields), body }
    }

    /// The message kind; `""` for a head without one (never valid).
    pub fn kind(&self) -> &str {
        self.head.get("kind").and_then(Json::as_str).unwrap_or("")
    }

    /// Required string field from the head.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.head
            .req(key)?
            .as_str()
            .with_context(|| format!("frame field {key:?} is not a string"))
    }

    /// Required numeric field from the head.
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.head
            .req(key)?
            .as_f64()
            .with_context(|| format!("frame field {key:?} is not a number"))
    }

    /// Required non-negative integer field from the head.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.head
            .req(key)?
            .as_usize()
            .with_context(|| format!("frame field {key:?} is not an integer"))
    }

    /// Required seed-style field: a `u64` serialized as a string.
    pub fn u64_str_field(&self, key: &str) -> Result<u64> {
        self.str_field(key)?
            .parse()
            .with_context(|| format!("frame field {key:?} is not a u64 string"))
    }

    /// Serialize to the full on-wire byte sequence.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let head = self.head.to_string().into_bytes();
        let payload_len = 4 + head.len() + self.body.len();
        if payload_len > MAX_FRAME {
            bail!(
                "frame payload {payload_len} bytes exceeds MAX_FRAME \
                 ({MAX_FRAME})"
            );
        }
        let mut payload = Vec::with_capacity(payload_len);
        payload.extend_from_slice(&(head.len() as u32).to_le_bytes());
        payload.extend_from_slice(&head);
        payload.extend_from_slice(&self.body);

        let mut buf = Vec::with_capacity(HEADER_LEN + payload_len);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
        buf.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        buf.extend_from_slice(&payload);
        Ok(buf)
    }

    /// Write the frame and flush (frames are the flush boundary).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(&self.encode()?)?;
        w.flush()?;
        Ok(())
    }

    /// Read one frame. Any error here — magic, version, length, checksum,
    /// head parse — means framing is lost and the connection must be
    /// dropped; there is no resynchronization inside a stream.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame> {
        let mut hdr = [0u8; HEADER_LEN];
        r.read_exact(&mut hdr).context("reading frame header")?;
        if &hdr[0..4] != MAGIC {
            bail!("bad frame magic (stream out of sync)");
        }
        let ver = u16::from_le_bytes([hdr[4], hdr[5]]);
        if ver != VERSION {
            bail!("unsupported wire version {ver} (want {VERSION})");
        }
        let payload_len =
            u32::from_le_bytes([hdr[6], hdr[7], hdr[8], hdr[9]]) as usize;
        if payload_len > MAX_FRAME {
            bail!(
                "frame payload {payload_len} bytes exceeds MAX_FRAME \
                 ({MAX_FRAME})"
            );
        }
        if payload_len < 4 {
            bail!("frame payload {payload_len} bytes is too short for a head");
        }
        let want = u64::from_le_bytes([
            hdr[10], hdr[11], hdr[12], hdr[13], hdr[14], hdr[15], hdr[16],
            hdr[17],
        ]);
        let mut payload = vec![0u8; payload_len];
        r.read_exact(&mut payload).context("reading frame payload")?;
        if fnv1a64(&payload) != want {
            bail!("frame checksum mismatch (corrupted on the wire)");
        }
        let head_len =
            u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]])
                as usize;
        if 4 + head_len > payload_len {
            bail!(
                "frame head {head_len} bytes overruns the payload \
                 ({payload_len} bytes)"
            );
        }
        let head = std::str::from_utf8(&payload[4..4 + head_len])
            .context("frame head is not UTF-8")?;
        let head = Json::parse(head)
            .map_err(|e| anyhow::anyhow!("frame head is not valid JSON: {e}"))?;
        let body = payload[4 + head_len..].to_vec();
        Ok(Frame { head, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame::with_body(
            UPLOAD,
            vec![
                ("task", "syn-pets".into()),
                ("strategy", "lora".into()),
                ("attempt", 2usize.into()),
                ("top1", 0.75.into()),
            ],
            b"TEDL-payload-bytes".to_vec(),
        )
    }

    #[test]
    fn round_trips_head_and_body() {
        let f = sample();
        let bytes = f.encode().unwrap();
        let g = Frame::read_from(&mut &bytes[..]).unwrap();
        assert_eq!(g.kind(), UPLOAD);
        assert_eq!(g.str_field("task").unwrap(), "syn-pets");
        assert_eq!(g.usize_field("attempt").unwrap(), 2);
        assert_eq!(g.f64_field("top1").unwrap(), 0.75);
        assert_eq!(g.body, b"TEDL-payload-bytes");
        // and the re-encoding is byte-identical (head keys are sorted)
        assert_eq!(g.encode().unwrap(), bytes);
    }

    #[test]
    fn empty_body_frames_work() {
        let f = Frame::new(HEARTBEAT, vec![("device", "pi".into())]);
        let bytes = f.encode().unwrap();
        let g = Frame::read_from(&mut &bytes[..]).unwrap();
        assert_eq!(g.kind(), HEARTBEAT);
        assert!(g.body.is_empty());
    }

    #[test]
    fn seeds_survive_as_strings() {
        let seed = u64::MAX - 7;
        let f = Frame::new(WELCOME, vec![("seed", seed.to_string().into())]);
        let bytes = f.encode().unwrap();
        let g = Frame::read_from(&mut &bytes[..]).unwrap();
        assert_eq!(g.u64_str_field("seed").unwrap(), seed);
    }

    #[test]
    fn corruption_is_detected_everywhere() {
        let bytes = sample().encode().unwrap();
        // flip every single byte position in turn: each one must either
        // fail (magic/version/len/checksum/head) — never parse silently
        // into different content
        for i in 0..bytes.len() {
            let mut b = bytes.clone();
            b[i] ^= 0x40;
            match Frame::read_from(&mut &b[..]) {
                Err(_) => {}
                Ok(g) => {
                    // a flip in the length prefix could only "succeed" by
                    // also consuming different bytes — impossible with a
                    // checksum over the payload; so success means the flip
                    // round-tripped to identical content, which is a bug
                    assert_eq!(
                        g.encode().unwrap(),
                        bytes,
                        "flip at byte {i} silently changed the frame"
                    );
                }
            }
        }
    }

    #[test]
    fn truncation_is_an_error() {
        let bytes = sample().encode().unwrap();
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN, bytes.len() - 1] {
            assert!(
                Frame::read_from(&mut &bytes[..cut]).is_err(),
                "truncation at {cut} parsed"
            );
        }
    }

    #[test]
    fn oversize_length_prefix_fails_before_allocating() {
        let mut b = sample().encode().unwrap();
        // claim a payload just over MAX_FRAME
        b[6..10].copy_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let err = Frame::read_from(&mut &b[..]).unwrap_err().to_string();
        assert!(err.contains("MAX_FRAME"), "{err}");
    }

    #[test]
    fn version_skew_is_rejected() {
        let mut b = sample().encode().unwrap();
        b[4..6].copy_from_slice(&2u16.to_le_bytes());
        let err = Frame::read_from(&mut &b[..]).unwrap_err().to_string();
        assert!(err.contains("unsupported wire version"), "{err}");
    }

    #[test]
    fn frames_stream_back_to_back() {
        let a = Frame::new(PHASE, vec![("phase", "train".into())]);
        let b = sample();
        let mut stream = a.encode().unwrap();
        stream.extend_from_slice(&b.encode().unwrap());
        let mut r = &stream[..];
        assert_eq!(Frame::read_from(&mut r).unwrap().kind(), PHASE);
        assert_eq!(Frame::read_from(&mut r).unwrap().kind(), UPLOAD);
        assert!(r.is_empty());
    }
}
