//! The coordinator daemon: TCP listener, participant registry, heartbeat
//! eviction, and [`NetRunner`] — the bridge that lets the round engine
//! (`coordinator::rounds::run_round`) drive remote participants exactly
//! like in-process workers.
//!
//! Threading model: one accept loop, one eviction sweeper, and per
//! connection a reader thread (the connection handler itself) plus a
//! writer thread that owns the write half and applies wire-level fault
//! injection. All shared state lives in [`NetState`] behind independent
//! mutexes (`peers`, `pending`, `uploads`) that are never held across
//! each other — a guard is always dropped before the next lock is taken,
//! so the declared lock order is satisfied trivially.
//!
//! Ack semantics: `upload_ok` is **transport-level** ("delivered and
//! consumed — stop resending"). Acceptance or rejection of the delta is
//! decided by the round engine's `accept_upload` (which runs
//! `taskedge::analysis` checks), the same path local rounds take; a
//! rejected upload surfaces to the participant as a fresh `assign`.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::faults::FaultPlan;
use crate::coordinator::fleet::Job;
use crate::coordinator::rounds::{JobRunner, RoundState, RunOutput};
use crate::edge::profiles::profile_by_name;
use crate::edge::{admit, Admission, DeviceProfile};
use crate::peft::{self, MemoryFootprint};
use crate::runtime::Manifest;
use crate::util::hash::fnv1a64_hex;
use crate::util::json::Json;
use crate::vit::TaskDelta;

use super::wire::{self, Frame};
use super::{job_fields, job_to_json};

/// How long a connection gets to send its `join` frame.
const HANDSHAKE_TIMEOUT_MS: u64 = 5_000;
/// Accept/sweeper poll granularity.
const POLL_MS: u64 = 20;
/// How long the coordinator waits for a standby to ack a shipped entry
/// before declaring it dead and detaching it (the round proceeds without
/// replication rather than stalling behind a hung standby).
const SHIP_ACK_TIMEOUT_MS: u64 = 5_000;

/// Digest sentinel for "this round has no backbone to stream" (sim mode).
pub const NO_BACKBONE: &str = "none";

// ---------------------------------------------------------------------------
// Shared state
// ---------------------------------------------------------------------------

/// What a connection's writer thread is told to do.
enum WriterCmd {
    Send(Box<Frame>),
    Close,
}

/// A live participant. `id` disambiguates reconnects: a stale reader
/// thread may only clean up the registry entry it created.
struct Peer {
    id: u64,
    tx: Sender<WriterCmd>,
    last_seen: Instant,
}

/// Reply routed from a reader thread to a blocked [`NetRunner`] call.
enum Reply {
    Output(Box<RunOutput>),
    Fail(String),
    Warmed(Option<String>),
}

/// One outstanding request the engine is waiting on, keyed by
/// [`run_key`] / [`warmup_key`]. `dev` lets a disconnect fail exactly the
/// requests routed to that participant.
struct PendingSlot {
    dev: String,
    tx: Sender<Reply>,
}

/// Daemon construction parameters.
pub struct NetConfig {
    /// Model config name participants should run (`welcome.config`).
    pub config_name: String,
    /// Round seed (`welcome.seed`) — remote runners derive deltas from it.
    pub seed: u64,
    /// A participant silent for this long is evicted and its in-flight
    /// requests failed (the engine retries them).
    pub heartbeat_timeout_ms: u64,
    /// Wire-level fault injection (netdrop/netdup/netcorrupt/netdelay),
    /// applied by every connection's writer thread.
    pub faults: FaultPlan,
    /// Serialized `TEPT` backbone to stream to participants that ask
    /// (`need_backbone`); `None` for sim rounds.
    pub backbone: Option<Vec<u8>>,
    /// Coordinator generation, carried in every welcome frame. A fresh
    /// primary is generation 1; a promoted standby announces the
    /// primary's generation + 1, and participants refuse to fall back to
    /// any coordinator announcing a generation below the highest they
    /// have seen — which is what locks a returning stale primary out
    /// (split-brain prevention).
    pub generation: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            config_name: "sim".to_string(),
            seed: 42,
            heartbeat_timeout_ms: 3_000,
            faults: FaultPlan::default(),
            backbone: None,
            generation: 1,
        }
    }
}

/// All coordinator-side connection state, shared between the listener,
/// the sweeper, per-connection threads, and [`NetRunner`].
pub struct NetState {
    config_name: String,
    seed: u64,
    heartbeat_timeout_ms: u64,
    faults: FaultPlan,
    backbone_bytes: Vec<u8>,
    backbone_digest: String,
    /// Current round phase (`RoundState` as u8) so late joiners' welcome
    /// frames carry it.
    phase: AtomicU8,
    stop: AtomicBool,
    next_peer: AtomicU64,
    peers: Mutex<HashMap<String, Peer>>,
    /// Signalled (with the `peers` guard) whenever a participant attaches.
    joined: Condvar,
    pending: Mutex<HashMap<String, PendingSlot>>,
    /// Upload dedupe log: key → digest. A re-sent upload for a completed
    /// key is acked but not re-processed (idempotence); a duplicate with a
    /// *different* digest is a determinism violation and is logged.
    uploads: Mutex<HashMap<String, String>>,
    generation: u64,
    /// Journal replication to the hot standby. A leaf lock (ranked after
    /// `wire` in the xtask ordering): nothing else is ever acquired while
    /// it is held, and the synchronous ack round-trip inside it is bounded
    /// by [`SHIP_ACK_TIMEOUT_MS`].
    ship: Mutex<Ship>,
}

/// Replication state: the full shipped log (the `jsnap` catch-up payload
/// for a late-attaching standby) plus the live link, if one is attached.
struct Ship {
    log: Vec<String>,
    seq: u64,
    link: Option<ShipLink>,
}

/// The attached standby's connection. The write half and the buffered
/// read half both live here: every exchange with the standby is a
/// request/response under the `ship` lock, so no reader thread ever
/// touches this stream.
struct ShipLink {
    w: TcpStream,
    r: std::io::BufReader<TcpStream>,
    /// The service address the standby will bind if it promotes —
    /// forwarded to participants in welcome frames so they know where to
    /// re-target on primary loss.
    addr: String,
    id: u64,
}

fn run_key(task: &str, strategy: &str, attempt: usize) -> String {
    format!("run|{task}|{strategy}|{attempt}")
}

fn warmup_key(device: &str) -> String {
    format!("warmup|{device}")
}

fn phase_to_u8(p: RoundState) -> u8 {
    match p {
        RoundState::Join => 0,
        RoundState::Warmup => 1,
        RoundState::Train => 2,
        RoundState::Collect => 3,
        RoundState::Cooldown => 4,
    }
}

fn phase_from_u8(v: u8) -> RoundState {
    match v {
        0 => RoundState::Join,
        1 => RoundState::Warmup,
        2 => RoundState::Train,
        3 => RoundState::Collect,
        _ => RoundState::Cooldown,
    }
}

impl NetState {
    pub fn new(cfg: NetConfig) -> Arc<NetState> {
        let backbone_bytes = cfg.backbone.unwrap_or_default();
        let backbone_digest = if backbone_bytes.is_empty() {
            NO_BACKBONE.to_string()
        } else {
            fnv1a64_hex(&backbone_bytes)
        };
        Arc::new(NetState {
            config_name: cfg.config_name,
            seed: cfg.seed,
            heartbeat_timeout_ms: cfg.heartbeat_timeout_ms.max(1),
            faults: cfg.faults,
            backbone_bytes,
            backbone_digest,
            phase: AtomicU8::new(phase_to_u8(RoundState::Join)),
            stop: AtomicBool::new(false),
            next_peer: AtomicU64::new(0),
            peers: Mutex::new(HashMap::new()),
            joined: Condvar::new(),
            pending: Mutex::new(HashMap::new()),
            uploads: Mutex::new(HashMap::new()),
            generation: cfg.generation.max(1),
            ship: Mutex::new(Ship { log: Vec::new(), seq: 0, link: None }),
        })
    }

    pub fn config_name(&self) -> &str {
        &self.config_name
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    fn set_phase(&self, p: RoundState) {
        self.phase.store(phase_to_u8(p), Ordering::SeqCst);
    }

    fn phase(&self) -> RoundState {
        phase_from_u8(self.phase.load(Ordering::SeqCst))
    }

    /// Names of currently-attached participants.
    pub fn attached(&self) -> Vec<String> {
        let peers = self.peers.lock().unwrap();
        peers.keys().cloned().collect()
    }

    /// Block until `n` distinct participants are attached (rendezvous
    /// before starting a round).
    pub fn await_participants(
        &self,
        n: usize,
        timeout: Duration,
    ) -> Result<Vec<String>> {
        let deadline = Instant::now() + timeout;
        let mut peers = self.peers.lock().unwrap();
        loop {
            if peers.len() >= n {
                return Ok(peers.keys().cloned().collect());
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "only {}/{n} participants joined within {timeout:?} \
                     (have: {:?})",
                    peers.len(),
                    peers.keys().collect::<Vec<_>>()
                );
            }
            let (guard, _) = self
                .joined
                .wait_timeout(peers, deadline - now)
                .unwrap();
            peers = guard;
        }
    }

    /// Block until the participant claiming `device` is attached, and
    /// return a handle to its writer queue.
    fn await_attach(
        &self,
        device: &str,
        timeout: Duration,
    ) -> Result<Sender<WriterCmd>> {
        let deadline = Instant::now() + timeout;
        let mut peers = self.peers.lock().unwrap();
        loop {
            if let Some(p) = peers.get(device) {
                return Ok(p.tx.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("participant {device:?} not attached within {timeout:?}");
            }
            let (guard, _) = self
                .joined
                .wait_timeout(peers, deadline - now)
                .unwrap();
            peers = guard;
        }
    }

    fn touch(&self, device: &str, id: u64) {
        let mut peers = self.peers.lock().unwrap();
        if let Some(p) = peers.get_mut(device) {
            if p.id == id {
                p.last_seen = Instant::now();
            }
        }
    }

    fn insert_pending(&self, key: String, dev: &str, tx: Sender<Reply>) {
        let mut pending = self.pending.lock().unwrap();
        pending.insert(key, PendingSlot { dev: dev.to_string(), tx });
    }

    fn remove_pending(&self, key: &str) {
        let mut pending = self.pending.lock().unwrap();
        pending.remove(key);
    }

    fn complete(&self, key: &str, reply: Reply) {
        let slot = {
            let mut pending = self.pending.lock().unwrap();
            pending.remove(key)
        };
        if let Some(slot) = slot {
            let _ = slot.tx.send(reply);
        }
    }

    /// Fail every pending request routed to `device` (it disconnected or
    /// was evicted); the engine retries them on re-attach.
    fn fail_pending(&self, device: &str, why: &str) {
        let failed: Vec<PendingSlot> = {
            let mut pending = self.pending.lock().unwrap();
            let keys: Vec<String> = pending
                .iter()
                .filter(|(_, s)| s.dev == device)
                .map(|(k, _)| k.clone())
                .collect();
            keys.into_iter().filter_map(|k| pending.remove(&k)).collect()
        };
        for slot in failed {
            let _ = slot.tx.send(Reply::Fail(why.to_string()));
        }
    }

    fn broadcast(&self, frame: &Frame) {
        let txs: Vec<Sender<WriterCmd>> = {
            let peers = self.peers.lock().unwrap();
            peers.values().map(|p| p.tx.clone()).collect()
        };
        for tx in txs {
            let _ = tx.send(WriterCmd::Send(Box::new(frame.clone())));
        }
    }

    fn close_all(&self) {
        let txs: Vec<Sender<WriterCmd>> = {
            let mut peers = self.peers.lock().unwrap();
            peers.drain().map(|(_, p)| p.tx).collect()
        };
        for tx in txs {
            let _ = tx.send(WriterCmd::Close);
        }
    }

    fn evict_stale(&self) {
        let deadline = Duration::from_millis(self.heartbeat_timeout_ms);
        let evicted: Vec<(String, Peer)> = {
            let mut peers = self.peers.lock().unwrap();
            let stale: Vec<String> = peers
                .iter()
                .filter(|(_, p)| p.last_seen.elapsed() >= deadline)
                .map(|(d, _)| d.clone())
                .collect();
            stale
                .into_iter()
                .filter_map(|d| peers.remove(&d).map(|p| (d, p)))
                .collect()
        };
        for (dev, peer) in evicted {
            crate::info!(
                "[net] evicting {dev}: silent for {} ms",
                self.heartbeat_timeout_ms
            );
            let _ = peer.tx.send(WriterCmd::Close);
            self.fail_pending(&dev, "participant evicted (heartbeat deadline)");
        }
    }

    /// Handle an `upload` frame from `device`. Always acks delivery (so
    /// the participant stops resending), dedupes by key, and routes the
    /// parsed result to the engine's pending slot.
    fn handle_upload(&self, device: &str, frame: &Frame, tx: &Sender<WriterCmd>) {
        let (task, strategy, attempt) = match (
            frame.str_field("task"),
            frame.str_field("strategy"),
            frame.usize_field("attempt"),
        ) {
            (Ok(t), Ok(s), Ok(a)) => (t.to_string(), s.to_string(), a),
            _ => {
                crate::info!("[net] {device}: malformed upload head; ignored");
                return;
            }
        };
        let ack = Frame::new(
            wire::UPLOAD_OK,
            vec![
                ("task", task.as_str().into()),
                ("strategy", strategy.as_str().into()),
                ("attempt", attempt.into()),
            ],
        );
        let _ = tx.send(WriterCmd::Send(Box::new(ack)));

        let key = run_key(&task, &strategy, attempt);
        let digest = frame
            .head
            .get("digest")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        {
            let mut uploads = self.uploads.lock().unwrap();
            if let Some(prev) = uploads.get(&key) {
                if *prev != digest {
                    crate::info!(
                        "[net] {device}: duplicate upload for {key} with a \
                         DIFFERENT digest ({prev} vs {digest}) — determinism \
                         violation; keeping the first"
                    );
                }
                return; // ack-lost resend: already delivered once
            }
            uploads.insert(key.clone(), digest);
        }
        self.complete(&key, parse_upload(frame));
    }

    /// This coordinator's generation (see [`NetConfig::generation`]).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The attached standby's advertised service address, if any.
    pub fn standby_addr(&self) -> Option<String> {
        let ship = self.ship.lock().unwrap();
        ship.link.as_ref().map(|l| l.addr.clone())
    }

    /// The welcome frame for the coordinator's current state: round
    /// identity, phase, lease interval, generation, and — when a standby
    /// is attached — its advertised address. Sent on join and
    /// re-broadcast whenever a standby attaches, so participants always
    /// know where to re-target on primary loss.
    fn welcome_frame(&self) -> Frame {
        let mut fields: Vec<(&str, Json)> = vec![
            ("seed", self.seed.to_string().into()),
            ("config", self.config_name.as_str().into()),
            ("backbone_digest", self.backbone_digest.as_str().into()),
            ("phase", self.phase().name().into()),
            (
                "heartbeat_ms",
                ((self.heartbeat_timeout_ms / 3).max(10) as usize).into(),
            ),
            ("generation", (self.generation as usize).into()),
        ];
        if let Some(addr) = self.standby_addr() {
            fields.push(("standby", Json::Str(addr)));
        }
        Frame::new(wire::WELCOME, fields)
    }

    /// The round engine's journal-shipping hook: every journal line lands
    /// here synchronously, after its local durable write and before the
    /// engine proceeds. Infallible outward — a dead or hung standby is
    /// detached, never an error the round sees.
    pub fn journal_shipper(self: &Arc<Self>) -> crate::coordinator::rounds::JournalShipper {
        let st = self.clone();
        crate::coordinator::rounds::JournalShipper(Arc::new(move |line: &str| {
            st.ship_entry(line);
        }))
    }

    /// Record one journal line in the ship log and replicate it to the
    /// attached standby (blocking on its ack). The `shipdrop` fault
    /// silently loses the frame *after* logging — the standby's journal
    /// gains a hole exactly like a real lost packet, and the affected job
    /// re-runs deterministically if the standby ever promotes.
    fn ship_entry(&self, line: &str) {
        let mut ship = self.ship.lock().unwrap();
        ship.seq += 1;
        let seq = ship.seq;
        ship.log.push(line.to_string());
        if ship.link.is_none() {
            return;
        }
        if self.faults.ship_drops(seq) {
            crate::info!("[net] shipdrop fault: journal entry {seq} lost");
            return;
        }
        let frame = Frame::with_body(
            wire::JSHIP,
            vec![("seq", (seq as usize).into())],
            line.as_bytes().to_vec(),
        );
        if !ship_round_trip(&mut ship, &frame, seq) {
            crate::info!("[net] standby detached (ship entry {seq} unacked)");
        }
    }

    /// Attach a standby: under the ship lock, send the full snapshot so
    /// far and install the live link once it is acked. Holding the lock
    /// across the catch-up is the no-gap guarantee — a journal entry
    /// written during attach blocks until the snapshot (which will
    /// include it) completes, then ships live.
    fn attach_standby(
        &self,
        w: TcpStream,
        r: std::io::BufReader<TcpStream>,
        addr: String,
        id: u64,
    ) -> Result<()> {
        w.set_read_timeout(Some(Duration::from_millis(SHIP_ACK_TIMEOUT_MS)))
            .context("setting standby ack timeout")?;
        let mut ship = self.ship.lock().unwrap();
        if let Some(old) = ship.link.take() {
            crate::info!(
                "[net] standby replaced by a new attach (old peer {})",
                old.id
            );
        }
        let mut body = ship.log.join("\n").into_bytes();
        if !body.is_empty() {
            body.push(b'\n');
        }
        let seq = ship.seq;
        let snap = Frame::with_body(
            wire::JSNAP,
            vec![
                ("seq", (seq as usize).into()),
                ("entries", ship.log.len().into()),
            ],
            body,
        );
        ship.link = Some(ShipLink { w, r, addr, id });
        if !ship_round_trip(&mut ship, &snap, seq) {
            bail!("standby never acked the journal snapshot");
        }
        Ok(())
    }

    /// Renew the standby's lease. Returns false once this handler's link
    /// is gone (detached on error, or replaced by a newer attach).
    fn ship_heartbeat(&self, id: u64) -> bool {
        let mut ship = self.ship.lock().unwrap();
        match &mut ship.link {
            Some(l) if l.id == id => {
                let hb = Frame::new(wire::HEARTBEAT, vec![]);
                if hb.write_to(&mut l.w).is_err() {
                    ship.link = None;
                    crate::info!("[net] standby detached (heartbeat failed)");
                    return false;
                }
                true
            }
            _ => false,
        }
    }

    /// Drop the standby link. Graceful close sends `shutdown` first so
    /// the standby exits instead of promoting; a kill just severs the
    /// connection, exactly like a crashed primary.
    fn ship_close(&self, graceful: bool) {
        let mut ship = self.ship.lock().unwrap();
        if let Some(mut l) = ship.link.take() {
            if graceful {
                let _ = Frame::new(wire::SHUTDOWN, vec![]).write_to(&mut l.w);
            }
        }
    }
}

/// Send one frame to the standby and wait for its matching ack (`seq`
/// echoed back). Any failure — write, timeout, bad ack — detaches the
/// link and returns false; replication degrades, the round continues.
fn ship_round_trip(ship: &mut Ship, frame: &Frame, seq: u64) -> bool {
    let Some(l) = &mut ship.link else { return false };
    let ok = frame.write_to(&mut l.w).is_ok()
        && matches!(
            Frame::read_from(&mut l.r),
            Ok(ack) if ack.head.get("seq").and_then(Json::as_usize)
                == Some(seq as usize)
        );
    if !ok {
        ship.link = None;
    }
    ok
}

/// Parse an upload into the engine's reply: end-to-end digest check, then
/// a structural `TEDL` parse from the untrusted bytes. `Fail` here means
/// the engine records a failed attempt and retries — nothing touches the
/// journal.
fn parse_upload(frame: &Frame) -> Reply {
    let want = match frame.str_field("digest") {
        Ok(d) => d.to_string(),
        Err(e) => return Reply::Fail(format!("{e:#}")),
    };
    let got = fnv1a64_hex(&frame.body);
    if got != want {
        return Reply::Fail(format!(
            "upload digest mismatch: head says {want}, body hashes to {got}"
        ));
    }
    let delta = match TaskDelta::from_bytes(&frame.body) {
        Ok(d) => d,
        Err(e) => return Reply::Fail(format!("unparseable delta upload: {e:#}")),
    };
    let metric = |k: &str| frame.f64_field(k);
    match (
        metric("top1"),
        metric("top5"),
        metric("trainable_frac"),
        metric("sim_energy_j"),
        metric("sim_step_ms"),
    ) {
        (Ok(top1), Ok(top5), Ok(trainable_frac), Ok(sim_energy_j), Ok(sim_step_ms)) => {
            Reply::Output(Box::new(RunOutput {
                top1,
                top5,
                trainable_frac,
                sim_energy_j,
                sim_step_ms,
                delta,
            }))
        }
        _ => Reply::Fail("upload head is missing metric fields".to_string()),
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// The listening daemon. Dropping it shuts everything down (participants
/// get `shutdown`); use [`FleetServer::kill`] to simulate a crash instead.
pub struct FleetServer {
    pub addr: SocketAddr,
    state: Arc<NetState>,
    accept: Option<JoinHandle<()>>,
    sweeper: Option<JoinHandle<()>>,
}

impl FleetServer {
    /// Bind and start accepting. `bind_addr` like `"127.0.0.1:0"` picks a
    /// free port — read it back from [`FleetServer::addr`].
    pub fn start(bind_addr: &str, state: Arc<NetState>) -> Result<FleetServer> {
        let listener = bind_reuse(bind_addr)?;
        let addr = listener.local_addr().context("reading bound address")?;
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let st = state.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, st));
        let st = state.clone();
        let sweeper = std::thread::spawn(move || sweeper_loop(st));
        crate::info!("[net] fleet coordinator listening on {addr}");
        Ok(FleetServer {
            addr,
            state,
            accept: Some(accept),
            sweeper: Some(sweeper),
        })
    }

    pub fn state(&self) -> Arc<NetState> {
        self.state.clone()
    }

    /// Rendezvous: block until `n` participants are attached.
    pub fn await_participants(
        &self,
        n: usize,
        timeout: Duration,
    ) -> Result<Vec<String>> {
        self.state.await_participants(n, timeout)
    }

    /// Graceful shutdown: stop admitting, tell every participant, close
    /// all connections, join the service threads.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() && self.sweeper.is_none() {
            return;
        }
        self.state.stop.store(true, Ordering::SeqCst);
        self.state.broadcast(&Frame::new(wire::SHUTDOWN, vec![]));
        self.state.close_all();
        self.state.ship_close(true);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }

    /// Crash simulation: drop every connection without a `shutdown` frame
    /// so participants treat it as a network failure and reconnect — the
    /// restart-with-`--resume` path in tests and the chaos bench.
    pub fn kill(&mut self) {
        if self.accept.is_none() && self.sweeper.is_none() {
            return;
        }
        self.state.stop.store(true, Ordering::SeqCst);
        self.state.close_all();
        self.state.ship_close(false);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind with `SO_REUSEADDR`, so a restarted coordinator (`--resume`) can
/// reclaim its port immediately: connection sockets from the previous
/// incarnation linger in `TIME_WAIT` for a minute after a crash or
/// shutdown, and a plain `TcpListener::bind` would fail with
/// `EADDRINUSE` until they expire. The offline build has no `socket2`
/// (and std exposes no builder), so on Linux the listener is created
/// through the raw libc calls libstd already links — same trick as
/// `util::signal`. Other targets fall back to the plain bind.
fn bind_reuse(bind_addr: &str) -> Result<TcpListener> {
    let sa: SocketAddr = bind_addr
        .parse()
        .with_context(|| format!("invalid bind address {bind_addr:?}"))?;
    #[cfg(target_os = "linux")]
    if let SocketAddr::V4(v4) = sa {
        return bind_reuse_v4(&v4);
    }
    TcpListener::bind(sa).with_context(|| format!("binding {bind_addr}"))
}

#[cfg(target_os = "linux")]
fn bind_reuse_v4(v4: &std::net::SocketAddrV4) -> Result<TcpListener> {
    use std::os::unix::io::FromRawFd;

    /// `struct sockaddr_in` (Linux layout: 16-bit family first).
    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        sin_port: u16,   // network byte order
        sin_addr: u32,   // network byte order
        sin_zero: [u8; 8],
    }
    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const i32,
            len: u32,
        ) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }
    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;

    let os_err = || std::io::Error::last_os_error();
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            bail!("socket() failed: {}", os_err());
        }
        let one: i32 = 1;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, 4) != 0 {
            let e = os_err();
            close(fd);
            bail!("setsockopt(SO_REUSEADDR) failed: {e}");
        }
        let addr = SockaddrIn {
            sin_family: AF_INET as u16,
            sin_port: v4.port().to_be(),
            // octets are already network order; reassemble byte-for-byte
            sin_addr: u32::from_ne_bytes(v4.ip().octets()),
            sin_zero: [0; 8],
        };
        let len = std::mem::size_of::<SockaddrIn>() as u32;
        if bind(fd, &addr, len) != 0 {
            let e = os_err();
            close(fd);
            bail!("binding {v4} failed: {e}");
        }
        if listen(fd, 128) != 0 {
            let e = os_err();
            close(fd);
            bail!("listen() on {v4} failed: {e}");
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

fn accept_loop(listener: TcpListener, state: Arc<NetState>) {
    while !state.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let st = state.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, st) {
                        crate::debug!("[net] connection ended: {e:#}");
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(POLL_MS));
            }
            Err(e) => {
                crate::info!("[net] accept error: {e}");
                std::thread::sleep(Duration::from_millis(POLL_MS));
            }
        }
    }
}

fn sweeper_loop(state: Arc<NetState>) {
    let period_ms = (state.heartbeat_timeout_ms / 2).max(POLL_MS);
    let mut slept = 0u64;
    while !state.stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(POLL_MS));
        slept += POLL_MS;
        if slept >= period_ms {
            slept = 0;
            state.evict_stale();
        }
    }
}

/// Per-connection reader: handshake, register, then serve frames until
/// the connection dies. Participant connections get a paired writer
/// thread owning the write half; a standby connection is handed to
/// [`handle_standby`] instead (its writes are request/response under the
/// `ship` lock, so it needs no writer thread and takes no wire faults —
/// replication fidelity is exercised by the dedicated `shipdrop` fault).
fn handle_conn(stream: TcpStream, state: Arc<NetState>) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(HANDSHAKE_TIMEOUT_MS)))
        .context("setting handshake timeout")?;
    let mut reader = std::io::BufReader::new(
        stream.try_clone().context("cloning stream for reads")?,
    );
    let join =
        Frame::read_from(&mut reader).context("reading join frame")?;
    if join.head.get("role").and_then(Json::as_str) == Some("standby") {
        return handle_standby(stream, reader, state, &join);
    }

    let write_half = stream.try_clone().context("cloning stream for writes")?;
    let (tx, rx) = channel::<WriterCmd>();
    let writer = std::thread::spawn({
        let faults = state.faults.clone();
        move || writer_loop(write_half, rx, faults)
    });
    let reject = |msg: String| {
        let f = Frame::new(wire::REJECT, vec![("error", msg.as_str().into())]);
        let _ = tx.send(WriterCmd::Send(Box::new(f)));
        let _ = tx.send(WriterCmd::Close);
    };

    let device = join
        .str_field("device")
        .map(str::to_string)
        .unwrap_or_default();
    if join.kind() != wire::JOIN
        || device.is_empty()
        || profile_by_name(&device).is_none()
        || state.stop.load(Ordering::SeqCst)
    {
        let msg = if state.stop.load(Ordering::SeqCst) {
            "coordinator is shutting down".to_string()
        } else if join.kind() != wire::JOIN {
            format!("expected a join frame, got {:?}", join.kind())
        } else {
            format!(
                "unknown device {device:?} (no such profile on the \
                 coordinator)"
            )
        };
        reject(msg.clone());
        let _ = writer.join();
        bail!("join rejected: {msg}");
    }

    // register, replacing any stale claim for the same device name —
    // reconnects must not wait out the eviction deadline
    let id = state.next_peer.fetch_add(1, Ordering::SeqCst) + 1;
    let old = {
        let mut peers = state.peers.lock().unwrap();
        let old = peers.insert(
            device.clone(),
            Peer { id, tx: tx.clone(), last_seen: Instant::now() },
        );
        state.joined.notify_all();
        old
    };
    if let Some(old) = old {
        crate::info!("[net] {device}: reconnected; closing the stale link");
        let _ = old.tx.send(WriterCmd::Close);
    }
    stream
        .set_read_timeout(None)
        .context("clearing handshake timeout")?;

    let _ = tx.send(WriterCmd::Send(Box::new(state.welcome_frame())));
    crate::info!("[net] participant {device} joined (peer {id})");

    let served = serve_peer(&mut reader, &state, &device, id, &tx);

    // cleanup: deregister only the entry we created (a reconnect may have
    // replaced it already), then fail our in-flight requests
    let removed = {
        let mut peers = state.peers.lock().unwrap();
        match peers.get(&device) {
            Some(p) if p.id == id => {
                peers.remove(&device);
                true
            }
            _ => false,
        }
    };
    if removed {
        state.fail_pending(&device, "participant disconnected");
        crate::info!("[net] participant {device} detached (peer {id})");
    }
    let _ = tx.send(WriterCmd::Close);
    drop(tx);
    let _ = writer.join();
    served
}

fn serve_peer(
    reader: &mut impl std::io::Read,
    state: &NetState,
    device: &str,
    id: u64,
    tx: &Sender<WriterCmd>,
) -> Result<()> {
    loop {
        if state.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let frame = Frame::read_from(reader)
            .with_context(|| format!("reading from participant {device}"))?;
        state.touch(device, id);
        match frame.kind() {
            wire::HEARTBEAT => {}
            wire::NEED_BACKBONE => {
                let f = Frame::with_body(
                    wire::BACKBONE,
                    vec![("digest", state.backbone_digest.as_str().into())],
                    state.backbone_bytes.clone(),
                );
                let _ = tx.send(WriterCmd::Send(Box::new(f)));
            }
            wire::WARMED => {
                let error = frame
                    .head
                    .get("error")
                    .and_then(Json::as_str)
                    .map(str::to_string);
                state.complete(&warmup_key(device), Reply::Warmed(error));
            }
            wire::UPLOAD => state.handle_upload(device, &frame, tx),
            wire::RUNFAIL => {
                let key = run_key(
                    frame.str_field("task")?,
                    frame.str_field("strategy")?,
                    frame.usize_field("attempt")?,
                );
                let error = frame.str_field("error")?.to_string();
                state.complete(&key, Reply::Fail(error));
            }
            other => {
                crate::info!(
                    "[net] participant {device} sent unexpected {other:?}; \
                     ignored"
                );
            }
        }
    }
}

/// A standby's connection: welcome it, hand the socket to the ship state
/// (snapshot catch-up + live stream happen under the `ship` lock), then
/// renew its lease with heartbeats until it detaches or the daemon
/// stops. This thread never reads the socket — acks are consumed by the
/// shipping round-trips.
fn handle_standby(
    stream: TcpStream,
    reader: std::io::BufReader<TcpStream>,
    state: Arc<NetState>,
    join: &Frame,
) -> Result<()> {
    let mut w = stream;
    let reject = |w: &mut TcpStream, msg: &str| {
        let f = Frame::new(wire::REJECT, vec![("error", msg.into())]);
        let _ = f.write_to(w);
    };
    if state.stop.load(Ordering::SeqCst) {
        reject(&mut w, "coordinator is shutting down");
        bail!("standby join rejected: coordinator is shutting down");
    }
    let Ok(advertise) = join.str_field("advertise").map(str::to_string)
    else {
        reject(&mut w, "standby join is missing its \"advertise\" address");
        bail!("standby join without an advertise address");
    };
    let id = state.next_peer.fetch_add(1, Ordering::SeqCst) + 1;
    let hb_ms = (state.heartbeat_timeout_ms / 3).max(10);
    let welcome = Frame::new(
        wire::WELCOME,
        vec![
            ("seed", state.seed.to_string().into()),
            ("config", state.config_name.as_str().into()),
            ("generation", (state.generation as usize).into()),
            ("heartbeat_ms", (hb_ms as usize).into()),
        ],
    );
    welcome.write_to(&mut w).context("welcoming the standby")?;
    let w2 = w.try_clone().context("cloning standby stream")?;
    state.attach_standby(w2, reader, advertise.clone(), id)?;
    crate::info!(
        "[net] standby attached (peer {id}), will advertise {advertise}"
    );
    // every connected participant learns the failover target immediately
    state.broadcast(&state.welcome_frame());
    loop {
        if state.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(hb_ms));
        if !state.ship_heartbeat(id) {
            return Ok(());
        }
    }
}

/// Writer thread: owns the write half, serializes outbound frames, and
/// applies the plan's wire faults (drop/dup/corrupt/delay) with a
/// per-connection frame sequence counter — deterministic per plan seed.
fn writer_loop(mut stream: TcpStream, rx: Receiver<WriterCmd>, faults: FaultPlan) {
    use std::io::Write;
    let has_faults = faults.has_net_faults();
    let mut seq: u64 = 0;
    for cmd in rx {
        match cmd {
            WriterCmd::Close => break,
            WriterCmd::Send(frame) => {
                seq += 1;
                if !has_faults {
                    if frame.write_to(&mut stream).is_err() {
                        break;
                    }
                    continue;
                }
                let delay = faults.net_delay_ms();
                if delay > 0 {
                    std::thread::sleep(Duration::from_millis(delay));
                }
                if faults.net_drops(seq) {
                    continue;
                }
                let mut bytes = match frame.encode() {
                    Ok(b) => b,
                    Err(_) => continue,
                };
                if faults.net_corrupts(seq) {
                    // flip a payload byte AFTER the checksum was computed:
                    // the receiver detects it and reconnects
                    let i = wire::HEADER_LEN;
                    if bytes.len() > i {
                        bytes[i] ^= 0x40;
                    }
                }
                let copies = if faults.net_dups(seq) { 2 } else { 1 };
                let mut dead = false;
                for _ in 0..copies {
                    if stream
                        .write_all(&bytes)
                        .and_then(|_| stream.flush())
                        .is_err()
                    {
                        dead = true;
                        break;
                    }
                }
                if dead {
                    break;
                }
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

// ---------------------------------------------------------------------------
// NetRunner — the JobRunner the round engine drives
// ---------------------------------------------------------------------------

/// Routes each device's admission locally (same math as `SimRunner` /
/// `SessionRunner`) and its work to the remote participant claiming that
/// device name. Slotting in as a [`JobRunner`] means the round engine
/// keeps owning retries, stragglers, quorum, and the journal.
pub struct NetRunner {
    state: Arc<NetState>,
    manifest: Manifest,
    attach_timeout_ms: u64,
    warmup_timeout_ms: u64,
    reply_timeout_ms: u64,
}

impl NetRunner {
    pub fn new(state: Arc<NetState>, manifest: Manifest) -> NetRunner {
        NetRunner {
            state,
            manifest,
            attach_timeout_ms: 30_000,
            warmup_timeout_ms: 120_000,
            reply_timeout_ms: 600_000,
        }
    }

    /// Override the attach / warmup-ack / run-reply timeouts (tests and
    /// the chaos bench shrink them drastically).
    pub fn with_timeouts(
        mut self,
        attach_ms: u64,
        warmup_ms: u64,
        reply_ms: u64,
    ) -> NetRunner {
        self.attach_timeout_ms = attach_ms.max(1);
        self.warmup_timeout_ms = warmup_ms.max(1);
        self.reply_timeout_ms = reply_ms.max(1);
        self
    }
}

impl JobRunner for NetRunner {
    fn admit(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
    ) -> Result<Admission> {
        let cfg = self.manifest.config(&self.state.config_name)?;
        let est = peft::accounting::estimate_trainable(&job.strategy, cfg);
        let footprint = MemoryFootprint::compute(cfg, est, self.manifest.batch);
        Ok(admit(device, &footprint))
    }

    fn warmup(&self, device: &'static DeviceProfile, jobs: &[Job]) -> Result<()> {
        let tx = self.state.await_attach(
            device.name,
            Duration::from_millis(self.attach_timeout_ms),
        )?;
        let key = warmup_key(device.name);
        let (rtx, rrx) = channel::<Reply>();
        self.state.insert_pending(key.clone(), device.name, rtx);
        let jobs_json = Json::Arr(jobs.iter().map(job_to_json).collect());
        let f = Frame::new(
            wire::WARMUP,
            vec![("device", device.name.into()), ("jobs", jobs_json)],
        );
        if tx.send(WriterCmd::Send(Box::new(f))).is_err() {
            self.state.remove_pending(&key);
            bail!("participant {} detached before warmup", device.name);
        }
        match rrx.recv_timeout(Duration::from_millis(self.warmup_timeout_ms)) {
            Ok(Reply::Warmed(None)) => Ok(()),
            Ok(Reply::Warmed(Some(e))) => bail!("remote warmup failed: {e}"),
            Ok(Reply::Fail(e)) => bail!("remote warmup failed: {e}"),
            Ok(Reply::Output(_)) => {
                bail!("protocol error: run output answered a warmup")
            }
            Err(_) => {
                self.state.remove_pending(&key);
                bail!(
                    "no warmup ack from {} within {} ms",
                    device.name,
                    self.warmup_timeout_ms
                )
            }
        }
    }

    fn run(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
        attempt: u32,
    ) -> Result<RunOutput> {
        let strategy = job.strategy.name();
        let key = run_key(job.task.name, &strategy, attempt as usize);
        let tx = self.state.await_attach(
            device.name,
            Duration::from_millis(self.attach_timeout_ms),
        )?;
        let (rtx, rrx) = channel::<Reply>();
        self.state.insert_pending(key.clone(), device.name, rtx);
        let mut fields = job_fields(job);
        fields.push(("attempt", (attempt as usize).into()));
        let f = Frame::new(wire::ASSIGN, fields);
        if tx.send(WriterCmd::Send(Box::new(f))).is_err() {
            self.state.remove_pending(&key);
            bail!("participant {} detached before the assign", device.name);
        }
        match rrx.recv_timeout(Duration::from_millis(self.reply_timeout_ms)) {
            Ok(Reply::Output(out)) => Ok(*out),
            Ok(Reply::Fail(e)) => bail!("remote attempt failed: {e}"),
            Ok(Reply::Warmed(_)) => {
                bail!("protocol error: warmup ack answered an assign")
            }
            Err(_) => {
                self.state.remove_pending(&key);
                bail!(
                    "no result for {}/{strategy} attempt {attempt} within \
                     {} ms",
                    job.task.name,
                    self.reply_timeout_ms
                )
            }
        }
    }

    fn on_phase(&self, phase: RoundState) {
        self.state.set_phase(phase);
        self.state
            .broadcast(&Frame::new(wire::PHASE, vec![("phase", phase.name().into())]));
        if phase == RoundState::Cooldown {
            self.state.broadcast(&Frame::new(wire::DONE, vec![]));
        }
    }
}
