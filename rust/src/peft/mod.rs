//! PEFT strategy zoo: TaskEdge + every baseline from the paper's Table I,
//! expressed over the uniform mask contract of the AOT train graphs.

pub mod accounting;
pub mod strategy;

pub use accounting::{estimate_delta_bytes, store_checkpoint_bytes,
                     trainable_fraction, trainable_params, DeltaSizeReport,
                     MemoryFootprint};
pub use strategy::{Family, Strategy};
