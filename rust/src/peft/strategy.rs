//! Strategy = how trainable parameters are chosen and which train graph
//! family runs. The dense strategies differ ONLY in their masks (Eq. 1's M),
//! so they share the `train_adam`/`train_sgd` artifacts; LoRA/VPT/Adapter
//! carry their own trainable state and graphs.
//!
//! Protocol note: the classification head is trainable under every strategy
//! (each downstream task gets a fresh head) — this matches the VTAB
//! protocol of the paper's baselines; the sparsity budget K applies to the
//! backbone weight matrices.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::masking::{self, Mask};
use crate::runtime::ModelConfig;
use crate::util::rng::Rng;
use crate::vit::ParamStore;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Backbone weights trained through masks (train_adam / train_sgd).
    Dense,
    /// Frozen backbone + (B·A)⊙M deltas (lora_train / lora_eval).
    Lora,
    /// Prompt tokens + head (vpt_train / vpt_eval).
    Vpt,
    /// Bottleneck adapters + head (adapter_train / adapter_eval).
    Adapter,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// The paper's method: Eq. 2 scores + per-neuron top-K (Alg. 1).
    TaskEdge { k: usize },
    /// §III-C structured variant: N:M groups with Eq. 2 scores.
    TaskEdgeNM { n: usize, m: usize },
    /// §III-D / Eq. 6: sparse low-rank adaptation, masks from Eq. 2 scores.
    SparseLora { k: usize },
    /// Plain LoRA (all-ones masks over the deltas).
    Lora,
    /// Ablation: task-aware scores but *global* top-fraction selection —
    /// the allocation the paper argues against.
    GlobalTaskAware { frac: f64 },
    /// Magnitude-only baseline: |W| scores, per-neuron top-K.
    Magnitude { k: usize },
    /// GPS-style baseline: |∇W| scores, per-neuron top-K.
    Gps { k: usize },
    /// Random selection at a density matching TaskEdge's budget.
    Random { frac: f64 },
    /// Full fine-tuning (all-ones masks).
    Full,
    /// Linear probe: head only.
    Linear,
    /// BitFit: bias terms + head.
    BitFit,
    /// Visual prompt tuning (shallow).
    Vpt,
    /// Houlsby adapters.
    Adapter,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::TaskEdge { k } => format!("taskedge_k{k}"),
            Strategy::TaskEdgeNM { n, m } => format!("taskedge_nm{n}:{m}"),
            Strategy::SparseLora { k } => format!("sparse_lora_k{k}"),
            Strategy::Lora => "lora".into(),
            Strategy::GlobalTaskAware { frac } => format!("global_taskaware_{frac}"),
            Strategy::Magnitude { k } => format!("magnitude_k{k}"),
            Strategy::Gps { k } => format!("gps_k{k}"),
            Strategy::Random { frac } => format!("random_{frac}"),
            Strategy::Full => "full".into(),
            Strategy::Linear => "linear".into(),
            Strategy::BitFit => "bitfit".into(),
            Strategy::Vpt => "vpt".into(),
            Strategy::Adapter => "adapter".into(),
        }
    }

    /// Parse a CLI strategy spec, e.g. `taskedge:k=8`, `nm:2:4`, `lora`.
    ///
    /// Malformed option values are hard errors with the offending value in
    /// the message — a typo like `taskedge:k=abc` must not silently run
    /// with the default budget (it would fine-tune a different model than
    /// the one asked for and report it under the asked-for name).
    pub fn parse(s: &str) -> Result<Strategy> {
        let parts: Vec<&str> = s.split(':').collect();
        let k_of = |default: usize| -> Result<usize> {
            match parts.len() {
                1 => Ok(default),
                2 => {
                    let v = parts[1].strip_prefix("k=").with_context(|| {
                        format!(
                            "strategy {s:?}: expected `{}:k=N`, got option \
                             {:?}",
                            parts[0], parts[1]
                        )
                    })?;
                    let k: usize = v.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "strategy {s:?}: k must be a positive integer, \
                             got {v:?}"
                        )
                    })?;
                    if k == 0 {
                        bail!("strategy {s:?}: k must be >= 1");
                    }
                    Ok(k)
                }
                _ => bail!(
                    "strategy {s:?}: too many options (expected \
                     `{}[:k=N]`)",
                    parts[0]
                ),
            }
        };
        let frac_of = |default: f64| -> Result<f64> {
            match parts.len() {
                1 => Ok(default),
                2 => {
                    let f: f64 = parts[1].parse().map_err(|_| {
                        anyhow::anyhow!(
                            "strategy {s:?}: fraction must be a number in \
                             (0, 1], got {:?}",
                            parts[1]
                        )
                    })?;
                    if !(f > 0.0 && f <= 1.0) {
                        bail!(
                            "strategy {s:?}: fraction must be in (0, 1], \
                             got {f}"
                        );
                    }
                    Ok(f)
                }
                _ => bail!(
                    "strategy {s:?}: too many options (expected \
                     `{}[:FRAC]`)",
                    parts[0]
                ),
            }
        };
        let no_options = || -> Result<()> {
            if parts.len() > 1 {
                bail!("strategy {s:?}: {:?} takes no options", parts[0]);
            }
            Ok(())
        };
        Ok(match parts[0] {
            "taskedge" => Strategy::TaskEdge { k: k_of(8)? },
            "nm" | "taskedge_nm" => match parts.len() {
                1 => Strategy::TaskEdgeNM { n: 2, m: 4 },
                3 => {
                    let int = |what: &str, v: &str| -> Result<usize> {
                        v.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "strategy {s:?}: {what} must be a positive \
                                 integer, got {v:?}"
                            )
                        })
                    };
                    let n = int("N", parts[1])?;
                    let m = int("M", parts[2])?;
                    if n == 0 || n > m {
                        bail!(
                            "strategy {s:?}: need 1 <= N <= M, got {n}:{m}"
                        );
                    }
                    Strategy::TaskEdgeNM { n, m }
                }
                _ => bail!(
                    "strategy {s:?}: expected `nm:N:M` (e.g. `nm:2:4`)"
                ),
            },
            "sparse_lora" => Strategy::SparseLora { k: k_of(8)? },
            "lora" => {
                no_options()?;
                Strategy::Lora
            }
            "global" => Strategy::GlobalTaskAware { frac: frac_of(0.01)? },
            "magnitude" => Strategy::Magnitude { k: k_of(8)? },
            "gps" => Strategy::Gps { k: k_of(8)? },
            "random" => Strategy::Random { frac: frac_of(0.01)? },
            "full" => {
                no_options()?;
                Strategy::Full
            }
            "linear" => {
                no_options()?;
                Strategy::Linear
            }
            "bitfit" => {
                no_options()?;
                Strategy::BitFit
            }
            "vpt" => {
                no_options()?;
                Strategy::Vpt
            }
            "adapter" => {
                no_options()?;
                Strategy::Adapter
            }
            other => bail!("unknown strategy {other:?}"),
        })
    }

    pub fn family(&self) -> Family {
        match self {
            Strategy::SparseLora { .. } | Strategy::Lora => Family::Lora,
            Strategy::Vpt => Family::Vpt,
            Strategy::Adapter => Family::Adapter,
            _ => Family::Dense,
        }
    }

    /// Does mask construction need activation statistics (Alg. 1 step 1-2)?
    pub fn needs_calibration(&self) -> bool {
        matches!(
            self,
            Strategy::TaskEdge { .. }
                | Strategy::TaskEdgeNM { .. }
                | Strategy::SparseLora { .. }
                | Strategy::GlobalTaskAware { .. }
        )
    }

    /// Does mask construction need gradient magnitudes (GPS baseline)?
    pub fn needs_grad_scores(&self) -> bool {
        matches!(self, Strategy::Gps { .. })
    }

    /// Build masks for every parameter tensor (Dense family) or for every
    /// LoRA target (Lora family). `colnorms` maps stat name -> ||X_j||_2;
    /// `grad_scores` maps param name -> accumulated |∇W|.
    pub fn build_masks(
        &self,
        cfg: &ModelConfig,
        params: &ParamStore,
        colnorms: Option<&BTreeMap<String, Vec<f32>>>,
        grad_scores: Option<&BTreeMap<String, Vec<f32>>>,
        rng: &mut Rng,
    ) -> Result<BTreeMap<String, Mask>> {
        match self.family() {
            Family::Dense => self.dense_masks(cfg, params, colnorms, grad_scores, rng),
            Family::Lora => self.lora_masks(cfg, params, colnorms),
            Family::Vpt | Family::Adapter => Ok(BTreeMap::new()),
        }
    }

    /// Scores in PAPER layout (d_out, d_in).
    ///
    /// The L2 model stores weight matrices as (d_in, d_out) (activations
    /// are right-multiplied: y = x·W), while the paper's Eq. 2 / Alg. 1 and
    /// the masking kernels use (d_out, d_in) with per-ROW neuron budgets.
    /// We transpose into paper view here and transpose the resulting mask
    /// back in `dense_masks` — allocation is once-per-task, so the copies
    /// are irrelevant next to training.
    fn scores_for(
        &self,
        cfg: &ModelConfig,
        params: &ParamStore,
        spec_name: &str,
        colnorms: Option<&BTreeMap<String, Vec<f32>>>,
        grad_scores: Option<&BTreeMap<String, Vec<f32>>>,
    ) -> Result<Vec<f32>> {
        let p = cfg.param(spec_name)?;
        let (d_in, d_out) = (p.shape[0], p.shape[1]);
        let w_t = transpose(params.get(spec_name)?.f32s()?, d_in, d_out);
        match self {
            Strategy::Magnitude { .. } => Ok(masking::magnitude_scores(&w_t)),
            Strategy::Gps { .. } => {
                let g = grad_scores
                    .and_then(|g| g.get(spec_name))
                    .context("GPS strategy requires grad scores")?;
                Ok(transpose(g, d_in, d_out))
            }
            _ => {
                let stat = p.stat.as_ref().context("masked param missing stat")?;
                let cn = colnorms
                    .and_then(|c| c.get(stat))
                    .with_context(|| format!("missing calibration stat {stat:?}"))?;
                masking::importance_scores(&w_t, d_out, d_in, cn)
            }
        }
    }

    fn dense_masks(
        &self,
        cfg: &ModelConfig,
        params: &ParamStore,
        colnorms: Option<&BTreeMap<String, Vec<f32>>>,
        grad_scores: Option<&BTreeMap<String, Vec<f32>>>,
        rng: &mut Rng,
    ) -> Result<BTreeMap<String, Mask>> {
        let mut masks: BTreeMap<String, Mask> = cfg
            .params
            .iter()
            .map(|p| (p.name.clone(), Mask::zeros(&p.shape)))
            .collect();

        // Head is trainable under every protocol (fresh head per task).
        let set_ones = |masks: &mut BTreeMap<String, Mask>, name: &str| {
            if let Some(m) = masks.get_mut(name) {
                *m = Mask::ones(&m.shape.clone());
            }
        };

        match self {
            Strategy::Full => {
                for p in &cfg.params {
                    set_ones(&mut masks, &p.name);
                }
            }
            Strategy::Linear => {
                set_ones(&mut masks, "head.w");
                set_ones(&mut masks, "head.b");
            }
            Strategy::BitFit => {
                for p in &cfg.params {
                    if p.name.ends_with(".b") || p.name.ends_with(".bias") {
                        set_ones(&mut masks, &p.name);
                    }
                }
                set_ones(&mut masks, "head.w");
            }
            Strategy::Random { frac } => {
                for p in cfg.masked_params().filter(|p| p.name != "head.w") {
                    masks.insert(
                        p.name.clone(),
                        masking::random_frac(p.shape[0], p.shape[1], *frac, rng)?,
                    );
                }
                set_ones(&mut masks, "head.w");
                set_ones(&mut masks, "head.b");
            }
            Strategy::GlobalTaskAware { frac } => {
                let specs: Vec<_> = cfg
                    .masked_params()
                    .filter(|p| p.name != "head.w")
                    .collect();
                let scores: Vec<Vec<f32>> = specs
                    .iter()
                    .map(|p| {
                        self.scores_for(cfg, params, &p.name, colnorms, grad_scores)
                    })
                    .collect::<Result<_>>()?;
                // scores are in paper view: (d_out=shape[1], d_in=shape[0])
                let refs: Vec<(&[f32], usize, usize)> = specs
                    .iter()
                    .zip(&scores)
                    .map(|(p, s)| (s.as_slice(), p.shape[1], p.shape[0]))
                    .collect();
                let selected = masking::global_top_frac(&refs, *frac)?;
                for (p, m) in specs.iter().zip(selected) {
                    masks.insert(p.name.clone(), to_model_layout(m));
                }
                set_ones(&mut masks, "head.w");
                set_ones(&mut masks, "head.b");
            }
            Strategy::TaskEdge { k }
            | Strategy::Magnitude { k }
            | Strategy::Gps { k } => {
                for p in cfg.masked_params().filter(|p| p.name != "head.w") {
                    let s = self.scores_for(cfg, params, &p.name, colnorms,
                                            grad_scores)?;
                    let m = masking::per_neuron_topk(&s, p.shape[1], p.shape[0], *k)?;
                    masks.insert(p.name.clone(), to_model_layout(m));
                }
                set_ones(&mut masks, "head.w");
                set_ones(&mut masks, "head.b");
            }
            Strategy::TaskEdgeNM { n, m } => {
                for p in cfg.masked_params().filter(|p| p.name != "head.w") {
                    let s = self.scores_for(cfg, params, &p.name, colnorms,
                                            grad_scores)?;
                    let mk = masking::nm_select(&s, p.shape[1], p.shape[0], *n, *m)?;
                    masks.insert(p.name.clone(), to_model_layout(mk));
                }
                set_ones(&mut masks, "head.w");
                set_ones(&mut masks, "head.b");
            }
            Strategy::SparseLora { .. } | Strategy::Lora
            | Strategy::Vpt | Strategy::Adapter => unreachable!("non-dense"),
        }
        Ok(masks)
    }

    fn lora_masks(
        &self,
        cfg: &ModelConfig,
        params: &ParamStore,
        colnorms: Option<&BTreeMap<String, Vec<f32>>>,
    ) -> Result<BTreeMap<String, Mask>> {
        let mut masks = BTreeMap::new();
        for name in &cfg.lora_targets {
            let p = cfg.param(name)?;
            let (d_in, d_out) = (p.shape[0], p.shape[1]);
            let mask = match self {
                Strategy::Lora => Mask::ones(&p.shape),
                Strategy::SparseLora { k } => {
                    let stat = p.stat.as_ref().context("lora target missing stat")?;
                    let cn = colnorms
                        .and_then(|c| c.get(stat))
                        .with_context(|| format!("missing stat {stat:?}"))?;
                    let w_t = transpose(params.get(name)?.f32s()?, d_in, d_out);
                    let s = masking::importance_scores(&w_t, d_out, d_in, cn)?;
                    to_model_layout(masking::per_neuron_topk(&s, d_out, d_in, *k)?)
                }
                _ => unreachable!("non-lora"),
            };
            masks.insert(name.clone(), mask);
        }
        Ok(masks)
    }
}

/// (rows, cols) row-major -> (cols, rows) row-major.
fn transpose(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), rows * cols);
    let mut out = vec![0.0f32; data.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = data[r * cols + c];
        }
    }
    out
}

/// Convert a mask from paper view (d_out, d_in) back to the model's
/// storage layout (d_in, d_out).
fn to_model_layout(m: Mask) -> Mask {
    let (d_out, d_in) = (m.shape[0], m.shape[1]);
    Mask {
        shape: vec![d_in, d_out],
        data: transpose(&m.data, d_out, d_in),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["taskedge:k=4", "nm:2:4", "lora", "sparse_lora:k=2", "full",
                  "linear", "bitfit", "vpt", "adapter", "magnitude:k=8",
                  "gps:k=8", "random:0.01", "global:0.02"] {
            let st = Strategy::parse(s).unwrap();
            // name() must be stable and nonempty
            assert!(!st.name().is_empty());
        }
        assert!(Strategy::parse("bogus").is_err());
    }

    #[test]
    fn parse_defaults_without_options() {
        assert_eq!(Strategy::parse("taskedge").unwrap(),
                   Strategy::TaskEdge { k: 8 });
        assert_eq!(Strategy::parse("nm").unwrap(),
                   Strategy::TaskEdgeNM { n: 2, m: 4 });
        assert_eq!(Strategy::parse("random").unwrap(),
                   Strategy::Random { frac: 0.01 });
    }

    #[test]
    fn parse_rejects_malformed_values() {
        // regression: these used to fall back to defaults via .ok(), so a
        // typo silently ran the wrong configuration under the right name
        for bad in [
            "taskedge:k=abc", // non-numeric k
            "taskedge:8",     // missing k= prefix
            "taskedge:k=0",   // zero budget
            "taskedge:k=8:x", // trailing junk
            "nm:x:y",         // non-numeric N:M
            "nm:2",           // incomplete N:M
            "nm:4:2",         // N > M
            "nm:0:4",         // zero N
            "sparse_lora:k=", // empty k
            "gps:k=-3",       // negative k
            "random:xyz",     // non-numeric fraction
            "random:1.5",     // fraction out of (0, 1]
            "random:0",       // zero fraction
            "global:frac",    // non-numeric fraction
            "lora:k=2",       // option on an option-less strategy
            "full:1",         // option on an option-less strategy
        ] {
            let err = Strategy::parse(bad);
            assert!(err.is_err(), "{bad:?} must be rejected");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(
                msg.contains("strategy"),
                "{bad:?} error should name the spec: {msg}"
            );
        }
    }

    #[test]
    fn families() {
        assert_eq!(Strategy::TaskEdge { k: 8 }.family(), Family::Dense);
        assert_eq!(Strategy::SparseLora { k: 8 }.family(), Family::Lora);
        assert_eq!(Strategy::Vpt.family(), Family::Vpt);
        assert_eq!(Strategy::Adapter.family(), Family::Adapter);
    }

    #[test]
    fn calibration_requirements() {
        assert!(Strategy::TaskEdge { k: 8 }.needs_calibration());
        assert!(Strategy::SparseLora { k: 8 }.needs_calibration());
        assert!(!Strategy::Magnitude { k: 8 }.needs_calibration());
        assert!(Strategy::Gps { k: 8 }.needs_grad_scores());
        assert!(!Strategy::Full.needs_calibration());
    }
}
