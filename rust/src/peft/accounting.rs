//! Parameter + memory accounting — the quantitative backbone of the
//! paper's edge argument (§I: LLaMA-7B needs 58 GB because optimizer state
//! and gradients scale with *trainable* parameters).

use std::collections::BTreeMap;

use crate::masking::Mask;
use crate::peft::{Family, Strategy};
use crate::runtime::ModelConfig;
use crate::vit::TaskDelta;

/// Trainable parameter count for a strategy given its built masks.
pub fn trainable_params(
    strategy: &Strategy,
    cfg: &ModelConfig,
    masks: &BTreeMap<String, Mask>,
) -> usize {
    match strategy.family() {
        Family::Dense => masks.values().map(|m| m.count_ones()).sum(),
        Family::Lora => {
            // A + B factors are the trainable state (masks gate the delta,
            // not the factor count).
            cfg.lora_targets
                .iter()
                .map(|t| {
                    let p = cfg.param(t).unwrap();
                    cfg.lora_rank * (p.shape[0] + p.shape[1])
                })
                .sum()
        }
        Family::Vpt => {
            cfg.prompt_len * cfg.dim
                + cfg.dim * cfg.num_classes
                + cfg.num_classes
        }
        Family::Adapter => {
            let per_block = cfg.dim * cfg.adapter_dim   // down.w
                + cfg.adapter_dim                        // down.b
                + cfg.adapter_dim * cfg.dim              // up.w
                + cfg.dim;                               // up.b
            cfg.depth * per_block
                + cfg.dim * cfg.num_classes
                + cfg.num_classes
        }
    }
}

/// Trainable fraction (the paper's "Params (%)" column).
pub fn trainable_fraction(
    strategy: &Strategy,
    cfg: &ModelConfig,
    masks: &BTreeMap<String, Mask>,
) -> f64 {
    trainable_params(strategy, cfg, masks) as f64 / cfg.num_params as f64
}

/// Analytic trainable-parameter estimate BEFORE masks are built — used by
/// the fleet scheduler for admission control (the masks need calibration
/// data, which only the admitted device should pay for).
pub fn estimate_trainable(strategy: &Strategy, cfg: &ModelConfig) -> usize {
    let head: usize = cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
        + cfg.param("head.b").map(|p| p.numel()).unwrap_or(0);
    let backbone_masked = || {
        cfg.masked_params()
            .filter(|p| p.name != "head.w")
            .collect::<Vec<_>>()
    };
    match strategy {
        Strategy::TaskEdge { k } | Strategy::Magnitude { k } | Strategy::Gps { k } => {
            // model layout is (d_in, d_out): one budget of min(k, d_in) per
            // output neuron (column)
            backbone_masked()
                .iter()
                .map(|p| p.shape[1] * (*k).min(p.shape[0]))
                .sum::<usize>()
                + head
        }
        Strategy::TaskEdgeNM { n, m } => {
            backbone_masked()
                .iter()
                .map(|p| p.numel() * n / m)
                .sum::<usize>()
                + head
        }
        Strategy::GlobalTaskAware { frac } | Strategy::Random { frac } => {
            let total: usize = backbone_masked().iter().map(|p| p.numel()).sum();
            (total as f64 * frac).round() as usize + head
        }
        Strategy::Full => cfg.num_params,
        Strategy::Linear => head,
        Strategy::BitFit => {
            cfg.params
                .iter()
                .filter(|p| p.name.ends_with(".b") || p.name.ends_with(".bias"))
                .map(|p| p.numel())
                .sum::<usize>()
                + cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
        }
        Strategy::Lora | Strategy::SparseLora { .. } | Strategy::Vpt
        | Strategy::Adapter => {
            trainable_params(strategy, cfg, &BTreeMap::new())
        }
    }
}

// -- checkpoint / delta size accounting -------------------------------------

/// Exact serialized size of a full `ParamStore` checkpoint for `cfg`
/// (mirrors `ParamStore::save`: magic + count + per-tensor name/shape/f32s).
pub fn store_checkpoint_bytes(cfg: &ModelConfig) -> usize {
    4 + 4
        + cfg
            .params
            .iter()
            .map(|p| 2 + p.name.len() + 1 + 8 * p.shape.len() + 4 * p.numel())
            .sum::<usize>()
}

/// Delta-vs-full checkpoint comparison: the storage half of the paper's
/// edge argument (per-task artifacts should scale with TRAINABLE, not
/// total, parameters).
#[derive(Debug, Clone)]
pub struct DeltaSizeReport {
    /// exact serialized delta bytes (`TaskDelta::file_bytes`)
    pub delta_bytes: usize,
    /// exact serialized full-checkpoint bytes for the same config
    pub full_bytes: usize,
}

impl DeltaSizeReport {
    pub fn new(delta: &TaskDelta, cfg: &ModelConfig) -> DeltaSizeReport {
        DeltaSizeReport {
            delta_bytes: delta.file_bytes(),
            full_bytes: store_checkpoint_bytes(cfg),
        }
    }

    /// delta size as a fraction of the full checkpoint
    pub fn ratio(&self) -> f64 {
        self.delta_bytes as f64 / self.full_bytes.max(1) as f64
    }
}

/// Analytic delta-checkpoint estimate BEFORE training runs — the storage
/// twin of [`estimate_trainable`]. Mirrors `TaskDelta::diff`'s per-tensor
/// break-even rule: a sparse coordinate costs 8 bytes (u32 index + f32
/// value) but a plane never costs more than its dense rewrite (4
/// bytes/value), so 0.5-density planes like N:M 2:4 are charged dense.
/// The fresh head and family-specific tensors (LoRA factors, prompt,
/// adapters) are dense. Per-tensor name/shape framing is ignored (tens of
/// bytes per tensor).
pub fn estimate_delta_bytes(strategy: &Strategy, cfg: &ModelConfig) -> usize {
    let head: usize = cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
        + cfg.param("head.b").map(|p| p.numel()).unwrap_or(0);
    // diff's encoding choice per plane: sparse entries or dense rewrite
    let plane = |nnz: usize, numel: usize| (8 * nnz).min(4 * numel);
    let backbone = || cfg.masked_params().filter(|p| p.name != "head.w");
    match strategy.family() {
        Family::Dense => match strategy {
            Strategy::Full => 4 * cfg.num_params,
            Strategy::Linear => 4 * head,
            Strategy::BitFit => {
                // bias planes rewrite wholesale -> dense
                cfg.params
                    .iter()
                    .filter(|p| {
                        p.name.ends_with(".b") || p.name.ends_with(".bias")
                    })
                    .map(|p| 4 * p.numel())
                    .sum::<usize>()
                    + 4 * cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
            }
            Strategy::TaskEdge { k }
            | Strategy::Magnitude { k }
            | Strategy::Gps { k } => {
                backbone()
                    .map(|p| plane(p.shape[1] * (*k).min(p.shape[0]), p.numel()))
                    .sum::<usize>()
                    + 4 * head
            }
            Strategy::TaskEdgeNM { n, m } => {
                backbone()
                    .map(|p| plane(p.numel() * *n / *m, p.numel()))
                    .sum::<usize>()
                    + 4 * head
            }
            Strategy::GlobalTaskAware { frac } | Strategy::Random { frac } => {
                backbone()
                    .map(|p| {
                        plane((p.numel() as f64 * *frac).round() as usize,
                              p.numel())
                    })
                    .sum::<usize>()
                    + 4 * head
            }
            _ => unreachable!("non-dense strategies handled by family"),
        },
        Family::Lora => {
            let factors: usize = cfg
                .lora_targets
                .iter()
                .filter_map(|t| cfg.param(t).ok())
                .map(|p| cfg.lora_rank * (p.shape[0] + p.shape[1]))
                .sum();
            let mask_indices: usize = match strategy {
                // sparse masks ship their support as u32 indices
                Strategy::SparseLora { k } => cfg
                    .lora_targets
                    .iter()
                    .filter_map(|t| cfg.param(t).ok())
                    .map(|p| 4 * p.shape[1] * (*k).min(p.shape[0]))
                    .sum(),
                // all-ones masks are a tag byte, not materialized
                _ => 0,
            };
            4 * (factors + head) + mask_indices
        }
        Family::Vpt => 4 * (cfg.prompt_len * cfg.dim + head),
        Family::Adapter => {
            let adapters: usize =
                cfg.adapters.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            4 * (adapters + head)
        }
    }
}

/// Fine-tuning memory footprint model (bytes, f32 everywhere):
///
/// - weights: all parameters (must be resident for forward)
/// - gradients: dense backprop still materializes ∇W per tensor, but the
///   *persistent* gradient buffer can be restricted to the trainable set
///   (sparse accumulation) — both are reported
/// - optimizer state: 2 moments × trainable (the paper's key saving)
/// - activations: batch × tokens × dim × depth × c_act
#[derive(Debug, Clone)]
pub struct MemoryFootprint {
    pub weights_bytes: usize,
    pub grad_dense_bytes: usize,
    pub grad_sparse_bytes: usize,
    pub optimizer_bytes: usize,
    pub activation_bytes: usize,
}

impl MemoryFootprint {
    pub fn compute(cfg: &ModelConfig, trainable: usize, batch: usize) -> Self {
        let p = cfg.num_params;
        let tokens = (cfg.image_size / cfg.patch_size).pow(2) + 1;
        // ~12 activation tensors per block retained for backward (qkv, att,
        // proj, ln, mlp hidden, residuals) — a standard transformer estimate.
        let c_act = 12;
        MemoryFootprint {
            weights_bytes: 4 * p,
            grad_dense_bytes: 4 * p,
            grad_sparse_bytes: 4 * trainable,
            optimizer_bytes: 2 * 4 * trainable,
            activation_bytes: 4 * batch * tokens * cfg.dim * cfg.depth * c_act,
        }
    }

    /// Total with dense transient gradients (worst case during backward).
    pub fn total_dense(&self) -> usize {
        self.weights_bytes + self.grad_dense_bytes + self.optimizer_bytes
            + self.activation_bytes
    }

    /// Total with sparse gradient accumulation (TaskEdge steady state).
    pub fn total_sparse(&self) -> usize {
        self.weights_bytes + self.grad_sparse_bytes + self.optimizer_bytes
            + self.activation_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn cfg() -> ModelConfig {
        Manifest::parse(
            r#"{"version":1,"batch":2,"configs":{"t":{
            "image_size":16,"patch_size":4,"dim":8,"depth":2,"heads":2,
            "mlp_ratio":2,"num_classes":4,"channels":3,"prompt_len":3,
            "adapter_dim":2,"lora_rank":2,"num_params":1000,
            "params":[
              {"name":"w1","shape":[8,16],"init":"trunc_normal","masked":true,"stat":"w1.in"},
              {"name":"head.w","shape":[8,4],"init":"trunc_normal","masked":true,"stat":"head.in"}],
            "lora_targets":["w1","head.w"],"adapters":[]}},"artifacts":[]}"#,
        )
        .unwrap()
        .config("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn dense_counts_masks() {
        let cfg = cfg();
        let mut masks = BTreeMap::new();
        let mut m = Mask::zeros(&[8, 16]);
        m.data[0] = 1.0;
        m.data[5] = 1.0;
        masks.insert("w1".to_string(), m);
        masks.insert("head.w".to_string(), Mask::ones(&[8, 4]));
        let st = Strategy::TaskEdge { k: 1 };
        assert_eq!(trainable_params(&st, &cfg, &masks), 2 + 32);
        assert!((trainable_fraction(&st, &cfg, &masks) - 0.034).abs() < 1e-9);
    }

    #[test]
    fn lora_counts_factors() {
        let cfg = cfg();
        let st = Strategy::Lora;
        // targets: w1 (8+16)*2 + head.w (8+4)*2 = 48 + 24 = 72
        assert_eq!(trainable_params(&st, &cfg, &BTreeMap::new()), 72);
    }

    #[test]
    fn vpt_and_adapter_counts() {
        let cfg = cfg();
        assert_eq!(
            trainable_params(&Strategy::Vpt, &cfg, &BTreeMap::new()),
            3 * 8 + 8 * 4 + 4
        );
        let per_block = 8 * 2 + 2 + 2 * 8 + 8;
        assert_eq!(
            trainable_params(&Strategy::Adapter, &cfg, &BTreeMap::new()),
            2 * per_block + 8 * 4 + 4
        );
    }

    #[test]
    fn checkpoint_bytes_match_saved_store() {
        let cfg = cfg();
        let store = crate::vit::ParamStore::zeros_like(&cfg);
        let path = std::env::temp_dir().join("taskedge_test_acct_ckpt.bin");
        store.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(on_disk, store_checkpoint_bytes(&cfg));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn delta_estimates_scale_with_strategy() {
        let cfg = cfg();
        // head = 8*4 + 0 (no head.b in this mini config)
        let head = cfg.param("head.w").unwrap().numel();
        assert_eq!(
            estimate_delta_bytes(&Strategy::Linear, &cfg),
            4 * head
        );
        // Full is a dense rewrite of the whole store
        assert_eq!(
            estimate_delta_bytes(&Strategy::Full, &cfg),
            4 * cfg.num_params
        );
        // sparse strategies pay 8 bytes per backbone coordinate
        let k1 = estimate_delta_bytes(&Strategy::TaskEdge { k: 1 }, &cfg);
        let k4 = estimate_delta_bytes(&Strategy::TaskEdge { k: 4 }, &cfg);
        assert!(k1 < k4, "delta estimate must grow with k ({k1} vs {k4})");
        assert!(k4 < 4 * cfg.num_params);
    }

    #[test]
    fn memory_scales_with_trainable() {
        let cfg = cfg();
        let lo = MemoryFootprint::compute(&cfg, 10, 4);
        let hi = MemoryFootprint::compute(&cfg, 1000, 4);
        assert!(lo.optimizer_bytes < hi.optimizer_bytes);
        assert_eq!(lo.weights_bytes, hi.weights_bytes);
        assert!(lo.total_sparse() < hi.total_sparse());
        assert!(lo.total_sparse() <= lo.total_dense());
    }
}
