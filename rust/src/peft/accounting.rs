//! Parameter + memory accounting — the quantitative backbone of the
//! paper's edge argument (§I: LLaMA-7B needs 58 GB because optimizer state
//! and gradients scale with *trainable* parameters).

use std::collections::BTreeMap;

use crate::masking::Mask;
use crate::peft::{Family, Strategy};
use crate::runtime::ModelConfig;

/// Trainable parameter count for a strategy given its built masks.
pub fn trainable_params(
    strategy: &Strategy,
    cfg: &ModelConfig,
    masks: &BTreeMap<String, Mask>,
) -> usize {
    match strategy.family() {
        Family::Dense => masks.values().map(|m| m.count_ones()).sum(),
        Family::Lora => {
            // A + B factors are the trainable state (masks gate the delta,
            // not the factor count).
            cfg.lora_targets
                .iter()
                .map(|t| {
                    let p = cfg.param(t).unwrap();
                    cfg.lora_rank * (p.shape[0] + p.shape[1])
                })
                .sum()
        }
        Family::Vpt => {
            cfg.prompt_len * cfg.dim
                + cfg.dim * cfg.num_classes
                + cfg.num_classes
        }
        Family::Adapter => {
            let per_block = cfg.dim * cfg.adapter_dim   // down.w
                + cfg.adapter_dim                        // down.b
                + cfg.adapter_dim * cfg.dim              // up.w
                + cfg.dim;                               // up.b
            cfg.depth * per_block
                + cfg.dim * cfg.num_classes
                + cfg.num_classes
        }
    }
}

/// Trainable fraction (the paper's "Params (%)" column).
pub fn trainable_fraction(
    strategy: &Strategy,
    cfg: &ModelConfig,
    masks: &BTreeMap<String, Mask>,
) -> f64 {
    trainable_params(strategy, cfg, masks) as f64 / cfg.num_params as f64
}

/// Analytic trainable-parameter estimate BEFORE masks are built — used by
/// the fleet scheduler for admission control (the masks need calibration
/// data, which only the admitted device should pay for).
pub fn estimate_trainable(strategy: &Strategy, cfg: &ModelConfig) -> usize {
    let head: usize = cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
        + cfg.param("head.b").map(|p| p.numel()).unwrap_or(0);
    let backbone_masked = || {
        cfg.masked_params()
            .filter(|p| p.name != "head.w")
            .collect::<Vec<_>>()
    };
    match strategy {
        Strategy::TaskEdge { k } | Strategy::Magnitude { k } | Strategy::Gps { k } => {
            // model layout is (d_in, d_out): one budget of min(k, d_in) per
            // output neuron (column)
            backbone_masked()
                .iter()
                .map(|p| p.shape[1] * (*k).min(p.shape[0]))
                .sum::<usize>()
                + head
        }
        Strategy::TaskEdgeNM { n, m } => {
            backbone_masked()
                .iter()
                .map(|p| p.numel() * n / m)
                .sum::<usize>()
                + head
        }
        Strategy::GlobalTaskAware { frac } | Strategy::Random { frac } => {
            let total: usize = backbone_masked().iter().map(|p| p.numel()).sum();
            (total as f64 * frac).round() as usize + head
        }
        Strategy::Full => cfg.num_params,
        Strategy::Linear => head,
        Strategy::BitFit => {
            cfg.params
                .iter()
                .filter(|p| p.name.ends_with(".b") || p.name.ends_with(".bias"))
                .map(|p| p.numel())
                .sum::<usize>()
                + cfg.param("head.w").map(|p| p.numel()).unwrap_or(0)
        }
        Strategy::Lora | Strategy::SparseLora { .. } | Strategy::Vpt
        | Strategy::Adapter => {
            trainable_params(strategy, cfg, &BTreeMap::new())
        }
    }
}

/// Fine-tuning memory footprint model (bytes, f32 everywhere):
///
/// - weights: all parameters (must be resident for forward)
/// - gradients: dense backprop still materializes ∇W per tensor, but the
///   *persistent* gradient buffer can be restricted to the trainable set
///   (sparse accumulation) — both are reported
/// - optimizer state: 2 moments × trainable (the paper's key saving)
/// - activations: batch × tokens × dim × depth × c_act
#[derive(Debug, Clone)]
pub struct MemoryFootprint {
    pub weights_bytes: usize,
    pub grad_dense_bytes: usize,
    pub grad_sparse_bytes: usize,
    pub optimizer_bytes: usize,
    pub activation_bytes: usize,
}

impl MemoryFootprint {
    pub fn compute(cfg: &ModelConfig, trainable: usize, batch: usize) -> Self {
        let p = cfg.num_params;
        let tokens = (cfg.image_size / cfg.patch_size).pow(2) + 1;
        // ~12 activation tensors per block retained for backward (qkv, att,
        // proj, ln, mlp hidden, residuals) — a standard transformer estimate.
        let c_act = 12;
        MemoryFootprint {
            weights_bytes: 4 * p,
            grad_dense_bytes: 4 * p,
            grad_sparse_bytes: 4 * trainable,
            optimizer_bytes: 2 * 4 * trainable,
            activation_bytes: 4 * batch * tokens * cfg.dim * cfg.depth * c_act,
        }
    }

    /// Total with dense transient gradients (worst case during backward).
    pub fn total_dense(&self) -> usize {
        self.weights_bytes + self.grad_dense_bytes + self.optimizer_bytes
            + self.activation_bytes
    }

    /// Total with sparse gradient accumulation (TaskEdge steady state).
    pub fn total_sparse(&self) -> usize {
        self.weights_bytes + self.grad_sparse_bytes + self.optimizer_bytes
            + self.activation_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn cfg() -> ModelConfig {
        Manifest::parse(
            r#"{"version":1,"batch":2,"configs":{"t":{
            "image_size":16,"patch_size":4,"dim":8,"depth":2,"heads":2,
            "mlp_ratio":2,"num_classes":4,"channels":3,"prompt_len":3,
            "adapter_dim":2,"lora_rank":2,"num_params":1000,
            "params":[
              {"name":"w1","shape":[8,16],"init":"trunc_normal","masked":true,"stat":"w1.in"},
              {"name":"head.w","shape":[8,4],"init":"trunc_normal","masked":true,"stat":"head.in"}],
            "lora_targets":["w1","head.w"],"adapters":[]}},"artifacts":[]}"#,
        )
        .unwrap()
        .config("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn dense_counts_masks() {
        let cfg = cfg();
        let mut masks = BTreeMap::new();
        let mut m = Mask::zeros(&[8, 16]);
        m.data[0] = 1.0;
        m.data[5] = 1.0;
        masks.insert("w1".to_string(), m);
        masks.insert("head.w".to_string(), Mask::ones(&[8, 4]));
        let st = Strategy::TaskEdge { k: 1 };
        assert_eq!(trainable_params(&st, &cfg, &masks), 2 + 32);
        assert!((trainable_fraction(&st, &cfg, &masks) - 0.034).abs() < 1e-9);
    }

    #[test]
    fn lora_counts_factors() {
        let cfg = cfg();
        let st = Strategy::Lora;
        // targets: w1 (8+16)*2 + head.w (8+4)*2 = 48 + 24 = 72
        assert_eq!(trainable_params(&st, &cfg, &BTreeMap::new()), 72);
    }

    #[test]
    fn vpt_and_adapter_counts() {
        let cfg = cfg();
        assert_eq!(
            trainable_params(&Strategy::Vpt, &cfg, &BTreeMap::new()),
            3 * 8 + 8 * 4 + 4
        );
        let per_block = 8 * 2 + 2 + 2 * 8 + 8;
        assert_eq!(
            trainable_params(&Strategy::Adapter, &cfg, &BTreeMap::new()),
            2 * per_block + 8 * 4 + 4
        );
    }

    #[test]
    fn memory_scales_with_trainable() {
        let cfg = cfg();
        let lo = MemoryFootprint::compute(&cfg, 10, 4);
        let hi = MemoryFootprint::compute(&cfg, 1000, 4);
        assert!(lo.optimizer_bytes < hi.optimizer_bytes);
        assert_eq!(lo.weights_bytes, hi.weights_bytes);
        assert!(lo.total_sparse() < hi.total_sparse());
        assert!(lo.total_sparse() <= lo.total_dense());
    }
}
