//! Importance scoring (paper Eq. 2) and baseline criteria, host-side.
//!
//! The coordinator accumulates squared activation column norms from the
//! `calibrate` artifact across batches, then computes
//! `S_ij = |W_ij| * sqrt(sum_t X_tj^2)` here. Semantics are pinned to the
//! L1 Pallas kernels / ref.py oracles by golden-vector tests
//! (`artifacts/goldens.json`).

use anyhow::{bail, Result};

/// Accumulator for per-feature squared column norms over calibration batches.
#[derive(Debug, Clone)]
pub struct StatAccumulator {
    pub dim: usize,
    pub sum_sq: Vec<f64>, // f64 accumulation: batches * tokens can be large
    pub batches: usize,
}

impl StatAccumulator {
    pub fn new(dim: usize) -> StatAccumulator {
        StatAccumulator { dim, sum_sq: vec![0.0; dim], batches: 0 }
    }

    pub fn add(&mut self, colnorm_sq: &[f32]) -> Result<()> {
        if colnorm_sq.len() != self.dim {
            bail!("stat dim {} != accumulator dim {}", colnorm_sq.len(), self.dim);
        }
        for (acc, &v) in self.sum_sq.iter_mut().zip(colnorm_sq) {
            *acc += v as f64;
        }
        self.batches += 1;
        Ok(())
    }

    /// ||X_j||_2 over everything accumulated so far.
    pub fn colnorms(&self) -> Vec<f32> {
        self.sum_sq.iter().map(|&s| s.sqrt() as f32).collect()
    }
}

/// Eq. 2: S_ij = |W_ij| * ||X_j||_2 for a (d_out, d_in) row-major weight.
pub fn importance_scores(w: &[f32], d_out: usize, d_in: usize,
                         colnorms: &[f32]) -> Result<Vec<f32>> {
    if w.len() != d_out * d_in {
        bail!("weight len {} != {d_out}x{d_in}", w.len());
    }
    if colnorms.len() != d_in {
        bail!("colnorms len {} != d_in {d_in}", colnorms.len());
    }
    let mut s = Vec::with_capacity(w.len());
    for i in 0..d_out {
        let row = &w[i * d_in..(i + 1) * d_in];
        for (j, &wij) in row.iter().enumerate() {
            s.push(wij.abs() * colnorms[j]);
        }
    }
    Ok(s)
}

/// Magnitude baseline: S_ij = |W_ij| (ignores the task data).
pub fn magnitude_scores(w: &[f32]) -> Vec<f32> {
    w.iter().map(|v| v.abs()).collect()
}

/// GPS-style baseline: scores = accumulated |∇W| (fed from the
/// `grad_scores` artifact over a few batches).
#[derive(Debug, Clone)]
pub struct GradAccumulator {
    pub numel: usize,
    pub sum_abs: Vec<f64>,
    pub batches: usize,
}

impl GradAccumulator {
    pub fn new(numel: usize) -> GradAccumulator {
        GradAccumulator { numel, sum_abs: vec![0.0; numel], batches: 0 }
    }

    pub fn add(&mut self, grad_abs: &[f32]) -> Result<()> {
        if grad_abs.len() != self.numel {
            bail!("grad len {} != {}", grad_abs.len(), self.numel);
        }
        for (acc, &g) in self.sum_abs.iter_mut().zip(grad_abs) {
            *acc += g as f64;
        }
        self.batches += 1;
        Ok(())
    }

    pub fn scores(&self) -> Vec<f32> {
        self.sum_abs.iter().map(|&s| s as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_sums_batches() {
        let mut acc = StatAccumulator::new(3);
        acc.add(&[1.0, 4.0, 9.0]).unwrap();
        acc.add(&[3.0, 0.0, 7.0]).unwrap();
        let n = acc.colnorms();
        assert!((n[0] - 2.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
        assert!((n[2] - 4.0).abs() < 1e-6);
        assert_eq!(acc.batches, 2);
    }

    #[test]
    fn accumulator_dim_check() {
        let mut acc = StatAccumulator::new(3);
        assert!(acc.add(&[1.0]).is_err());
    }

    #[test]
    fn importance_formula() {
        // w = [[1, -2], [0.5, 4]], colnorms = [3, 0.5]
        let s = importance_scores(&[1.0, -2.0, 0.5, 4.0], 2, 2, &[3.0, 0.5]).unwrap();
        assert_eq!(s, vec![3.0, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn importance_shape_errors() {
        assert!(importance_scores(&[1.0; 4], 2, 3, &[1.0; 3]).is_err());
        assert!(importance_scores(&[1.0; 6], 2, 3, &[1.0; 2]).is_err());
    }

    #[test]
    fn magnitude_is_abs() {
        assert_eq!(magnitude_scores(&[-1.5, 2.0]), vec![1.5, 2.0]);
    }
}
