//! Binary mask type (Eq. 1's M) with invariants and serialization.
//!
//! Stored as f32 0.0/1.0 so it feeds the AOT train graphs directly (the
//! masked-update Pallas kernels take f32 masks).

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Mask {
    pub fn zeros(shape: &[usize]) -> Mask {
        Mask { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Mask {
        Mask { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_data(shape: &[usize], data: Vec<f32>) -> Result<Mask> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("mask shape {shape:?} needs {n} elems, got {}", data.len());
        }
        if data.iter().any(|&v| v != 0.0 && v != 1.0) {
            bail!("mask must be binary (0.0/1.0)");
        }
        Ok(Mask { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().filter(|&&v| v == 1.0).count()
    }

    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.count_ones() as f64 / self.numel() as f64
        }
    }

    /// Mask ratio as the paper reports it: fraction of parameters FROZEN.
    pub fn mask_ratio(&self) -> f64 {
        1.0 - self.density()
    }

    /// Row-wise one counts (2-D masks): the per-neuron budget check.
    pub fn row_counts(&self) -> Result<Vec<usize>> {
        if self.shape.len() != 2 {
            bail!("row_counts needs a 2-D mask, got {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        Ok((0..r)
            .map(|i| {
                self.data[i * c..(i + 1) * c]
                    .iter()
                    .filter(|&&v| v == 1.0)
                    .count()
            })
            .collect())
    }

    /// Check the structured N:M invariant over consecutive column groups.
    pub fn satisfies_nm(&self, n: usize, m: usize) -> bool {
        if self.shape.len() != 2 || self.shape[1] % m != 0 {
            return false;
        }
        self.data
            .chunks(m)
            .all(|g| g.iter().filter(|&&v| v == 1.0).count() == n)
    }

    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::from_f32(&self.shape, self.data.clone()).unwrap()
    }

    /// Compact serialization: shape + indices of the ones (masks are
    /// extremely sparse, so index encoding is ~density*numel entries).
    pub fn to_json(&self) -> Json {
        let ones: Vec<usize> = self
            .data
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == 1.0)
            .map(|(i, _)| i)
            .collect();
        Json::obj(vec![
            ("shape", Json::arr_usize(&self.shape)),
            ("ones", Json::arr_usize(&ones)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Mask> {
        let shape = j.req("shape")?.as_usize_vec().unwrap_or_default();
        let mut mask = Mask::zeros(&shape);
        for idx in j.req("ones")?.as_usize_vec().unwrap_or_default() {
            if idx >= mask.data.len() {
                bail!("mask index {idx} out of bounds for shape {shape:?}");
            }
            mask.data[idx] = 1.0;
        }
        Ok(mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_and_ratio() {
        let m = Mask::from_data(&[2, 4], vec![1., 0., 0., 0., 1., 1., 0., 0.]).unwrap();
        assert_eq!(m.count_ones(), 3);
        assert!((m.density() - 0.375).abs() < 1e-12);
        assert!((m.mask_ratio() - 0.625).abs() < 1e-12);
        assert_eq!(m.row_counts().unwrap(), vec![1, 2]);
    }

    #[test]
    fn rejects_nonbinary() {
        assert!(Mask::from_data(&[2], vec![0.5, 1.0]).is_err());
    }

    #[test]
    fn nm_invariant() {
        let m = Mask::from_data(&[1, 8], vec![1., 1., 0., 0., 0., 1., 1., 0.]).unwrap();
        assert!(m.satisfies_nm(2, 4));
        assert!(!m.satisfies_nm(1, 4));
        assert!(!m.satisfies_nm(2, 3)); // indivisible
    }

    #[test]
    fn json_roundtrip() {
        let m = Mask::from_data(&[2, 3], vec![0., 1., 0., 1., 0., 1.]).unwrap();
        let m2 = Mask::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
    }
}
