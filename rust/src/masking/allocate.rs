//! Trainable-weight allocation (paper Alg. 1 step 3 + §III-C).
//!
//! The paper's contribution: *per-neuron* top-K allocation distributes the
//! trainable budget evenly across depth, vs. the global top-k baseline that
//! concentrates it in top layers (reproduced in the allocation ablation).
//!
//! Tie-breaking is pinned to `lax.top_k` semantics (value desc, index asc)
//! so Rust, Pallas and ref.py select identical coordinate sets.

use anyhow::{bail, Result};

use super::mask::Mask;
use crate::util::rng::Rng;

/// Select the indices of the top-k entries of `row` (value desc, index asc).
///
/// Ordering is `f32::total_cmp`, which pins NaN to a documented place in
/// the total order: +NaN sorts above +inf (selected first), -NaN below
/// -inf (selected last). `partial_cmp(..).unwrap_or(Equal)` left NaN rows
/// at the mercy of the sort algorithm's comparison schedule, breaking
/// Rust/Pallas/ref.py parity.
fn topk_indices(row: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(row.len());
    let mut idx: Vec<usize> = (0..row.len()).collect();
    // Stable selection: sort by value desc; ties keep index order because
    // sort_by is stable over the ascending index sequence.
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    idx.truncate(k);
    idx
}

/// Per-neuron top-K (TaskEdge): each output neuron (row) keeps exactly
/// min(k, d_in) trainable input connections.
pub fn per_neuron_topk(scores: &[f32], d_out: usize, d_in: usize, k: usize) -> Result<Mask> {
    if scores.len() != d_out * d_in {
        bail!("scores len {} != {d_out}x{d_in}", scores.len());
    }
    if k == 0 {
        bail!("k must be >= 1");
    }
    let mut mask = Mask::zeros(&[d_out, d_in]);
    for i in 0..d_out {
        let row = &scores[i * d_in..(i + 1) * d_in];
        for j in topk_indices(row, k) {
            mask.data[i * d_in + j] = 1.0;
        }
    }
    Ok(mask)
}

/// Structured N:M: within every group of `m` consecutive columns keep the
/// top `n` (sparse-tensor-core layout, §III-C).
pub fn nm_select(scores: &[f32], d_out: usize, d_in: usize, n: usize, m: usize) -> Result<Mask> {
    if scores.len() != d_out * d_in {
        bail!("scores len {} != {d_out}x{d_in}", scores.len());
    }
    if d_in % m != 0 {
        bail!("d_in={d_in} not divisible by m={m}");
    }
    if n == 0 || n > m {
        bail!("need 1 <= n <= m, got n={n} m={m}");
    }
    let mut mask = Mask::zeros(&[d_out, d_in]);
    for i in 0..d_out {
        for g in 0..d_in / m {
            let base = i * d_in + g * m;
            let group = &scores[base..base + m];
            for j in topk_indices(group, n) {
                mask.data[base + j] = 1.0;
            }
        }
    }
    Ok(mask)
}

/// Global top-fraction across MULTIPLE tensors at once — the baseline the
/// paper argues against (selection concentrates in high-score layers).
/// Returns one mask per input tensor, preserving order.
pub fn global_top_frac(
    tensors: &[(&[f32], usize, usize)], // (scores, d_out, d_in)
    frac: f64,
) -> Result<Vec<Mask>> {
    if !(0.0..=1.0).contains(&frac) {
        bail!("frac must be in [0,1], got {frac}");
    }
    let total: usize = tensors.iter().map(|(s, _, _)| s.len()).sum();
    let budget = ((total as f64) * frac).round() as usize;
    // (score, tensor idx, flat idx) global selection
    let mut entries: Vec<(f32, usize, usize)> = Vec::with_capacity(total);
    for (t, (s, d_out, d_in)) in tensors.iter().enumerate() {
        if s.len() != d_out * d_in {
            bail!("tensor {t}: scores len {} != {d_out}x{d_in}", s.len());
        }
        for (i, &v) in s.iter().enumerate() {
            entries.push((v, t, i));
        }
    }
    // same pinned NaN semantics as `topk_indices`: total_cmp keeps the
    // global selection deterministic even with NaN scores
    entries.sort_by(|a, b| {
        b.0.total_cmp(&a.0)
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
    });
    let mut masks: Vec<Mask> = tensors
        .iter()
        .map(|(_, d_out, d_in)| Mask::zeros(&[*d_out, *d_in]))
        .collect();
    for &(_, t, i) in entries.iter().take(budget) {
        masks[t].data[i] = 1.0;
    }
    Ok(masks)
}

/// Random selection at a given density (control baseline).
pub fn random_frac(d_out: usize, d_in: usize, frac: f64, rng: &mut Rng) -> Result<Mask> {
    if !(0.0..=1.0).contains(&frac) {
        bail!("frac must be in [0,1], got {frac}");
    }
    let numel = d_out * d_in;
    let budget = ((numel as f64) * frac).round() as usize;
    let perm = rng.permutation(numel);
    let mut mask = Mask::zeros(&[d_out, d_in]);
    for &i in perm.iter().take(budget) {
        mask.data[i] = 1.0;
    }
    Ok(mask)
}

/// Per-layer share of trainable parameters — the depth-distribution metric
/// behind the paper's §III-C argument (used by the allocation ablation).
pub fn layer_distribution(masks: &[&Mask]) -> Vec<f64> {
    let total: usize = masks.iter().map(|m| m.count_ones()).sum();
    masks
        .iter()
        .map(|m| {
            if total == 0 {
                0.0
            } else {
                m.count_ones() as f64 / total as f64
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn per_neuron_budget_exact() {
        let scores = vec![0.1, 0.9, 0.5, 0.3, 0.8, 0.2, 0.7, 0.4];
        let m = per_neuron_topk(&scores, 2, 4, 2).unwrap();
        assert_eq!(m.row_counts().unwrap(), vec![2, 2]);
        // row 0: top2 of [0.1,0.9,0.5,0.3] = idx 1,2
        assert_eq!(&m.data[0..4], &[0.0, 1.0, 1.0, 0.0]);
        // row 1: top2 of [0.8,0.2,0.7,0.4] = idx 0,2
        assert_eq!(&m.data[4..8], &[1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn tie_break_lowest_index() {
        let scores = vec![1.0; 6];
        let m = per_neuron_topk(&scores, 1, 6, 3).unwrap();
        assert_eq!(m.data, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn nan_scores_sort_deterministically() {
        // +NaN pins above +inf under total_cmp: selected first, then the
        // remaining budget goes to the true maxima
        let scores = [0.5, f32::NAN, f32::NEG_INFINITY, 0.75];
        let m = per_neuron_topk(&scores, 1, 4, 2).unwrap();
        assert_eq!(m.data, vec![0.0, 1.0, 0.0, 1.0]);
        // deterministic across repeated calls
        let m2 = per_neuron_topk(&scores, 1, 4, 2).unwrap();
        assert_eq!(m.data, m2.data);
        // -NaN pins below -inf: never selected while finite scores remain
        let neg = [-f32::NAN, 0.0, -1.0, f32::NEG_INFINITY];
        let mneg = per_neuron_topk(&neg, 1, 4, 2).unwrap();
        assert_eq!(mneg.data, vec![0.0, 1.0, 1.0, 0.0]);
        // all-NaN rows still honour the budget, lowest indices first
        let mnan = per_neuron_topk(&[f32::NAN; 4], 1, 4, 2).unwrap();
        assert_eq!(mnan.data, vec![1.0, 1.0, 0.0, 0.0]);
        // global baseline shares the pinned semantics
        let g = global_top_frac(&[(&scores[..], 1, 4)], 0.5).unwrap();
        assert_eq!(g[0].data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn k_larger_than_din_saturates() {
        let m = per_neuron_topk(&[1.0, 2.0], 1, 2, 10).unwrap();
        assert_eq!(m.count_ones(), 2);
    }

    #[test]
    fn nm_exact_groups() {
        let scores = vec![0.9, 0.1, 0.5, 0.6, 0.2, 0.8, 0.3, 0.4];
        let m = nm_select(&scores, 1, 8, 2, 4).unwrap();
        assert!(m.satisfies_nm(2, 4));
        assert_eq!(&m.data[0..4], &[1.0, 0.0, 0.0, 1.0]);
        assert_eq!(&m.data[4..8], &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn global_budget_total() {
        let s1 = vec![10.0, 9.0, 8.0, 7.0];
        let s2 = vec![1.0, 2.0, 3.0, 4.0];
        let masks = global_top_frac(&[(&s1, 2, 2), (&s2, 2, 2)], 0.5).unwrap();
        let total: usize = masks.iter().map(|m| m.count_ones()).sum();
        assert_eq!(total, 4);
        // all budget lands in tensor 1 (the "concentration" pathology)
        assert_eq!(masks[0].count_ones(), 4);
        assert_eq!(masks[1].count_ones(), 0);
    }

    #[test]
    fn random_density() {
        let mut rng = Rng::new(0);
        let m = random_frac(20, 50, 0.1, &mut rng).unwrap();
        assert_eq!(m.count_ones(), 100);
    }

    #[test]
    fn prop_per_neuron_budget_holds() {
        check(
            "per-neuron-topk-budget",
            40,
            |r| {
                let d_out = 1 + r.below(20);
                let d_in = 1 + r.below(64);
                let k = 1 + r.below(16);
                let scores = r.normal_vec(d_out * d_in, 1.0);
                (d_out, d_in, k, scores)
            },
            |(d_out, d_in, k, scores)| {
                let m = per_neuron_topk(scores, *d_out, *d_in, *k)
                    .map_err(|e| e.to_string())?;
                let want = (*k).min(*d_in);
                for (i, c) in m.row_counts().unwrap().iter().enumerate() {
                    ensure(*c == want, format!("row {i} has {c} != {want}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_nm_invariant_holds() {
        check(
            "nm-invariant",
            40,
            |r| {
                let d_out = 1 + r.below(12);
                let groups = 1 + r.below(10);
                let (n, m) = [(1usize, 2usize), (2, 4), (1, 4), (4, 8)][r.below(4)];
                let scores = r.normal_vec(d_out * groups * m, 1.0);
                (d_out, groups * m, n, m, scores)
            },
            |(d_out, d_in, n, m, scores)| {
                let mask = nm_select(scores, *d_out, *d_in, *n, *m)
                    .map_err(|e| e.to_string())?;
                ensure(mask.satisfies_nm(*n, *m), "N:M violated")
            },
        );
    }

    #[test]
    fn prop_selected_scores_dominate_unselected() {
        check(
            "topk-selects-max",
            30,
            |r| {
                let d_in = 2 + r.below(40);
                let k = 1 + r.below(d_in.min(8));
                let scores = r.normal_vec(d_in, 1.0);
                (d_in, k, scores)
            },
            |(d_in, k, scores)| {
                let m = per_neuron_topk(scores, 1, *d_in, *k)
                    .map_err(|e| e.to_string())?;
                let sel_min = scores
                    .iter()
                    .zip(&m.data)
                    .filter(|(_, &b)| b == 1.0)
                    .map(|(s, _)| *s)
                    .fold(f32::INFINITY, f32::min);
                let unsel_max = scores
                    .iter()
                    .zip(&m.data)
                    .filter(|(_, &b)| b == 0.0)
                    .map(|(s, _)| *s)
                    .fold(f32::NEG_INFINITY, f32::max);
                ensure(sel_min >= unsel_max, format!("{sel_min} < {unsel_max}"))
            },
        );
    }
}
