//! Host-side twin of the L1 allocation kernels: importance scoring (Eq. 2),
//! per-neuron top-K / N:M / global / random allocation (Alg. 1 step 3), and
//! the [`Mask`] type. Pinned to the Pallas kernels via golden vectors.

pub mod allocate;
pub mod mask;
pub mod scores;

pub use allocate::{global_top_frac, layer_distribution, nm_select,
                   per_neuron_topk, random_frac};
pub use mask::Mask;
pub use scores::{importance_scores, magnitude_scores, GradAccumulator,
                 StatAccumulator};
