//! `TaskDelta` — the sparse, mask-keyed representation of one fine-tuned
//! task over a frozen backbone (the paper's <0.1%-of-parameters claim made
//! concrete as a storage/transport format).
//!
//! A fine-tuned task is NOT a new `ParamStore`: under every TaskEdge
//! strategy only a tiny masked subset of backbone coordinates moves, plus a
//! fresh classification head and (per family) LoRA factors / prompt /
//! adapter tensors. `TaskDelta` stores exactly that:
//!
//! - `sparse`:  per-tensor `(indices, values)` pairs for masked dense-family
//!   updates — flat row-major `u32` indices (strictly increasing) and the
//!   *tuned* `f32` value at each index. Storing tuned values (not additive
//!   differences) makes `extract -> apply_to` bit-exact: `base + (tuned -
//!   base)` does not round-trip in f32, `store[i] = tuned[i]` does.
//! - `dense`:   full replacement tensors where sparse encoding would be
//!   larger than the tensor itself (fresh `head.w`/`head.b`, BitFit biases,
//!   `Strategy::Full`). Break-even is density 0.5: a sparse entry costs 8
//!   bytes (u32 index + f32 value) vs 4 bytes per dense value.
//! - `lora`:    `(B, A, mask)` factors per LoRA target — the Eq. 6 delta
//!   `(B·A) ⊙ M` is merged into the backbone weight at apply time. All-ones
//!   masks (plain LoRA) are tagged, not materialized, on disk.
//! - `extra`:   task tensors with no backbone slot (VPT prompt, adapter
//!   stacks), carried for the aux-family eval graphs; `apply_to` leaves
//!   them alone.
//!
//! # Binary format (version 1, little-endian, magic `TEDL`)
//!
//! ```text
//! "TEDL" | u16 version
//! str config_name | str strategy | str task        (str = u16 len + utf8)
//! u32 n_sparse  { str name | shape | u32 nnz | u32 idx[nnz] | f32 val[nnz] }
//! u32 n_dense   { str name | shape | f32 val[numel] }
//! u32 n_lora    { str name | tensor B | tensor A |
//!                 u8 mask_tag (1 = all-ones) | shape |
//!                 if tag==0: u32 nnz | u32 idx[nnz] }
//! u32 n_extra   { str name | shape | f32 val[numel] }
//! ```
//!
//! where `shape = u8 rank | u64 dim[rank]` and `tensor = shape | f32
//! val[numel]` (the same conventions as the `ParamStore` checkpoint).
//! Readers must reject a bad magic or an unknown version — the format is
//! versioned precisely so later PRs can add quantized value planes.
//!
//! `file_bytes()` is the exact serialized size, asserted against the
//! on-disk artifact in tests and used by `peft::accounting` for the
//! delta-vs-full-checkpoint comparisons.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::masking::Mask;
use crate::runtime::HostTensor;
use crate::vit::ParamStore;

const MAGIC: &[u8; 4] = b"TEDL"; // TaskEdge DeLta
const VERSION: u16 = 1;

/// Sparse replacement plane for one backbone tensor: `store[idx] = value`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseTensorDelta {
    /// shape of the tensor this delta targets (stale-shape guard)
    pub shape: Vec<usize>,
    /// flat row-major coordinates, strictly increasing
    pub indices: Vec<u32>,
    /// tuned value at each coordinate
    pub values: Vec<f32>,
}

/// Low-rank factors for one LoRA target: weight delta `(B·A) ⊙ M`.
#[derive(Debug, Clone, PartialEq)]
pub struct LoraFactorDelta {
    /// (d_in, r)
    pub b: HostTensor,
    /// (r, d_out)
    pub a: HostTensor,
    /// (d_in, d_out) — all-ones for plain LoRA, Eq. 2 support for SparseLora
    pub mask: Mask,
}

/// One fine-tuned task, stored as its difference from the backbone.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TaskDelta {
    /// backbone config this delta was extracted against
    pub config_name: String,
    /// strategy name (informational, e.g. `taskedge_k8`)
    pub strategy: String,
    /// task name (informational, e.g. `pets`)
    pub task: String,
    /// sparse masked updates, keyed by backbone tensor name
    pub sparse: BTreeMap<String, SparseTensorDelta>,
    /// full tensor replacements, keyed by backbone tensor name
    pub dense: BTreeMap<String, HostTensor>,
    /// LoRA factors, keyed by target backbone tensor name
    pub lora: BTreeMap<String, LoraFactorDelta>,
    /// task tensors with no backbone slot (prompt, adapters)
    pub extra: BTreeMap<String, HostTensor>,
}

impl TaskDelta {
    pub fn new(config_name: &str) -> TaskDelta {
        TaskDelta { config_name: config_name.to_string(), ..Default::default() }
    }

    // -- extraction ---------------------------------------------------------

    /// Value-level difference `tuned - backbone`: every coordinate whose f32
    /// bits changed is captured, as a sparse plane or a dense replacement
    /// (whichever serializes smaller). Tensors that did not move are absent.
    pub fn diff(backbone: &ParamStore, tuned: &ParamStore) -> Result<TaskDelta> {
        if backbone.config_name != tuned.config_name {
            bail!(
                "diff across configs: backbone {:?} vs tuned {:?}",
                backbone.config_name,
                tuned.config_name
            );
        }
        let mut delta = TaskDelta::new(&backbone.config_name);
        for name in backbone.order() {
            let base = backbone.get(name)?;
            let new = tuned.get(name)?;
            if base.shape != new.shape {
                bail!(
                    "diff {name:?}: shape {:?} != {:?}",
                    new.shape,
                    base.shape
                );
            }
            let (b, n) = match (base.f32s(), new.f32s()) {
                (Ok(b), Ok(n)) => (b, n),
                _ => {
                    if base != new {
                        bail!("non-f32 param {name:?} changed — unsupported");
                    }
                    continue;
                }
            };
            // bit-level compare: catches -0.0 vs 0.0 and NaN payloads too
            let indices: Vec<u32> = (0..n.len() as u32)
                .filter(|&i| b[i as usize].to_bits() != n[i as usize].to_bits())
                .collect();
            if indices.is_empty() {
                continue;
            }
            if indices.len() * 2 >= n.len() {
                delta.dense.insert(name.clone(), new.clone());
            } else {
                let values = indices.iter().map(|&i| n[i as usize]).collect();
                delta.sparse.insert(
                    name.clone(),
                    SparseTensorDelta { shape: new.shape.clone(), indices, values },
                );
            }
        }
        Ok(delta)
    }

    /// [`TaskDelta::diff`] plus the Alg. 1 invariant check: every changed
    /// coordinate of a masked tensor must lie inside its mask. Off-mask
    /// drift means a training kernel corrupted frozen state — fail loudly
    /// instead of shipping the corruption.
    ///
    /// Drift is judged NUMERICALLY (`a != b`): diff's bit-level compare
    /// also captures sign flips of zero (`-0.0` -> `+0.0`), which `x - 0.0`
    /// style masked updates can legally produce on frozen coordinates;
    /// those still land in the delta (so apply stays bit-exact) but are
    /// not corruption. A NaN appearing anywhere counts as drift.
    pub fn extract(
        backbone: &ParamStore,
        tuned: &ParamStore,
        masks: &BTreeMap<String, Mask>,
    ) -> Result<TaskDelta> {
        let delta = Self::diff(backbone, tuned)?;
        let drifted = |a: f32, b: f32| a != b || a.is_nan() || b.is_nan();
        for (name, sd) in &delta.sparse {
            if let Some(m) = masks.get(name) {
                let base = backbone.get(name)?.f32s()?;
                for (&i, &v) in sd.indices.iter().zip(&sd.values) {
                    if m.data.get(i as usize) != Some(&1.0)
                        && drifted(base[i as usize], v)
                    {
                        bail!(
                            "tensor {name:?}: coordinate {i} moved outside \
                             its mask (off-mask drift)"
                        );
                    }
                }
            }
        }
        for (name, t) in &delta.dense {
            if let Some(m) = masks.get(name) {
                let base = backbone.get(name)?.f32s()?;
                let vals = t.f32s()?;
                for (i, (&bv, &tv)) in base.iter().zip(vals).enumerate() {
                    if drifted(bv, tv) && m.data.get(i) != Some(&1.0) {
                        bail!(
                            "tensor {name:?}: coordinate {i} moved outside \
                             its mask (off-mask drift)"
                        );
                    }
                }
            }
        }
        Ok(delta)
    }

    // -- application --------------------------------------------------------

    /// Check this delta can be applied to `store` WITHOUT mutating anything:
    /// config name, target existence, shapes, dtypes, index bounds and
    /// ordering. Application never corrupts a store: it validates fully
    /// first, so a stale or mismatched delta is a clean error.
    pub fn validate_against(&self, store: &ParamStore) -> Result<()> {
        if store.config_name != self.config_name {
            bail!(
                "delta for config {:?} cannot apply to store of config {:?}",
                self.config_name,
                store.config_name
            );
        }
        for (name, sd) in &self.sparse {
            let t = store
                .get(name)
                .with_context(|| format!("sparse delta target {name:?}"))?;
            if t.shape != sd.shape {
                bail!(
                    "sparse delta {name:?}: stale shape {:?}, store has {:?}",
                    sd.shape,
                    t.shape
                );
            }
            t.f32s().with_context(|| format!("sparse delta target {name:?}"))?;
            if sd.indices.len() != sd.values.len() {
                bail!(
                    "sparse delta {name:?}: {} indices vs {} values",
                    sd.indices.len(),
                    sd.values.len()
                );
            }
            let numel = t.numel();
            let mut prev: Option<u32> = None;
            for &i in &sd.indices {
                if i as usize >= numel {
                    bail!(
                        "sparse delta {name:?}: index {i} out of bounds for \
                         {numel} elements (stale mask shape?)"
                    );
                }
                if let Some(p) = prev {
                    if i <= p {
                        bail!(
                            "sparse delta {name:?}: indices not strictly \
                             increasing ({p} then {i})"
                        );
                    }
                }
                prev = Some(i);
            }
        }
        for (name, t) in &self.dense {
            let cur = store
                .get(name)
                .with_context(|| format!("dense delta target {name:?}"))?;
            if cur.shape != t.shape {
                bail!(
                    "dense delta {name:?}: stale shape {:?}, store has {:?}",
                    t.shape,
                    cur.shape
                );
            }
            t.f32s()
                .with_context(|| format!("dense delta plane {name:?}"))?;
        }
        for (name, lf) in &self.lora {
            let w = store
                .get(name)
                .with_context(|| format!("lora delta target {name:?}"))?;
            if w.shape.len() != 2 {
                bail!("lora delta target {name:?} is not 2-D: {:?}", w.shape);
            }
            w.f32s().with_context(|| format!("lora delta target {name:?}"))?;
            lf.b.f32s()
                .with_context(|| format!("lora B factor for {name:?}"))?;
            lf.a.f32s()
                .with_context(|| format!("lora A factor for {name:?}"))?;
            let (d_in, d_out) = (w.shape[0], w.shape[1]);
            if lf.b.shape.len() != 2 || lf.a.shape.len() != 2 {
                bail!("lora factors for {name:?} are not 2-D");
            }
            let r = lf.b.shape[1];
            if lf.b.shape != [d_in, r] || lf.a.shape != [r, d_out] {
                bail!(
                    "lora factors for {name:?}: B {:?} / A {:?} do not \
                     factor a {:?} weight",
                    lf.b.shape,
                    lf.a.shape,
                    w.shape
                );
            }
            if lf.mask.shape != w.shape {
                bail!(
                    "lora mask for {name:?}: stale shape {:?}, weight is {:?}",
                    lf.mask.shape,
                    w.shape
                );
            }
        }
        Ok(())
    }

    /// Adapted parameters for serving: a copy of `backbone` with this delta
    /// merged in (`extra` tensors are not merged — they have no backbone
    /// slot). The backbone itself is never mutated.
    pub fn apply_to(&self, backbone: &ParamStore) -> Result<ParamStore> {
        let mut out = backbone.clone();
        self.apply_in_place(&mut out)?;
        Ok(out)
    }

    /// Merge into `store` in place. Validates everything up front, so on
    /// error the store is untouched.
    pub fn apply_in_place(&self, store: &mut ParamStore) -> Result<()> {
        self.validate_against(store)?;
        for (name, sd) in &self.sparse {
            let mut t = store.get(name)?.clone();
            let d = t.f32s_mut()?;
            for (&i, &v) in sd.indices.iter().zip(&sd.values) {
                d[i as usize] = v;
            }
            store.set(name, t)?;
        }
        for (name, t) in &self.dense {
            store.set(name, t.clone())?;
        }
        for (name, lf) in &self.lora {
            let mut t = store.get(name)?.clone();
            let (d_in, d_out) = (t.shape[0], t.shape[1]);
            let r = lf.b.shape[1];
            let w = t.f32s_mut()?;
            let b = lf.b.f32s()?;
            let a = lf.a.f32s()?;
            for i in 0..d_in {
                for j in 0..d_out {
                    if lf.mask.data[i * d_out + j] == 1.0 {
                        let mut acc = 0.0f32;
                        for k in 0..r {
                            acc += b[i * r + k] * a[k * d_out + j];
                        }
                        w[i * d_out + j] += acc;
                    }
                }
            }
            store.set(name, t)?;
        }
        Ok(())
    }

    /// Undo this delta on `store` by restoring the touched tensors from
    /// `backbone` (bit-exact: sparse planes restore per coordinate, dense
    /// and LoRA targets restore wholesale).
    pub fn revert(&self, store: &mut ParamStore, backbone: &ParamStore) -> Result<()> {
        self.validate_against(store)?;
        self.validate_against(backbone)?;
        for (name, sd) in &self.sparse {
            let base = backbone.get(name)?.f32s()?;
            let mut t = store.get(name)?.clone();
            let d = t.f32s_mut()?;
            for &i in &sd.indices {
                d[i as usize] = base[i as usize];
            }
            store.set(name, t)?;
        }
        for name in self.dense.keys().chain(self.lora.keys()) {
            store.set(name, backbone.get(name)?.clone())?;
        }
        Ok(())
    }

    // -- size accounting ----------------------------------------------------

    /// Total stored f32 payload values (sparse + dense + factors + extra).
    pub fn num_values(&self) -> usize {
        self.sparse.values().map(|s| s.values.len()).sum::<usize>()
            + self.dense.values().map(|t| t.numel()).sum::<usize>()
            + self
                .lora
                .values()
                .map(|l| l.b.numel() + l.a.numel())
                .sum::<usize>()
            + self.extra.values().map(|t| t.numel()).sum::<usize>()
    }

    /// Exact serialized size in bytes (mirrors `save`; asserted in tests).
    pub fn file_bytes(&self) -> usize {
        let str_bytes = |s: &str| 2 + s.len();
        let shape_bytes = |shape: &[usize]| 1 + 8 * shape.len();
        let tensor_bytes =
            |t: &HostTensor| shape_bytes(&t.shape) + 4 * t.numel();
        let mut n = 4 + 2 // magic + version
            + str_bytes(&self.config_name)
            + str_bytes(&self.strategy)
            + str_bytes(&self.task)
            + 4 * 4; // four section counts
        for (name, sd) in &self.sparse {
            n += str_bytes(name)
                + shape_bytes(&sd.shape)
                + 4
                + 8 * sd.indices.len();
        }
        for (name, t) in &self.dense {
            n += str_bytes(name) + tensor_bytes(t);
        }
        for (name, lf) in &self.lora {
            n += str_bytes(name) + tensor_bytes(&lf.b) + tensor_bytes(&lf.a) + 1;
            let ones = lf.mask.count_ones();
            if ones != lf.mask.numel() {
                n += shape_bytes(&lf.mask.shape) + 4 + 4 * ones;
            } else {
                n += shape_bytes(&lf.mask.shape);
            }
        }
        for (name, t) in &self.extra {
            n += str_bytes(name) + tensor_bytes(t);
        }
        n
    }

    // -- binary checkpoint --------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {path:?}"))?,
        );
        self.write_to(&mut f)
    }

    /// Exact serialized size is [`TaskDelta::file_bytes`] — the wire-upload
    /// payload and a drained `.tedl` file are byte-identical by
    /// construction, which is what lets the round journal vouch for
    /// network uploads with the same digest it uses for local drains.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::with_capacity(self.file_bytes());
        self.write_to(&mut buf)?;
        Ok(buf)
    }

    /// Serialize into any writer — exactly the bytes `save` puts on disk.
    pub fn write_to<W: Write>(&self, f: &mut W) -> Result<()> {
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        write_str(&mut f, &self.config_name)?;
        write_str(&mut f, &self.strategy)?;
        write_str(&mut f, &self.task)?;

        f.write_all(&(self.sparse.len() as u32).to_le_bytes())?;
        for (name, sd) in &self.sparse {
            write_str(&mut f, name)?;
            write_shape(&mut f, &sd.shape)?;
            f.write_all(&(sd.indices.len() as u32).to_le_bytes())?;
            for &i in &sd.indices {
                f.write_all(&i.to_le_bytes())?;
            }
            for &v in &sd.values {
                f.write_all(&v.to_le_bytes())?;
            }
        }

        f.write_all(&(self.dense.len() as u32).to_le_bytes())?;
        for (name, t) in &self.dense {
            write_str(&mut f, name)?;
            write_tensor(&mut f, t)?;
        }

        f.write_all(&(self.lora.len() as u32).to_le_bytes())?;
        for (name, lf) in &self.lora {
            write_str(&mut f, name)?;
            write_tensor(&mut f, &lf.b)?;
            write_tensor(&mut f, &lf.a)?;
            let ones: Vec<u32> = lf
                .mask
                .data
                .iter()
                .enumerate()
                .filter(|(_, &v)| v == 1.0)
                .map(|(i, _)| i as u32)
                .collect();
            if ones.len() == lf.mask.numel() {
                f.write_all(&[1u8])?; // all-ones: shape only
                write_shape(&mut f, &lf.mask.shape)?;
            } else {
                f.write_all(&[0u8])?;
                write_shape(&mut f, &lf.mask.shape)?;
                f.write_all(&(ones.len() as u32).to_le_bytes())?;
                for i in ones {
                    f.write_all(&i.to_le_bytes())?;
                }
            }
        }

        f.write_all(&(self.extra.len() as u32).to_le_bytes())?;
        for (name, t) in &self.extra {
            write_str(&mut f, name)?;
            write_tensor(&mut f, t)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TaskDelta> {
        let file_len = std::fs::metadata(path)
            .with_context(|| format!("stat delta {path:?}"))?
            .len() as usize;
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening delta {path:?}"))?,
        );
        Self::read_from(&mut f, file_len)
            .with_context(|| format!("loading delta {path:?}"))
    }

    /// Parse a delta from in-memory bytes — the networked-upload path.
    /// Validation is identical to [`TaskDelta::load`]: the slice length
    /// bounds every allocation the same way the file length does.
    pub fn from_bytes(bytes: &[u8]) -> Result<TaskDelta> {
        let mut r = bytes;
        Self::read_from(&mut r, bytes.len())
    }

    /// Shared reader behind `load`/`from_bytes`. All sizes come from the
    /// payload and are UNTRUSTED: every allocation is bounded by
    /// `max_bytes` (the artifact's own length) so a truncated or corrupted
    /// payload fails with a clean error, not an OOM abort.
    pub fn read_from<R: Read>(f: &mut R, max_bytes: usize) -> Result<TaskDelta> {
        let file_len = max_bytes;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a TaskEdge delta (bad magic)");
        }
        let mut ver = [0u8; 2];
        f.read_exact(&mut ver)?;
        let ver = u16::from_le_bytes(ver);
        if ver != VERSION {
            bail!("unsupported delta version {ver} (want {VERSION})");
        }
        let mut delta = TaskDelta {
            config_name: read_str(&mut f)?,
            strategy: read_str(&mut f)?,
            task: read_str(&mut f)?,
            ..Default::default()
        };

        for _ in 0..read_u32(&mut f)? {
            let name = read_str(&mut f)?;
            let shape = read_shape(&mut f)?;
            let nnz = read_u32(&mut f)? as usize;
            if nnz.saturating_mul(8) > file_len {
                bail!(
                    "sparse plane {name:?} claims {nnz} entries — more than \
                     the payload can hold (corrupt?)"
                );
            }
            let mut indices = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                indices.push(read_u32(&mut f)?);
            }
            let mut values = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                values.push(read_f32(&mut f)?);
            }
            delta
                .sparse
                .insert(name, SparseTensorDelta { shape, indices, values });
        }

        for _ in 0..read_u32(&mut f)? {
            let name = read_str(&mut f)?;
            delta.dense.insert(name, read_tensor(&mut f, file_len)?);
        }

        for _ in 0..read_u32(&mut f)? {
            let name = read_str(&mut f)?;
            let b = read_tensor(&mut f, file_len)?;
            let a = read_tensor(&mut f, file_len)?;
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let shape = read_shape(&mut f)?;
            // the mask is stored as a bare shape (all-ones) or indices, so
            // its in-memory size is not directly file-bounded — but it must
            // factor through B/A, whose payloads ARE file-bounded above
            if b.shape.len() != 2
                || a.shape.len() != 2
                || shape != [b.shape[0], a.shape[1]]
            {
                bail!(
                    "lora mask {name:?} shape {shape:?} does not match \
                     factors B {:?} / A {:?} (corrupt?)",
                    b.shape,
                    a.shape
                );
            }
            checked_numel(&shape)?;
            let mask = match tag[0] {
                1 => Mask::ones(&shape),
                0 => {
                    let mut m = Mask::zeros(&shape);
                    for _ in 0..read_u32(&mut f)? {
                        let i = read_u32(&mut f)? as usize;
                        if i >= m.data.len() {
                            bail!("lora mask index {i} out of bounds");
                        }
                        m.data[i] = 1.0;
                    }
                    m
                }
                t => bail!("unknown lora mask tag {t}"),
            };
            delta.lora.insert(name, LoraFactorDelta { b, a, mask });
        }

        for _ in 0..read_u32(&mut f)? {
            let name = read_str(&mut f)?;
            delta.extra.insert(name, read_tensor(&mut f, file_len)?);
        }
        Ok(delta)
    }
}

// -- little-endian plumbing (shared conventions with ParamStore) ------------

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    let b = s.as_bytes();
    if b.len() > u16::MAX as usize {
        bail!("string too long for delta format: {} bytes", b.len());
    }
    w.write_all(&(b.len() as u16).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let mut len = [0u8; 2];
    r.read_exact(&mut len)?;
    let mut b = vec![0u8; u16::from_le_bytes(len) as usize];
    r.read_exact(&mut b)?;
    String::from_utf8(b).context("bad utf8 string in delta")
}

fn write_shape<W: Write>(w: &mut W, shape: &[usize]) -> Result<()> {
    if shape.len() > u8::MAX as usize {
        bail!("rank {} too large for delta format", shape.len());
    }
    w.write_all(&(shape.len() as u8).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_shape<R: Read>(r: &mut R) -> Result<Vec<usize>> {
    let mut rank = [0u8; 1];
    r.read_exact(&mut rank)?;
    let mut shape = Vec::with_capacity(rank[0] as usize);
    for _ in 0..rank[0] {
        let mut d = [0u8; 8];
        r.read_exact(&mut d)?;
        shape.push(u64::from_le_bytes(d) as usize);
    }
    Ok(shape)
}

fn write_tensor<W: Write>(w: &mut W, t: &HostTensor) -> Result<()> {
    write_shape(w, &t.shape)?;
    for &v in t.f32s().context("delta tensors must be f32")? {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Overflow-safe element count for a file-supplied shape.
fn checked_numel(shape: &[usize]) -> Result<usize> {
    shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .with_context(|| format!("tensor shape {shape:?} overflows usize"))
}

/// Read one dense f32 tensor. `max_bytes` is the containing file's length:
/// a shape claiming more payload than the file holds is corrupt, and
/// failing here keeps allocations bounded by the artifact's actual size.
fn read_tensor<R: Read>(r: &mut R, max_bytes: usize) -> Result<HostTensor> {
    let shape = read_shape(r)?;
    let numel = checked_numel(&shape)?;
    if numel.saturating_mul(4) > max_bytes {
        bail!(
            "delta tensor of shape {shape:?} claims {numel} values — more \
             than the file can hold (corrupt?)"
        );
    }
    let mut bytes = vec![0u8; numel * 4];
    r.read_exact(&mut bytes)?;
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    HostTensor::from_f32(&shape, data)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelConfig};
    use crate::util::rng::Rng;

    fn mini_cfg() -> ModelConfig {
        let m = Manifest::parse(
            r#"{"version":1,"batch":2,"configs":{"t":{
            "image_size":8,"patch_size":4,"dim":8,"depth":1,"heads":2,
            "mlp_ratio":2,"num_classes":4,"channels":3,"prompt_len":2,
            "adapter_dim":2,"lora_rank":2,"num_params":140,
            "params":[
              {"name":"blk.w","shape":[8,8],"init":"trunc_normal","masked":true,"stat":"blk.in"},
              {"name":"blk.b","shape":[8],"init":"zeros","masked":false,"stat":null},
              {"name":"head.w","shape":[8,4],"init":"trunc_normal","masked":true,"stat":"head.in"},
              {"name":"head.b","shape":[4],"init":"zeros","masked":false,"stat":null},
              {"name":"ln.scale","shape":[8],"init":"ones","masked":false,"stat":null}],
            "lora_targets":["blk.w"],"adapters":[]}},"artifacts":[]}"#,
        )
        .unwrap();
        m.config("t").unwrap().clone()
    }

    /// backbone + a tuned copy that moves 3 blk.w coords and the full head.
    fn tuned_pair() -> (ParamStore, ParamStore, BTreeMap<String, Mask>) {
        let cfg = mini_cfg();
        let backbone = ParamStore::init(&cfg, &mut Rng::new(7));
        let mut tuned = backbone.clone();
        let mut w = tuned.get("blk.w").unwrap().clone();
        let mut mask = Mask::zeros(&[8, 8]);
        for &i in &[3usize, 17, 40] {
            w.f32s_mut().unwrap()[i] += 0.5;
            mask.data[i] = 1.0;
        }
        tuned.set("blk.w", w).unwrap();
        let mut hw = tuned.get("head.w").unwrap().clone();
        for v in hw.f32s_mut().unwrap() {
            *v = 0.25;
        }
        tuned.set("head.w", hw).unwrap();
        tuned
            .set("head.b", HostTensor::from_f32(&[4], vec![1., 2., 3., 4.]).unwrap())
            .unwrap();
        let mut masks = BTreeMap::new();
        masks.insert("blk.w".to_string(), mask);
        masks.insert("head.w".to_string(), Mask::ones(&[8, 4]));
        masks.insert("head.b".to_string(), Mask::ones(&[4]));
        (backbone, tuned, masks)
    }

    fn assert_stores_bit_equal(a: &ParamStore, b: &ParamStore) {
        for name in a.order() {
            let x = a.get(name).unwrap().f32s().unwrap();
            let y = b.get(name).unwrap().f32s().unwrap();
            assert_eq!(x.len(), y.len(), "{name}");
            for (i, (p, q)) in x.iter().zip(y).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{name}[{i}]: {p} vs {q}");
            }
        }
    }

    #[test]
    fn extract_apply_roundtrip_is_bit_exact() {
        let (backbone, tuned, masks) = tuned_pair();
        let delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        // blk.w is sparse (3 of 64), head tensors are dense replacements
        assert_eq!(delta.sparse["blk.w"].indices, vec![3, 17, 40]);
        assert!(delta.dense.contains_key("head.w"));
        assert!(delta.dense.contains_key("head.b"));
        assert!(!delta.sparse.contains_key("ln.scale"));
        let adapted = delta.apply_to(&backbone).unwrap();
        assert_stores_bit_equal(&adapted, &tuned);
    }

    #[test]
    fn revert_restores_backbone_bit_exact() {
        let (backbone, tuned, masks) = tuned_pair();
        let delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        let mut store = delta.apply_to(&backbone).unwrap();
        delta.revert(&mut store, &backbone).unwrap();
        assert_stores_bit_equal(&store, &backbone);
    }

    #[test]
    fn off_mask_drift_is_detected() {
        let (backbone, tuned, mut masks) = tuned_pair();
        // shrink the mask so index 40 is no longer covered
        masks.get_mut("blk.w").unwrap().data[40] = 0.0;
        let err = TaskDelta::extract(&backbone, &tuned, &masks).unwrap_err();
        assert!(err.to_string().contains("off-mask"), "{err:#}");
    }

    #[test]
    fn mismatched_config_fails_cleanly() {
        let (backbone, tuned, masks) = tuned_pair();
        let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        delta.config_name = "other".into();
        let err = delta.apply_to(&backbone).unwrap_err();
        assert!(err.to_string().contains("config"), "{err:#}");
    }

    #[test]
    fn stale_shape_fails_without_corrupting_store() {
        let (backbone, tuned, masks) = tuned_pair();
        let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        delta.sparse.get_mut("blk.w").unwrap().shape = vec![16, 4];
        let mut store = backbone.clone();
        assert!(delta.apply_in_place(&mut store).is_err());
        assert_stores_bit_equal(&store, &backbone);

        // out-of-bounds index (stale mask) must also fail pre-mutation
        let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        delta.sparse.get_mut("blk.w").unwrap().indices[2] = 64;
        assert!(delta.apply_in_place(&mut store).is_err());
        assert_stores_bit_equal(&store, &backbone);
    }

    #[test]
    fn save_load_roundtrip_and_exact_size() {
        let (backbone, tuned, masks) = tuned_pair();
        let mut delta = TaskDelta::extract(&backbone, &tuned, &masks).unwrap();
        delta.strategy = "taskedge_k8".into();
        delta.task = "pets".into();
        delta.lora.insert(
            "blk.w".into(),
            LoraFactorDelta {
                b: HostTensor::from_f32(&[8, 2], (0..16).map(|i| i as f32).collect())
                    .unwrap(),
                a: HostTensor::from_f32(&[2, 8], (0..16).map(|i| i as f32 * 0.5).collect())
                    .unwrap(),
                mask: Mask::ones(&[8, 8]),
            },
        );
        delta.extra.insert(
            "prompt".into(),
            HostTensor::from_f32(&[2, 8], vec![0.125; 16]).unwrap(),
        );
        let path = std::env::temp_dir().join("taskedge_test_delta.bin");
        delta.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(on_disk, delta.file_bytes(), "file_bytes must be exact");
        let loaded = TaskDelta::load(&path).unwrap();
        assert_eq!(loaded, delta);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join("taskedge_test_delta_bad.bin");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(TaskDelta::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_oversized_size_claims() {
        // a corrupt header claiming ~4G sparse entries must error cleanly,
        // not attempt a multi-GB allocation
        let path = std::env::temp_dir().join("taskedge_test_delta_huge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"TEDL");
        bytes.extend_from_slice(&1u16.to_le_bytes());
        for _ in 0..3 {
            bytes.extend_from_slice(&0u16.to_le_bytes()); // empty strings
        }
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one sparse plane
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.push(b'w'); // name "w"
        bytes.push(1u8);
        bytes.extend_from_slice(&8u64.to_le_bytes()); // shape [8]
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd nnz
        std::fs::write(&path, &bytes).unwrap();
        let err = TaskDelta::load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("corrupt"),
            "expected corruption error, got: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lora_apply_matches_reference() {
        // w (2x2), B = [[1],[2]], A = [[3, 4]], mask = [[1,0],[1,1]]
        // (B*A) = [[3,4],[6,8]]  ->  delta applied = [[3,0],[6,8]]
        let cfg = Manifest::parse(
            r#"{"version":1,"batch":1,"configs":{"t":{
            "image_size":8,"patch_size":4,"dim":2,"depth":1,"heads":1,
            "mlp_ratio":1,"num_classes":2,"channels":3,"prompt_len":1,
            "adapter_dim":1,"lora_rank":1,"num_params":4,
            "params":[{"name":"w","shape":[2,2],"init":"zeros","masked":true,"stat":"w.in"}],
            "lora_targets":["w"],"adapters":[]}},"artifacts":[]}"#,
        )
        .unwrap()
        .config("t")
        .unwrap()
        .clone();
        let backbone = ParamStore::zeros_like(&cfg);
        let mut delta = TaskDelta::new("t");
        delta.lora.insert(
            "w".into(),
            LoraFactorDelta {
                b: HostTensor::from_f32(&[2, 1], vec![1.0, 2.0]).unwrap(),
                a: HostTensor::from_f32(&[1, 2], vec![3.0, 4.0]).unwrap(),
                mask: Mask::from_data(&[2, 2], vec![1., 0., 1., 1.]).unwrap(),
            },
        );
        let adapted = delta.apply_to(&backbone).unwrap();
        assert_eq!(
            adapted.get("w").unwrap().f32s().unwrap(),
            &[3.0, 0.0, 6.0, 8.0]
        );
        // revert restores the zero backbone exactly
        let mut store = adapted.clone();
        delta.revert(&mut store, &backbone).unwrap();
        assert_eq!(store.get("w").unwrap().f32s().unwrap(), &[0.0; 4]);
    }
}
