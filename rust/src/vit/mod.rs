//! Rust-side mirror of the L2 ViT parameter layout.
//!
//! The manifest's `ModelConfig.params` list is the single source of truth
//! for tensor names/shapes/order; this module owns host-side initialization
//! (pretraining starts from scratch in-repo), named storage, flat I/O in
//! spec order, and a simple binary checkpoint format.

pub mod delta;
pub mod store;

pub use delta::{LoraFactorDelta, SparseTensorDelta, TaskDelta};
pub use store::ParamStore;

use crate::runtime::ModelConfig;
use crate::util::rng::Rng;

/// Initialize one tensor per its manifest `init` kind.
/// trunc_normal matches the L2 init family (std 0.02, clipped at 2σ).
pub fn init_tensor(init: &str, numel: usize, rng: &mut Rng) -> Vec<f32> {
    match init {
        "zeros" => vec![0.0; numel],
        "ones" => vec![1.0; numel],
        _ => (0..numel).map(|_| rng.trunc_normal_f32(0.02)).collect(),
    }
}

/// LoRA factor shapes for a config: (B: d1 x r, A: r x d2) per target.
pub fn lora_shapes(cfg: &ModelConfig) -> Vec<(String, Vec<usize>, Vec<usize>)> {
    cfg.lora_targets
        .iter()
        .map(|name| {
            let p = cfg.param(name).expect("lora target in params");
            let (d1, d2) = (p.shape[0], p.shape[1]);
            (name.clone(), vec![d1, cfg.lora_rank], vec![cfg.lora_rank, d2])
        })
        .collect()
}
