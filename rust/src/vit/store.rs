//! Named parameter storage + flat (spec-order) I/O + binary checkpoints.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{next_generation, HostTensor, ModelConfig};
use crate::util::rng::Rng;

const MAGIC: &[u8; 4] = b"TEPT"; // TaskEdge ParamTensors

/// A named collection of host tensors following a manifest param layout.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub config_name: String,
    tensors: BTreeMap<String, HostTensor>,
    /// spec order, for flat artifact I/O
    order: Vec<String>,
    /// content-state identity: unique per distinct tensor contents. A clone
    /// shares its source's generation (identical contents); any mutation
    /// moves the store to a fresh, globally-unique generation. Consumers
    /// (e.g. the runtime's prepared-literal cache) may treat two stores
    /// with equal generations as bit-identical.
    generation: u64,
}

impl ParamStore {
    /// Random init per the manifest's init kinds (fresh backbone).
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> ParamStore {
        let mut tensors = BTreeMap::new();
        let mut order = Vec::new();
        for p in &cfg.params {
            let data = super::init_tensor(&p.init, p.numel(), rng);
            tensors.insert(
                p.name.clone(),
                HostTensor::from_f32(&p.shape, data).unwrap(),
            );
            order.push(p.name.clone());
        }
        ParamStore {
            config_name: cfg.name.clone(),
            tensors,
            order,
            generation: next_generation(),
        }
    }

    /// All-zeros with the same layout (optimizer moment buffers).
    pub fn zeros_like(cfg: &ModelConfig) -> ParamStore {
        let mut tensors = BTreeMap::new();
        let mut order = Vec::new();
        for p in &cfg.params {
            tensors.insert(p.name.clone(), HostTensor::zeros(&p.shape));
            order.push(p.name.clone());
        }
        ParamStore {
            config_name: cfg.name.clone(),
            tensors,
            order,
            generation: next_generation(),
        }
    }

    pub fn order(&self) -> &[String] {
        &self.order
    }

    /// The store's content-state generation: unique across the process per
    /// distinct tensor contents (clones share it; mutations refresh it).
    /// Downstream caches key converted parameter literals on this value.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("param {name:?} not in store"))
    }

    /// Replace a tensor, moving `t` into the existing slot. This is the
    /// training write-back path (every updated tensor every step), so it
    /// must not re-allocate the key the way `insert(name.to_string(), ..)`
    /// would.
    pub fn set(&mut self, name: &str, t: HostTensor) -> Result<()> {
        let slot = self
            .tensors
            .get_mut(name)
            .with_context(|| format!("param {name:?} not in store"))?;
        if slot.shape != t.shape {
            bail!("set {name:?}: shape {:?} != {:?}", t.shape, slot.shape);
        }
        *slot = t;
        // contents changed: clones of the old state must no longer share a
        // generation with this store (every mutation path — here, set_flat,
        // and anything added later — must bump the generation itself)
        self.generation = next_generation();
        Ok(())
    }

    /// Flat tensors in spec order (the artifact calling convention).
    pub fn flat(&self) -> Vec<HostTensor> {
        self.order.iter().map(|n| self.tensors[n].clone()).collect()
    }

    /// Replace all tensors from a flat spec-order slice.
    pub fn set_flat(&mut self, tensors: &[HostTensor]) -> Result<()> {
        if tensors.len() != self.order.len() {
            bail!("set_flat: {} tensors != {}", tensors.len(), self.order.len());
        }
        // validate every shape BEFORE writing anything: a mid-loop bail
        // after partial writes would leave mutated contents under the old
        // generation id — stale prepared-literal cache hits
        for (name, t) in self.order.iter().zip(tensors) {
            let cur = self
                .tensors
                .get(name)
                .with_context(|| format!("param {name:?} not in store"))?;
            if cur.shape != t.shape {
                bail!("set_flat {name:?}: shape {:?} != {:?}", t.shape, cur.shape);
            }
        }
        for (name, t) in self.order.iter().zip(tensors) {
            *self.tensors.get_mut(name).unwrap() = t.clone();
        }
        // one bump covers the whole replacement (every path through here
        // is a content mutation)
        self.generation = next_generation();
        Ok(())
    }

    /// Re-initialize the classification head (fresh per downstream task).
    pub fn reinit_head(&mut self, rng: &mut Rng) -> Result<()> {
        let hw = self.get("head.w")?.clone();
        let n = hw.numel();
        self.set(
            "head.w",
            HostTensor::from_f32(&hw.shape, super::init_tensor("trunc_normal", n, rng))?,
        )?;
        let hb = self.get("head.b")?.clone();
        self.set("head.b", HostTensor::zeros(&hb.shape))?;
        Ok(())
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }

    // -- checkpoints --------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {path:?}"))?,
        );
        self.write_to(&mut f)
    }

    /// Serialize the checkpoint into memory — the one-time backbone
    /// streaming payload. Bytes are identical to what [`ParamStore::save`]
    /// puts on disk, so the digest a participant computes over the wire
    /// payload matches the digest of the coordinator's checkpoint file.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.write_to(&mut buf)?;
        Ok(buf)
    }

    /// Serialize into any writer — exactly the bytes `save` puts on disk.
    pub fn write_to<W: Write>(&self, f: &mut W) -> Result<()> {
        f.write_all(MAGIC)?;
        f.write_all(&(self.order.len() as u32).to_le_bytes())?;
        for name in &self.order {
            let t = &self.tensors[name];
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape.len() as u8).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in t.f32s()? {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path, cfg: &ModelConfig) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening checkpoint {path:?}"))?,
        );
        Self::read_from(&mut f, cfg)
            .with_context(|| format!("loading checkpoint {path:?}"))
    }

    /// Parse a checkpoint from in-memory bytes — the backbone-streaming
    /// receive path. Validation is identical to [`ParamStore::load`]: every
    /// tensor must name and shape-match a slot in `cfg`, so a hostile
    /// payload can at worst fail cleanly.
    pub fn from_bytes(bytes: &[u8], cfg: &ModelConfig) -> Result<ParamStore> {
        let mut r = bytes;
        Self::read_from(&mut r, cfg)
    }

    /// Shared reader behind `load`/`from_bytes`. Allocation per tensor is
    /// bounded by the manifest's declared shape (via `set`'s shape guard),
    /// not by the payload's claims.
    pub fn read_from<R: Read>(f: &mut R, cfg: &ModelConfig) -> Result<ParamStore> {
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a TaskEdge checkpoint (bad magic)");
        }
        let mut cnt = [0u8; 4];
        f.read_exact(&mut cnt)?;
        let count = u32::from_le_bytes(cnt) as usize;
        let mut store = ParamStore::zeros_like(cfg);
        for _ in 0..count {
            let mut nlen = [0u8; 2];
            f.read_exact(&mut nlen)?;
            let mut name = vec![0u8; u16::from_le_bytes(nlen) as usize];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("bad tensor name")?;
            let mut rank = [0u8; 1];
            f.read_exact(&mut rank)?;
            let mut shape = Vec::with_capacity(rank[0] as usize);
            for _ in 0..rank[0] {
                let mut d = [0u8; 8];
                f.read_exact(&mut d)?;
                shape.push(u64::from_le_bytes(d) as usize);
            }
            // validate the claimed shape against the manifest slot BEFORE
            // allocating: the payload is untrusted on the wire path, and a
            // bogus shape must fail cleanly instead of driving a huge
            // allocation
            let slot = store
                .get(&name)
                .with_context(|| format!("checkpoint names unknown tensor {name:?}"))?;
            if slot.shape != shape {
                bail!(
                    "checkpoint tensor {name:?} shape {shape:?} != manifest {:?}",
                    slot.shape
                );
            }
            let numel: usize = shape.iter().product();
            let mut bytes = vec![0u8; numel * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            store.set(&name, HostTensor::from_f32(&shape, data)?)?;
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn mini_cfg() -> ModelConfig {
        let m = Manifest::parse(
            r#"{"version":1,"batch":2,"configs":{"t":{
            "image_size":8,"patch_size":4,"dim":8,"depth":1,"heads":2,
            "mlp_ratio":2,"num_classes":4,"channels":3,"prompt_len":2,
            "adapter_dim":2,"lora_rank":2,"num_params":72,
            "params":[
              {"name":"head.w","shape":[8,4],"init":"trunc_normal","masked":true,"stat":"head.in"},
              {"name":"head.b","shape":[4],"init":"zeros","masked":false,"stat":null},
              {"name":"ln.scale","shape":[8],"init":"ones","masked":false,"stat":null}],
            "lora_targets":["head.w"],"adapters":[]}},"artifacts":[]}"#,
        )
        .unwrap();
        m.config("t").unwrap().clone()
    }

    #[test]
    fn init_kinds() {
        let cfg = mini_cfg();
        let mut rng = Rng::new(0);
        let s = ParamStore::init(&cfg, &mut rng);
        assert_eq!(s.get("head.b").unwrap().f32s().unwrap(), &[0.0; 4]);
        assert_eq!(s.get("ln.scale").unwrap().f32s().unwrap(), &[1.0; 8]);
        let w = s.get("head.w").unwrap().f32s().unwrap();
        assert!(w.iter().any(|&v| v != 0.0));
        assert!(w.iter().all(|&v| v.abs() <= 0.04 + 1e-6));
        assert_eq!(s.total_params(), 44);
    }

    #[test]
    fn flat_roundtrip() {
        let cfg = mini_cfg();
        let mut rng = Rng::new(1);
        let s = ParamStore::init(&cfg, &mut rng);
        let flat = s.flat();
        assert_eq!(flat.len(), 3);
        let mut s2 = ParamStore::zeros_like(&cfg);
        s2.set_flat(&flat).unwrap();
        assert_eq!(s2.get("head.w").unwrap(), s.get("head.w").unwrap());
    }

    #[test]
    fn set_shape_guard() {
        let cfg = mini_cfg();
        let mut s = ParamStore::zeros_like(&cfg);
        assert!(s.set("head.b", HostTensor::zeros(&[5])).is_err());
        assert!(s.set("nope", HostTensor::zeros(&[4])).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = mini_cfg();
        let mut rng = Rng::new(2);
        let s = ParamStore::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("taskedge_test_ckpt.bin");
        s.save(&dir).unwrap();
        let s2 = ParamStore::load(&dir, &cfg).unwrap();
        assert_eq!(s.get("head.w").unwrap(), s2.get("head.w").unwrap());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn generation_tracks_content_state() {
        let cfg = mini_cfg();
        let mut rng = Rng::new(9);
        let a = ParamStore::init(&cfg, &mut rng);
        let b = ParamStore::init(&cfg, &mut rng);
        // distinct stores never share a generation
        assert_ne!(a.generation(), b.generation());
        // a clone is bit-identical and keeps the generation...
        let mut c = a.clone();
        assert_eq!(c.generation(), a.generation());
        // ...until any mutation moves it to a fresh one
        let g0 = c.generation();
        c.set("head.b", HostTensor::zeros(&[4])).unwrap();
        assert_ne!(c.generation(), g0);
        assert_eq!(a.generation(), g0, "source store keeps its generation");
        // a failed set must not churn the generation
        let g1 = c.generation();
        assert!(c.set("head.b", HostTensor::zeros(&[5])).is_err());
        assert_eq!(c.generation(), g1);
        // reinit_head and set_flat are mutations too
        c.reinit_head(&mut rng).unwrap();
        assert_ne!(c.generation(), g1);
        let g2 = c.generation();
        let flat = a.flat();
        c.set_flat(&flat).unwrap();
        assert_ne!(c.generation(), g2);
    }

    #[test]
    fn reinit_head_changes_weights() {
        let cfg = mini_cfg();
        let mut rng = Rng::new(3);
        let mut s = ParamStore::init(&cfg, &mut rng);
        let before = s.get("head.w").unwrap().clone();
        s.reinit_head(&mut rng).unwrap();
        assert_ne!(&before, s.get("head.w").unwrap());
    }
}
