//! Host tensor type bridging Rust data and PJRT literals.
//!
//! Row-major, f32 or i32 (all artifact I/O uses exactly these two dtypes;
//! the manifest is the source of truth).

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-resident tensor. `shape == []` means rank-0 (scalar).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor {
            shape: shape.to_vec(),
            data: TensorData::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn ones(shape: &[usize]) -> HostTensor {
        HostTensor {
            shape: shape.to_vec(),
            data: TensorData::F32(vec![1.0; shape.iter().product()]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("item_f32 on tensor with {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Convert to a PJRT literal (zero reinterpretation: raw bytes copied).
    pub fn to_literal(&self) -> Result<Literal> {
        let (ty, bytes): (ElementType, &[u8]) = match &self.data {
            TensorData::F32(v) => (ElementType::F32, bytemuck_f32(v)),
            TensorData::I32(v) => (ElementType::S32, bytemuck_i32(v)),
        };
        Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)
            .context("literal creation failed")
    }

    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match lit.ty().context("literal dtype")? {
            xla::ElementType::F32 => {
                let v: Vec<f32> = lit.to_vec()?;
                HostTensor::from_f32(&dims, v)
            }
            xla::ElementType::S32 => {
                let v: Vec<i32> = lit.to_vec()?;
                HostTensor::from_i32(&dims, v)
            }
            other => bail!("unsupported literal dtype {other:?}"),
        }
    }
}

/// A host tensor converted to a PJRT literal **once**, for repeated
/// execution. The conversion (alloc + byte copy, proportional to tensor
/// size) is the dominant per-call cost when the same large tensors — the
/// frozen backbone parameters — are bound to every execution; preparing
/// them up front makes the per-call cost proportional to the inputs that
/// actually change.
pub struct PreparedLiteral {
    lit: Literal,
    bytes: usize,
}

// SAFETY: a Literal is an immutable host-side value after creation — the
// runtime only ever reads it (execute copies it to device buffers). The
// Rust wrapper lacks the auto-traits solely because of its raw pointer
// field; sharing read-only access across worker threads is sound (same
// reasoning as the runtime's shared executable cache).
unsafe impl Send for PreparedLiteral {}
unsafe impl Sync for PreparedLiteral {}

impl PreparedLiteral {
    pub fn new(t: &HostTensor) -> Result<PreparedLiteral> {
        Ok(PreparedLiteral { lit: t.to_literal()?, bytes: t.size_bytes() })
    }

    pub fn literal(&self) -> &Literal {
        &self.lit
    }

    /// Host bytes this literal froze — the per-call conversion cost it
    /// saves every time it is reused.
    pub fn size_bytes(&self) -> usize {
        self.bytes
    }
}

impl std::fmt::Debug for PreparedLiteral {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedLiteral").field("bytes", &self.bytes).finish()
    }
}

/// A host tensor uploaded to **device memory once**, for repeated
/// execution. Where [`PreparedLiteral`] saves the per-call host-side
/// conversion, a `DeviceBuffer` also saves the host→device copy PJRT
/// performs for every literal argument: binding a resident buffer to an
/// execution moves zero bytes across the bus. This is the unit of the
/// runtime's resident-parameter cache.
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    bytes: usize,
}

// SAFETY: a PjRtBuffer is an immutable device allocation after the upload
// completes — the runtime only ever binds it read-only to executions, and
// the CPU PJRT client synchronizes internally (same reasoning as the
// shared executable cache). The Rust wrapper lacks the auto-traits solely
// because of its raw pointer field.
unsafe impl Send for DeviceBuffer {}
unsafe impl Sync for DeviceBuffer {}

impl DeviceBuffer {
    /// Upload a prepared literal to the client's default device. `bytes`
    /// is the payload size this buffer keeps off the bus on every
    /// subsequent bind.
    pub fn upload(
        client: &xla::PjRtClient,
        lit: &Literal,
        bytes: usize,
    ) -> Result<DeviceBuffer> {
        let buf = client
            .buffer_from_host_literal(None, lit)
            .context("host->device upload")?;
        Ok(DeviceBuffer { buf, bytes })
    }

    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }

    /// Device bytes this buffer occupies — the h2d traffic each resident
    /// bind avoids.
    pub fn size_bytes(&self) -> usize {
        self.bytes
    }
}

impl std::fmt::Debug for DeviceBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceBuffer").field("bytes", &self.bytes).finish()
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    // SAFETY: i32 has no padding and alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostTensor::from_f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::from_f32(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.item_f32().unwrap(), 2.5);
        assert!(t.shape.is_empty());
    }

    #[test]
    fn dtype_access_guards() {
        let t = HostTensor::from_i32(&[2], vec![1, 2]).unwrap();
        assert!(t.f32s().is_err());
        assert_eq!(t.i32s().unwrap(), &[1, 2]);
    }
}
