//! `artifacts/manifest.json` — the contract between the AOT compile path
//! (python/compile/aot.py) and this runtime. The manifest enumerates model
//! configs (parameter layouts) and artifacts (flat input/output signatures);
//! the runtime never assumes a layout beyond what is recorded here.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use super::tensor::Dtype;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub masked: bool,
    pub stat: Option<String>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub image_size: usize,
    pub patch_size: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
    pub num_classes: usize,
    pub channels: usize,
    pub prompt_len: usize,
    pub adapter_dim: usize,
    pub lora_rank: usize,
    pub num_params: usize,
    pub params: Vec<ParamSpec>,
    pub lora_targets: Vec<String>,
    pub adapters: Vec<(String, Vec<usize>)>,
}

impl ModelConfig {
    pub fn param(&self, name: &str) -> Result<&ParamSpec> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("unknown param {name:?}"))
    }

    pub fn masked_params(&self) -> impl Iterator<Item = &ParamSpec> {
        self.params.iter().filter(|p| p.masked)
    }

    pub fn masked_param_count(&self) -> usize {
        self.masked_params().map(|p| p.numel()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub config: String,
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no input {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no output {name:?}", self.name))
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub configs: BTreeMap<String, ModelConfig>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()
        .context("expected array of io specs")?
        .iter()
        .map(|s| {
            Ok(IoSpec {
                name: s.req("name")?.as_str().context("name")?.to_string(),
                shape: s.req("shape")?.as_usize_vec().context("shape")?,
                dtype: Dtype::parse(s.req("dtype")?.as_str().context("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json parse error")?;
        let version = j.req("version")?.as_usize().context("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut configs = BTreeMap::new();
        for (name, cj) in j.req("configs")?.as_obj().context("configs")? {
            let us = |k: &str| -> Result<usize> {
                cj.req(k)?.as_usize().with_context(|| k.to_string())
            };
            let params = cj
                .req("params")?
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str().context("name")?.to_string(),
                        shape: p.req("shape")?.as_usize_vec().context("shape")?,
                        init: p.req("init")?.as_str().context("init")?.to_string(),
                        masked: p.req("masked")?.as_bool().context("masked")?,
                        stat: p.get("stat").and_then(|s| s.as_str()).map(String::from),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let lora_targets = cj
                .req("lora_targets")?
                .as_arr()
                .context("lora_targets")?
                .iter()
                .map(|s| {
                    s.as_str().map(String::from).with_context(|| {
                        format!(
                            "config {name:?}: lora_targets entries must be strings, got {s}"
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let adapters = cj
                .req("adapters")?
                .as_arr()
                .context("adapters")?
                .iter()
                .map(|a| {
                    Ok((
                        a.req("name")?.as_str().context("name")?.to_string(),
                        a.req("shape")?.as_usize_vec().context("shape")?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            configs.insert(
                name.clone(),
                ModelConfig {
                    name: name.clone(),
                    image_size: us("image_size")?,
                    patch_size: us("patch_size")?,
                    dim: us("dim")?,
                    depth: us("depth")?,
                    heads: us("heads")?,
                    mlp_ratio: us("mlp_ratio")?,
                    num_classes: us("num_classes")?,
                    channels: us("channels")?,
                    prompt_len: us("prompt_len")?,
                    adapter_dim: us("adapter_dim")?,
                    lora_rank: us("lora_rank")?,
                    num_params: us("num_params")?,
                    params,
                    lora_targets,
                    adapters,
                },
            );
        }

        // Top-level `batch` is the single authority for batch size (it is
        // what `artifact_for` keys canonical names on and what serve/session
        // read at runtime). A per-artifact `batch` that disagrees would be
        // silently ignored everywhere, so reject the skew at parse time.
        let batch = j.req("batch")?.as_usize().context("batch")?;

        let mut artifacts = BTreeMap::new();
        for aj in j.req("artifacts")?.as_arr().context("artifacts")? {
            let name = aj.req("name")?.as_str().context("name")?.to_string();
            let spec = ArtifactSpec {
                name: name.clone(),
                kind: aj.req("kind")?.as_str().context("kind")?.to_string(),
                config: aj.req("config")?.as_str().context("config")?.to_string(),
                batch: aj.req("batch")?.as_usize().context("batch")?,
                file: aj.req("file")?.as_str().context("file")?.to_string(),
                inputs: io_specs(aj.req("inputs")?)?,
                outputs: io_specs(aj.req("outputs")?)?,
            };
            if spec.batch != batch {
                bail!(
                    "artifact {name:?}: batch {} disagrees with manifest batch {batch} \
                     (top-level batch is authoritative)",
                    spec.batch
                );
            }
            if artifacts.insert(name.clone(), spec).is_some() {
                // artifacts arrive as a JSON *array*, so duplicates survive
                // the parser and would silently last-writer-win here
                bail!("duplicate artifact name {name:?}");
            }
        }

        Ok(Manifest { batch, configs, artifacts })
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .with_context(|| format!("config {name:?} not in manifest (have: {:?})",
                                     self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Canonical artifact naming: `{kind}_{config}_b{batch}`.
    pub fn artifact_for(&self, kind: &str, config: &str) -> Result<&ArtifactSpec> {
        let name = format!("{kind}_{config}_b{}", self.batch);
        self.artifact(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1, "batch": 4,
      "configs": {"t": {"image_size": 8, "patch_size": 4, "dim": 8,
        "depth": 1, "heads": 2, "mlp_ratio": 2, "num_classes": 4,
        "channels": 3, "prompt_len": 2, "adapter_dim": 2, "lora_rank": 2,
        "num_params": 100,
        "params": [{"name": "w", "shape": [4, 8], "init": "trunc_normal",
                    "masked": true, "stat": "w.in"},
                   {"name": "b", "shape": [8], "init": "zeros",
                    "masked": false, "stat": null}],
        "lora_targets": ["w"],
        "adapters": [{"name": "a.w", "shape": [8, 2]}]}},
      "artifacts": [{"name": "fwd_t_b4", "kind": "fwd", "config": "t",
        "batch": 4, "file": "fwd_t_b4.hlo.txt",
        "inputs": [{"name": "param:w", "shape": [4, 8], "dtype": "f32"},
                   {"name": "labels", "shape": [4], "dtype": "i32"}],
        "outputs": [{"name": "logits", "shape": [4, 4], "dtype": "f32"}]}]
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.batch, 4);
        let c = m.config("t").unwrap();
        assert_eq!(c.params.len(), 2);
        assert!(c.params[0].masked);
        assert_eq!(c.params[0].stat.as_deref(), Some("w.in"));
        assert_eq!(c.params[1].stat, None);
        assert_eq!(c.masked_param_count(), 32);
        let a = m.artifact_for("fwd", "t").unwrap();
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.input_index("labels").unwrap(), 1);
        assert!(a.input_index("nope").is_err());
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse("{\"version\": 1}").is_err());
        assert!(Manifest::parse("{\"version\": 2, \"batch\": 1, \"configs\": {}, \"artifacts\": []}").is_err());
    }

    #[test]
    fn duplicate_artifact_name_errors() {
        let dup = MINI.replace(
            "\"artifacts\": [{",
            "\"artifacts\": [{\"name\": \"fwd_t_b4\", \"kind\": \"fwd\", \
             \"config\": \"t\", \"batch\": 4, \"file\": \"x.hlo.txt\", \
             \"inputs\": [], \"outputs\": []}, {",
        );
        let err = Manifest::parse(&dup).unwrap_err();
        assert!(
            format!("{err:#}").contains("duplicate artifact name"),
            "{err:#}"
        );
    }

    #[test]
    fn duplicate_config_name_errors() {
        // duplicate config names are duplicate JSON object keys — rejected
        // by the json parser itself, surfaced through Manifest::parse
        let dup = MINI.replace("\"configs\": {\"t\":", "\"configs\": {\"t\": {}, \"t\":");
        let err = Manifest::parse(&dup).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate key"), "{err:#}");
    }

    #[test]
    fn non_string_lora_target_errors() {
        let bad = MINI.replace("\"lora_targets\": [\"w\"]", "\"lora_targets\": [\"w\", 3]");
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(
            format!("{err:#}").contains("lora_targets entries must be strings"),
            "{err:#}"
        );
    }

    #[test]
    fn artifact_batch_skew_errors() {
        // the artifact claims b8 while the manifest batch is 4
        let bad = MINI.replace("\"batch\": 4, \"file\"", "\"batch\": 8, \"file\"");
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(
            format!("{err:#}").contains("disagrees with manifest batch"),
            "{err:#}"
        );
    }

    #[test]
    fn bad_dtype_errors() {
        let bad = MINI.replace("\"dtype\": \"i32\"", "\"dtype\": \"f64\"");
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported dtype"), "{err:#}");
    }
}
