//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by the python
//! compile path) and executes them on the CPU PJRT client from the L3 hot
//! path. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> HloModuleProto
//! (text parser reassigns 64-bit ids) -> XlaComputation -> compile -> cached
//! PjRtLoadedExecutable -> execute with Literals built from [`HostTensor`]s.
//!
//! Hot-path structure (the serving tier executes thousands of batches per
//! second against the same parameter set):
//!
//! - the executable cache is an `RwLock` — concurrent workers resolve a
//!   compiled artifact with one uncontended read lock, no serialization;
//! - [`RuntimeStats`] counters are atomics, so stats updates in
//!   `execute`/`execute_bound`/`execute_prepared` never take a lock;
//! - [`Runtime::prepare`] converts an artifact's *persistent* inputs (the
//!   `param:*` tensors of a parameter-set generation) to `xla::Literal`s
//!   once, and [`Runtime::execute_prepared`] then converts only the
//!   per-call dynamic inputs (the padded image batch). Prepared sets are
//!   memoized by `(artifact, generation)` so N tasks serving the same
//!   frozen backbone share one conversion.
//! - A prepared set's frozen inputs are additionally uploaded to **device
//!   memory once** ([`tensor::DeviceBuffer`]) and every subsequent
//!   `execute_prepared` binds the resident buffers directly — per-step
//!   h2d traffic is the dynamic inputs (batch-sized), not the model.
//!   `TASKEDGE_RESIDENT=0` disables residency and falls back to the
//!   bit-identical literal path; `TASKEDGE_RESIDENT_BUDGET_MB` bounds
//!   device bytes with LRU eviction (evicted sets degrade to re-upload).
//! - [`Runtime::donate_writeback`] refreshes a prepared set's frozen
//!   slots in place from training write-backs — new literals + resident
//!   buffers installed, then the set's generation is bumped (the
//!   write-back fence), so stale-generation lookups can never observe the
//!   donated contents.

pub mod manifest;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, ParamSpec};
pub use tensor::{DeviceBuffer, Dtype, HostTensor, PreparedLiteral, TensorData};

/// Bound on memo slots for prepared parameter sets. Entries are `Weak`,
/// so the memo never pins a retired generation's literals in memory (a
/// full backbone-sized copy each) — it only deduplicates sets some
/// caller still holds alive, e.g. several tasks serving one backbone.
const PREPARED_CACHE_CAP: usize = 32;

/// Process-wide source of content-state generation ids. `ParamStore`
/// draws its per-mutation generations here, and sessions draw ids for
/// *composed* frozen input sets (backbone params + allocation masks) that
/// no single store describes. A single counter means a prepared set keyed
/// on any of these ids can never alias a set built from a different
/// source.
static GENERATION: AtomicU64 = AtomicU64::new(1);

/// Mint a globally unique content-state id (never reused). Key prepared
/// input sets on this when the frozen tensors are constant for the key's
/// lifetime — e.g. one id per fine-tuning session for the (backbone,
/// masks) composition that holds still across every train step.
pub fn next_generation() -> u64 {
    GENERATION.fetch_add(1, Ordering::Relaxed)
}

/// PJRT executables hold raw pointers; the underlying CPU client is
/// thread-safe, so we mark the cache entry Send+Sync to let the fleet
/// simulator share compiled executables across worker threads.
struct SharedExe(xla::PjRtLoadedExecutable);
// SAFETY: xla_extension's PjRtLoadedExecutable::Execute and the CPU client
// are thread-safe (internal synchronization); the Rust wrapper only lacks
// the auto-traits because of the raw pointer field.
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

/// Cumulative runtime counters (observability for the perf pass). This is
/// the snapshot type returned by [`Runtime::stats`]; internally the
/// counters are lock-free atomics so concurrent executor workers never
/// serialize on a stats mutex.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_ns: u128,
    pub executions: usize,
    pub execute_ns: u128,
    /// input bytes *bound* to executions (resident or not) — the legacy
    /// total; see `h2d_upload_bytes`/`h2d_resident_bytes` for the split
    /// into real bus traffic vs device-resident reuse
    pub h2d_bytes: usize,
    pub d2h_bytes: usize,
    /// bytes actually copied host->device: per-call dynamic inputs,
    /// literal-path frozen re-uploads, resident-set uploads and donation
    /// refreshes — the number that should track the bus
    pub h2d_upload_bytes: usize,
    /// frozen bytes bound from already-resident device buffers — traffic
    /// the resident cache kept off the bus
    pub h2d_resident_bytes: usize,
    /// prepared parameter-set builds ([`Runtime::prepare`] cache misses):
    /// happens at server start and per parameter swap, never per batch
    pub param_prepares: usize,
    /// host bytes converted to literals during those builds
    pub param_prepare_bytes: usize,
    /// [`Runtime::prepare`] calls answered from the generation-keyed cache
    /// (e.g. several tasks sharing one frozen backbone generation)
    pub param_cache_hits: usize,
    /// parameter bytes bound from the prepared cache (resident buffers or
    /// cached literals) across all [`Runtime::execute_prepared`] calls —
    /// per-call conversion work the cache saved the hot path
    pub param_reuse_bytes: usize,
    /// device bytes currently held by resident frozen-input sets (gauge)
    pub resident_bytes: usize,
    /// resident-set uploads (first residency + post-eviction re-uploads)
    pub resident_prepares: usize,
    /// resident sets stripped to stay under the byte budget
    pub resident_evictions: usize,
    /// [`Runtime::donate_writeback`] calls (in-place frozen-slot refreshes)
    pub donations: usize,
    /// bytes re-uploaded by donations — the training write-back traffic
    pub donated_refresh_bytes: usize,
}

/// Lock-free counter twin of [`RuntimeStats`]. Relaxed ordering is enough:
/// the counters are independent monotonic tallies, not synchronization.
#[derive(Default)]
struct StatCounters {
    compiles: AtomicUsize,
    compile_ns: AtomicU64,
    executions: AtomicUsize,
    execute_ns: AtomicU64,
    h2d_bytes: AtomicUsize,
    d2h_bytes: AtomicUsize,
    h2d_upload_bytes: AtomicUsize,
    h2d_resident_bytes: AtomicUsize,
    param_prepares: AtomicUsize,
    param_prepare_bytes: AtomicUsize,
    param_cache_hits: AtomicUsize,
    param_reuse_bytes: AtomicUsize,
    resident_prepares: AtomicUsize,
    resident_evictions: AtomicUsize,
    donations: AtomicUsize,
    donated_refresh_bytes: AtomicUsize,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RwLock<HashMap<String, Arc<SharedExe>>>,
    /// serializes XLA compilation so concurrent fleet workers requesting
    /// the same artifact produce exactly one executable (double-checked
    /// against `cache` under this lock)
    compile_lock: Mutex<()>,
    /// live prepared parameter sets, most-recently-inserted last; weak so
    /// a swapped-out generation's literals free as soon as its last user
    /// drops them (see `PREPARED_CACHE_CAP`)
    prepared: Mutex<Vec<Weak<PreparedParams>>>,
    /// serializes parameter-literal conversion so concurrent builders of
    /// the same generation produce exactly one prepared set (same
    /// double-check pattern as `compile_lock`)
    prepare_lock: Mutex<()>,
    /// resident-buffer registry: every prepared set whose frozen inputs
    /// may be device-resident, for budget accounting and LRU eviction.
    /// Entries are weak — a dropped set frees its device memory with it.
    resident: Mutex<Vec<Weak<PreparedParams>>>,
    /// `TASKEDGE_RESIDENT` gate: when false every execute falls back to
    /// the literal path (bit-identical measured baseline)
    resident_on: AtomicBool,
    /// resident-bytes budget (`TASKEDGE_RESIDENT_BUDGET_MB`);
    /// `usize::MAX` = unbounded. Exceeding it evicts LRU sets — degrade
    /// to re-upload, never device OOM.
    resident_budget: AtomicUsize,
    /// monotonic LRU clock for resident-set eviction
    resident_tick: AtomicU64,
    stats: StatCounters,
}

// SAFETY: see SharedExe — the CPU PJRT client is internally synchronized.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let resident_on = std::env::var("TASKEDGE_RESIDENT")
            .map(|v| v != "0")
            .unwrap_or(true);
        let resident_budget = std::env::var("TASKEDGE_RESIDENT_BUDGET_MB")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|mb| mb.saturating_mul(1024 * 1024))
            .unwrap_or(usize::MAX);
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RwLock::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            prepared: Mutex::new(Vec::new()),
            prepare_lock: Mutex::new(()),
            resident: Mutex::new(Vec::new()),
            resident_on: AtomicBool::new(resident_on),
            resident_budget: AtomicUsize::new(resident_budget),
            resident_tick: AtomicU64::new(1),
            stats: StatCounters::default(),
        })
    }

    /// Whether frozen inputs are kept device-resident (`TASKEDGE_RESIDENT`
    /// at load time; overridable for A/B runs and tests).
    pub fn resident_enabled(&self) -> bool {
        self.resident_on.load(Ordering::Relaxed)
    }

    /// Toggle residency at runtime. Turning it off makes every
    /// `execute_prepared` take the literal path (existing resident sets
    /// are kept and resume service when re-enabled).
    pub fn set_resident(&self, on: bool) {
        self.resident_on.store(on, Ordering::Relaxed);
    }

    /// Current resident-bytes budget in bytes (`usize::MAX` = unbounded).
    pub fn resident_budget_bytes(&self) -> usize {
        self.resident_budget.load(Ordering::Relaxed)
    }

    /// Set the resident-bytes budget. Takes effect on the next resident
    /// upload (which evicts LRU sets down to the new bound).
    pub fn set_resident_budget_bytes(&self, bytes: usize) {
        self.resident_budget.store(bytes, Ordering::Relaxed);
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Borrow an artifact's signature directly off the runtime — callers on
    /// hot paths resolve the spec once (or per call, by reference) instead
    /// of cloning `ArtifactSpec` out of the manifest.
    pub fn artifact_spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            compiles: self.stats.compiles.load(Ordering::Relaxed),
            compile_ns: self.stats.compile_ns.load(Ordering::Relaxed) as u128,
            executions: self.stats.executions.load(Ordering::Relaxed),
            execute_ns: self.stats.execute_ns.load(Ordering::Relaxed) as u128,
            h2d_bytes: self.stats.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.stats.d2h_bytes.load(Ordering::Relaxed),
            h2d_upload_bytes: self.stats.h2d_upload_bytes.load(Ordering::Relaxed),
            h2d_resident_bytes: self
                .stats
                .h2d_resident_bytes
                .load(Ordering::Relaxed),
            param_prepares: self.stats.param_prepares.load(Ordering::Relaxed),
            param_prepare_bytes: self
                .stats
                .param_prepare_bytes
                .load(Ordering::Relaxed),
            param_cache_hits: self.stats.param_cache_hits.load(Ordering::Relaxed),
            param_reuse_bytes: self.stats.param_reuse_bytes.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes_now(),
            resident_prepares: self.stats.resident_prepares.load(Ordering::Relaxed),
            resident_evictions: self
                .stats
                .resident_evictions
                .load(Ordering::Relaxed),
            donations: self.stats.donations.load(Ordering::Relaxed),
            donated_refresh_bytes: self
                .stats
                .donated_refresh_bytes
                .load(Ordering::Relaxed),
        }
    }

    /// Device bytes currently held by live resident sets (gauge, computed
    /// from the registry so drops are reflected without a hook).
    fn resident_bytes_now(&self) -> usize {
        let mut reg = self.resident.lock().unwrap();
        reg.retain(|w| w.strong_count() > 0);
        reg.iter()
            .filter_map(|w| w.upgrade())
            .map(|p| p.resident_bytes())
            .sum()
    }

    fn record_execute(
        &self,
        exec_ns: u64,
        bound_bytes: usize,
        upload_bytes: usize,
        resident_bytes: usize,
        out_bytes: usize,
    ) {
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats.execute_ns.fetch_add(exec_ns, Ordering::Relaxed);
        self.stats.h2d_bytes.fetch_add(bound_bytes, Ordering::Relaxed);
        self.stats
            .h2d_upload_bytes
            .fetch_add(upload_bytes, Ordering::Relaxed);
        self.stats
            .h2d_resident_bytes
            .fetch_add(resident_bytes, Ordering::Relaxed);
        self.stats.d2h_bytes.fetch_add(out_bytes, Ordering::Relaxed);
    }

    /// Compile (or fetch the cached) executable for a manifest artifact.
    /// The hit path is a single uncontended read lock and an `Arc` clone —
    /// no allocation, no writer exclusion between concurrent readers.
    fn executable(&self, name: &str) -> Result<Arc<SharedExe>> {
        if let Some(exe) = self.cache.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        // one compiler at a time; re-check the cache once we hold the lock
        let _guard = self.compile_lock.lock().unwrap();
        if let Some(exe) = self.cache.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exe = Arc::new(SharedExe(exe));
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        self.stats
            .compile_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        crate::debug!("compiled {name} in {:?}", t0.elapsed());
        self.cache
            .write()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (e.g. at session start).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate `inputs` against the artifact signature (shape + dtype).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                bail!(
                    "artifact {} input #{i} ({}): shape {:?} != manifest {:?}",
                    spec.name, s.name, t.shape, s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): dtype {:?} != manifest {:?}",
                    spec.name, s.name, t.dtype(), s.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact with host tensors; returns outputs in manifest
    /// order. The AOT path lowers with `return_tuple=True`, so the single
    /// result buffer is a tuple literal that we decompose.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        self.validate(spec, inputs)?;
        let exe = self.executable(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;

        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&spec.outputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    name, s.name, t.shape, s.shape
                );
            }
        }

        let in_bytes = inputs.iter().map(|t| t.size_bytes()).sum::<usize>();
        self.record_execute(
            exec_ns,
            in_bytes,
            in_bytes,
            0,
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        Ok(tensors)
    }

    /// Like [`Runtime::execute`] but with borrowed-or-owned inputs, so hot
    /// loops can bind persistent state (params, moments, masks) without
    /// cloning host tensors every step (EXPERIMENTS.md §Perf).
    pub fn execute_bound(&self, name: &str, inputs: &[Bind<'_>]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let t = t.tensor();
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): got {:?} {:?}, manifest {:?} {:?}",
                    spec.name, s.name, t.dtype(), t.shape, s.dtype, s.shape
                );
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.tensor().to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&spec.outputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    name, s.name, t.shape, s.shape
                );
            }
        }
        let in_bytes = inputs
            .iter()
            .map(|t| t.tensor().size_bytes())
            .sum::<usize>();
        self.record_execute(
            exec_ns,
            in_bytes,
            in_bytes,
            0,
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        Ok(tensors)
    }

    /// Execute by (kind, config) using the canonical artifact name. The
    /// name is borrowed straight out of the manifest — no per-call clone.
    pub fn execute_kind(
        &self,
        kind: &str,
        config: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact_for(kind, config)?;
        self.execute(&spec.name, inputs)
    }

    // -- prepared-input execution -------------------------------------------

    /// Convert an artifact's persistent inputs to XLA literals **once** for
    /// a parameter-set generation. `fixed` lists `(input slot, tensor)`
    /// pairs (typically every `param:*` slot of a serving graph);
    /// `generation` must uniquely identify the contents of those tensors
    /// (see `ParamStore::generation`). Repeated calls with the same
    /// `(artifact, generation)` and slot set return the cached set without
    /// converting anything — so several tasks serving the same frozen
    /// backbone share one conversion.
    pub fn prepare(
        &self,
        name: &str,
        generation: u64,
        fixed: &[(usize, &HostTensor)],
    ) -> Result<Arc<PreparedParams>> {
        if let Some(p) = self.prepared_lookup(name, generation, fixed) {
            return Ok(p);
        }
        // one conversion at a time, re-checked under the lock: concurrent
        // builders of the same generation (e.g. parallel server setup over
        // one shared backbone) share a single backbone-sized conversion
        let _guard = self.prepare_lock.lock().unwrap();
        if let Some(p) = self.prepared_lookup(name, generation, fixed) {
            return Ok(p);
        }
        let spec = self.manifest.artifact(name)?;
        let mut lits: Vec<Option<Arc<PreparedLiteral>>> =
            (0..spec.inputs.len()).map(|_| None).collect();
        let mut fixed_sig: Vec<Option<FixedSig>> =
            (0..spec.inputs.len()).map(|_| None).collect();
        let mut fixed_bytes = 0usize;
        for &(slot, t) in fixed {
            let s = spec.inputs.get(slot).with_context(|| {
                format!(
                    "artifact {name}: prepared slot #{slot} out of range \
                     ({} inputs)",
                    spec.inputs.len()
                )
            })?;
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact {name} input #{slot} ({}): got {:?} {:?}, \
                     manifest {:?} {:?}",
                    s.name,
                    t.dtype(),
                    t.shape,
                    s.dtype,
                    s.shape
                );
            }
            if lits[slot].is_some() {
                bail!("artifact {name}: slot #{slot} prepared twice");
            }
            fixed_bytes += t.size_bytes();
            lits[slot] = Some(Arc::new(PreparedLiteral::new(t)?));
            fixed_sig[slot] = Some(FixedSig {
                name: s.name.clone(),
                shape: s.shape.clone(),
                dtype: s.dtype,
            });
        }
        let dynamic: Vec<DynSlot> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| lits[*i].is_none())
            .map(|(i, s)| DynSlot {
                slot: i,
                name: s.name.clone(),
                shape: s.shape.clone(),
                dtype: s.dtype,
            })
            .collect();
        let outputs: Vec<(String, Vec<usize>)> = spec
            .outputs
            .iter()
            .map(|o| (o.name.clone(), o.shape.clone()))
            .collect();
        let exe = self.executable(name)?;
        let prep = Arc::new(PreparedParams {
            artifact: name.to_string(),
            generation: AtomicU64::new(generation),
            exe,
            fixed_sig,
            dynamic,
            outputs,
            fixed_bytes,
            slots: RwLock::new(FrozenSlots { lits: Arc::new(lits), resident: None }),
            last_used: AtomicU64::new(0),
            resident_gauge: AtomicUsize::new(0),
        });
        self.stats.param_prepares.fetch_add(1, Ordering::Relaxed);
        self.stats
            .param_prepare_bytes
            .fetch_add(fixed_bytes, Ordering::Relaxed);
        {
            let mut cache = self.prepared.lock().unwrap();
            cache.retain(|w| w.strong_count() > 0);
            if cache.len() >= PREPARED_CACHE_CAP {
                cache.remove(0);
            }
            cache.push(Arc::downgrade(&prep));
        }
        // eager residency: upload the frozen set now so the first execute
        // already binds resident buffers (registry entry + LRU accounting).
        // Residency is a perf layer — a refused upload degrades this set
        // to the literal path (re-upload per call), never a failed prepare
        if self.resident_enabled() {
            if let Err(e) = self.make_resident(&prep) {
                crate::info!(
                    "resident upload of {name} failed, serving literal path: {e:#}"
                );
            }
        }
        Ok(prep)
    }

    /// Memo lookup for [`Runtime::prepare`]: returns a still-live prepared
    /// set for `(artifact, generation)` with the same fixed-slot
    /// assignment, pruning slots whose last holder released their set
    /// (retired generations must not stay pinned here).
    fn prepared_lookup(
        &self,
        name: &str,
        generation: u64,
        fixed: &[(usize, &HostTensor)],
    ) -> Option<Arc<PreparedParams>> {
        let mut cache = self.prepared.lock().unwrap();
        cache.retain(|w| w.strong_count() > 0);
        let hit = cache.iter().rev().find_map(|w| {
            w.upgrade().filter(|p| {
                p.generation() == generation
                    && p.artifact == name
                    && p.fixed_slots_match(fixed)
            })
        });
        if hit.is_some() {
            self.stats.param_cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Execute with a prepared parameter set: only `dynamic` tensors (in
    /// the artifact's input order, skipping prepared slots) are converted
    /// and uploaded per call — the per-call h2d cost is proportional to
    /// the batch, not the model. With residency on (the default) the
    /// frozen slots bind device-resident buffers and move zero bytes; with
    /// it off (or after eviction pressure) the cached host literals are
    /// re-uploaded, bit-identically. This is the serving hot path.
    pub fn execute_prepared(
        &self,
        prep: &PreparedParams,
        dynamic: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        if dynamic.len() != prep.dynamic.len() {
            bail!(
                "artifact {}: expected {} dynamic inputs, got {}",
                prep.artifact,
                prep.dynamic.len(),
                dynamic.len()
            );
        }
        let mut dyn_lits = Vec::with_capacity(dynamic.len());
        let mut dyn_bytes = 0usize;
        for (t, d) in dynamic.iter().zip(&prep.dynamic) {
            if t.shape != d.shape || t.dtype() != d.dtype {
                bail!(
                    "artifact {} input #{} ({}): got {:?} {:?}, manifest \
                     {:?} {:?}",
                    prep.artifact,
                    d.slot,
                    d.name,
                    t.dtype(),
                    t.shape,
                    d.dtype,
                    d.shape
                );
            }
            dyn_bytes += t.size_bytes();
            dyn_lits.push(t.to_literal()?);
        }
        // snapshot the frozen state once: in-flight executions keep their
        // literals/buffers alive via these Arcs even if a donation or an
        // eviction swaps the set mid-execution (batch-boundary atomicity)
        let (lits, resident) = {
            let s = prep.slots.read().unwrap();
            (s.lits.clone(), s.resident.clone())
        };
        let resident = if !self.resident_enabled() || prep.fixed_bytes == 0 {
            None
        } else if let Some(r) = resident {
            prep.touch(&self.resident_tick);
            Some(r)
        } else {
            // evicted (or prepared while residency was off): re-upload —
            // degrade-to-reupload is the budget contract, never an error
            self.remake_resident(prep)?
        };

        let t0 = Instant::now();
        let result = match &resident {
            Some(set) => {
                // resident fast path: upload only the dynamics, bind the
                // frozen slots straight from device memory
                let mut dyn_bufs: Vec<DeviceBuffer> =
                    Vec::with_capacity(dyn_lits.len());
                for (lit, t) in dyn_lits.iter().zip(dynamic) {
                    dyn_bufs.push(DeviceBuffer::upload(
                        &self.client,
                        lit,
                        t.size_bytes(),
                    )?);
                }
                let mut refs: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(set.bufs.len());
                let mut di = 0usize;
                for b in &set.bufs {
                    match b {
                        Some(db) => refs.push(db.buffer()),
                        None => {
                            refs.push(dyn_bufs[di].buffer());
                            di += 1;
                        }
                    }
                }
                prep.exe.0.execute_b::<&xla::PjRtBuffer>(&refs)?
            }
            None => {
                // literal path: cached parameter literals + fresh dynamics
                // (PJRT re-uploads every literal argument — counted below)
                let mut refs: Vec<&xla::Literal> =
                    Vec::with_capacity(lits.len());
                let mut di = 0usize;
                for f in lits.iter() {
                    match f {
                        Some(pl) => refs.push(pl.literal()),
                        None => {
                            refs.push(&dyn_lits[di]);
                            di += 1;
                        }
                    }
                }
                prep.exe.0.execute::<&xla::Literal>(&refs)?
            }
        };
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;
        if parts.len() != prep.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                prep.artifact,
                prep.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, (oname, oshape)) in tensors.iter().zip(&prep.outputs) {
            if &t.shape != oshape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    prep.artifact, oname, t.shape, oshape
                );
            }
        }
        // h2d_bytes stays "everything bound" (the legacy total); the split
        // records what actually crossed the bus: on the resident path the
        // frozen set moves zero bytes, on the literal path PJRT re-uploads
        // it with every call
        let frozen_uploaded =
            if resident.is_some() { 0 } else { prep.fixed_bytes };
        self.record_execute(
            exec_ns,
            dyn_bytes + prep.fixed_bytes,
            dyn_bytes + frozen_uploaded,
            prep.fixed_bytes - frozen_uploaded,
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        self.stats
            .param_reuse_bytes
            .fetch_add(prep.fixed_bytes, Ordering::Relaxed);
        Ok(tensors)
    }

    // -- device residency ---------------------------------------------------

    /// Snapshot `prep`'s frozen literals and upload them as device
    /// buffers. Runs without the registry lock held — the upload is a
    /// model-sized h2d copy and must not serialize unrelated re-uploads,
    /// prepares, or stats readers behind it. Returns the uploaded set
    /// together with the literal vector it was built from, so installers
    /// can reject the upload if a donation swapped the slots mid-upload.
    fn upload_set(
        &self,
        prep: &PreparedParams,
    ) -> Result<(Arc<ResidentSet>, FrozenLits)> {
        let lits = prep.slots.read().unwrap().lits.clone();
        let mut bufs: Vec<Option<Arc<DeviceBuffer>>> =
            Vec::with_capacity(lits.len());
        for f in lits.iter() {
            bufs.push(match f {
                Some(pl) => Some(Arc::new(DeviceBuffer::upload(
                    &self.client,
                    pl.literal(),
                    pl.size_bytes(),
                )?)),
                None => None,
            });
        }
        self.stats.resident_prepares.fetch_add(1, Ordering::Relaxed);
        self.stats
            .h2d_upload_bytes
            .fetch_add(prep.fixed_bytes, Ordering::Relaxed);
        let set = Arc::new(ResidentSet { bufs, bytes: prep.fixed_bytes });
        Ok((set, lits))
    }

    /// Install an uploaded resident set — but only if the literals it was
    /// uploaded from are still the set's current contents. A donation
    /// landing between the upload's snapshot and this install swaps the
    /// `lits` Arc; installing buffers built from the pre-donation
    /// literals would resurrect the old weights for every later execute.
    /// The ptr-equality check under the slot write lock extends the
    /// donation fence across the unlocked upload window. Returns the set
    /// now serving (ours, or a racing uploader's that won), or `None`
    /// when the upload is stale and was discarded.
    fn install_resident(
        &self,
        prep: &PreparedParams,
        set: Arc<ResidentSet>,
        uploaded_from: &FrozenLits,
    ) -> Option<Arc<ResidentSet>> {
        let mut s = prep.slots.write().unwrap();
        if !Arc::ptr_eq(&s.lits, uploaded_from) {
            return None;
        }
        if let Some(r) = &s.resident {
            return Some(r.clone());
        }
        s.resident = Some(set.clone());
        prep.resident_gauge
            .store(prep.fixed_bytes, Ordering::Relaxed);
        prep.touch(&self.resident_tick);
        Some(set)
    }

    /// Upload `prep`'s frozen slots and install them, re-uploading from
    /// the fresh contents if a donation invalidated the snapshot
    /// mid-upload. Persistent contention gives up and returns `None` —
    /// the caller serves the literal path for this call and residency is
    /// retried on the next one (degrade, never a wrong answer).
    fn upload_and_install(
        &self,
        prep: &PreparedParams,
    ) -> Result<Option<Arc<ResidentSet>>> {
        for _ in 0..2 {
            let (set, from) = self.upload_set(prep)?;
            if let Some(live) = self.install_resident(prep, set, &from) {
                return Ok(Some(live));
            }
        }
        Ok(None)
    }

    /// First-time residency for a freshly prepared set: register it in the
    /// LRU registry, upload its frozen slots, and evict LRU sets if the
    /// registry now exceeds the byte budget. A set larger than the whole
    /// budget stays literal-only.
    fn make_resident(&self, prep: &Arc<PreparedParams>) -> Result<()> {
        if prep.fixed_bytes == 0
            || prep.fixed_bytes > self.resident_budget_bytes()
        {
            return Ok(());
        }
        {
            let mut reg = self.resident.lock().unwrap();
            reg.retain(|w| w.strong_count() > 0);
            if !reg
                .iter()
                .any(|w| w.upgrade().is_some_and(|p| Arc::ptr_eq(&p, prep)))
            {
                reg.push(Arc::downgrade(prep));
            }
            if prep.slots.read().unwrap().resident.is_some() {
                return Ok(());
            }
        }
        // registry lock released: the upload runs unserialized, and the
        // install re-validates against a concurrent donation
        if self.upload_and_install(prep)?.is_some() {
            self.evict_over_budget(Arc::as_ptr(prep));
        }
        Ok(())
    }

    /// Re-upload a previously evicted (or pre-residency) set from the hot
    /// path. Only sets in the registry come back — a set prepared while
    /// residency was disabled and never registered stays on the literal
    /// path, which is correct, just slower.
    fn remake_resident(
        &self,
        prep: &PreparedParams,
    ) -> Result<Option<Arc<ResidentSet>>> {
        if prep.fixed_bytes > self.resident_budget_bytes() {
            return Ok(None);
        }
        let me: *const PreparedParams = prep;
        {
            let mut reg = self.resident.lock().unwrap();
            reg.retain(|w| w.strong_count() > 0);
            if !reg
                .iter()
                .any(|w| w.upgrade().is_some_and(|p| Arc::as_ptr(&p) == me))
            {
                return Ok(None);
            }
            // double-check under the registry lock: a racing execute may
            // have re-uploaded the set already
            if let Some(r) = prep.slots.read().unwrap().resident.clone() {
                return Ok(Some(r));
            }
        }
        let set = self.upload_and_install(prep)?;
        if set.is_some() {
            self.evict_over_budget(me);
        }
        Ok(set)
    }

    /// Strip least-recently-used resident sets (never `keep`) until total
    /// resident bytes fit the budget. Acquires the registry lock itself —
    /// callers must not hold it. In-flight executions holding a stripped
    /// set's `Arc` finish on it; the device memory frees when the last
    /// holder drops.
    fn evict_over_budget(&self, keep: *const PreparedParams) {
        let mut reg = self.resident.lock().unwrap();
        reg.retain(|w| w.strong_count() > 0);
        let budget = self.resident_budget_bytes();
        loop {
            let live: Vec<Arc<PreparedParams>> =
                reg.iter().filter_map(|w| w.upgrade()).collect();
            let total: usize =
                live.iter().map(|p| p.resident_bytes()).sum();
            if total <= budget {
                return;
            }
            let victim = live
                .iter()
                .filter(|p| {
                    Arc::as_ptr(p) != keep && p.resident_bytes() > 0
                })
                .min_by_key(|p| p.last_used.load(Ordering::Relaxed));
            let Some(victim) = victim else { return };
            victim.slots.write().unwrap().resident = None;
            victim.resident_gauge.store(0, Ordering::Relaxed);
            self.stats.resident_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    // -- donation (in-place frozen-slot refresh) ----------------------------

    /// Refresh a prepared set's frozen slots **in place** from training
    /// write-backs, then bump its generation — the donation path. The new
    /// literals (and, when the set is resident, freshly uploaded device
    /// buffers) are installed under the slot lock *before* the new
    /// generation becomes visible (the write-back fence): a cache lookup
    /// keyed on the old generation can never observe donated contents,
    /// and one keyed on `new_generation` always sees them.
    ///
    /// Safety contract (see docs/contracts.md): the caller must be the
    /// sole owner of the `(artifact, old generation)` cache route —
    /// donating into a set another task still serves would mutate their
    /// parameters. On an upload error the donation rolls back: the
    /// pre-donation literals are restored (the resident buffers were
    /// never replaced) and the generation stays put, so the old set
    /// keeps serving exactly the old weights and the caller's next
    /// donation diffs against contents that really are the old store's.
    pub fn donate_writeback(
        &self,
        prep: &PreparedParams,
        new_generation: u64,
        updates: &[(usize, &HostTensor)],
    ) -> Result<()> {
        let mut fresh: Vec<(usize, Arc<PreparedLiteral>)> =
            Vec::with_capacity(updates.len());
        let mut bytes = 0usize;
        for &(slot, t) in updates {
            let sig = prep
                .fixed_sig
                .get(slot)
                .and_then(|s| s.as_ref())
                .with_context(|| {
                    format!(
                        "artifact {}: donated slot #{slot} is not a frozen \
                         slot of this prepared set",
                        prep.artifact
                    )
                })?;
            if t.shape != sig.shape || t.dtype() != sig.dtype {
                bail!(
                    "artifact {} donated slot #{slot} ({}): got {:?} {:?}, \
                     prepared {:?} {:?}",
                    prep.artifact,
                    sig.name,
                    t.dtype(),
                    t.shape,
                    sig.dtype,
                    sig.shape
                );
            }
            if fresh.iter().any(|(s, _)| *s == slot) {
                bail!(
                    "artifact {}: slot #{slot} donated twice",
                    prep.artifact
                );
            }
            bytes += t.size_bytes();
            fresh.push((slot, Arc::new(PreparedLiteral::new(t)?)));
        }
        let mut s = prep.slots.write().unwrap();
        let prev_lits = s.lits.clone();
        let mut lits = s.lits.as_ref().clone();
        for (slot, lit) in &fresh {
            lits[*slot] = Some(lit.clone());
        }
        s.lits = Arc::new(lits);
        let mut uploaded = 0usize;
        if let Some(old) = s.resident.clone() {
            let mut bufs = old.bufs.clone();
            for (slot, lit) in &fresh {
                let up = DeviceBuffer::upload(
                    &self.client,
                    lit.literal(),
                    lit.size_bytes(),
                );
                match up {
                    Ok(db) => {
                        uploaded += db.size_bytes();
                        bufs[*slot] = Some(Arc::new(db));
                    }
                    Err(e) => {
                        // device refused the refresh: roll the literals
                        // back to the pre-donation contents. `s.resident`
                        // was never replaced (the fresh buffers live only
                        // in the local `bufs` clone), so the set is again
                        // exactly the pre-donation state under the old,
                        // still-valid generation — the old set keeps
                        // serving, and the caller's live store still
                        // describes the prepared contents
                        s.lits = prev_lits.clone();
                        return Err(e);
                    }
                }
            }
            s.resident = Some(Arc::new(ResidentSet {
                bufs,
                bytes: old.bytes,
            }));
        }
        // the fence: contents first, key last, both under the write lock
        prep.generation.store(new_generation, Ordering::Release);
        drop(s);
        self.stats.donations.fetch_add(1, Ordering::Relaxed);
        self.stats
            .donated_refresh_bytes
            .fetch_add(bytes, Ordering::Relaxed);
        self.stats
            .h2d_upload_bytes
            .fetch_add(uploaded, Ordering::Relaxed);
        Ok(())
    }
}

/// One dynamic (per-call) input slot of a [`PreparedParams`] set.
#[derive(Debug, Clone)]
struct DynSlot {
    slot: usize,
    name: String,
    shape: Vec<usize>,
    dtype: Dtype,
}

/// Signature of a frozen input slot — what a donation must match.
#[derive(Debug, Clone)]
struct FixedSig {
    name: String,
    shape: Vec<usize>,
    dtype: Dtype,
}

/// The frozen slots' device-resident twin: slot-indexed buffers (`Some`
/// for frozen slots) uploaded once and bound to every execution. Shared
/// via `Arc` so an in-flight execution keeps its buffers alive across a
/// concurrent donation or eviction; per-buffer `Arc`s let a donation
/// copy-on-write only the refreshed slots.
struct ResidentSet {
    bufs: Vec<Option<Arc<DeviceBuffer>>>,
    bytes: usize,
}

/// Slot-indexed frozen literal vector, shared by `Arc`. The Arc identity
/// doubles as a content version: a donation always installs a *new* Arc,
/// so `Arc::ptr_eq` against a snapshot detects "donated since I looked"
/// without comparing tensors (see [`Runtime::install_resident`]).
type FrozenLits = Arc<Vec<Option<Arc<PreparedLiteral>>>>;

/// The mutable frozen state of a prepared set, swapped atomically under
/// one lock: the host literals (always present — the eviction/baseline
/// fallback) and the optional resident device buffers. A donation
/// replaces both *then* bumps the owning set's generation, so a
/// generation key can never name half-refreshed contents.
struct FrozenSlots {
    /// slot-indexed: `Some` for prepared inputs, `None` for dynamic ones
    lits: FrozenLits,
    resident: Option<Arc<ResidentSet>>,
}

/// An artifact's persistent inputs frozen as XLA literals (and, by
/// default, resident device buffers), plus everything
/// [`Runtime::execute_prepared`] needs to run without touching the
/// manifest or the executable cache: the resolved executable, the dynamic
/// slots' expected signatures, and the output signatures. Built by
/// [`Runtime::prepare`], shared across worker threads via `Arc`.
pub struct PreparedParams {
    artifact: String,
    /// content generation of the frozen slots; atomic because a donation
    /// re-keys the set in place (write-back fence: stored only after the
    /// refreshed contents are installed)
    generation: AtomicU64,
    exe: Arc<SharedExe>,
    /// slot-indexed signatures of the frozen inputs (`None` = dynamic)
    fixed_sig: Vec<Option<FixedSig>>,
    /// manifest-order signatures of the dynamic inputs
    dynamic: Vec<DynSlot>,
    /// (name, shape) per output, for validation without the manifest
    outputs: Vec<(String, Vec<usize>)>,
    fixed_bytes: usize,
    /// frozen literals + optional resident buffers (see [`FrozenSlots`])
    slots: RwLock<FrozenSlots>,
    /// LRU clock value of the last resident bind (eviction order)
    last_used: AtomicU64,
    /// device bytes currently resident (0 when evicted) — lock-free gauge
    /// so budget math and stats never touch the slot lock
    resident_gauge: AtomicUsize,
}

impl PreparedParams {
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    /// The parameter-set generation the frozen contents belong to. Moves
    /// forward when a donation refreshes the set in place.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Host bytes frozen into cached literals — the conversion cost each
    /// `execute_prepared` call avoids.
    pub fn fixed_bytes(&self) -> usize {
        self.fixed_bytes
    }

    /// Device bytes this set currently holds resident (0 when evicted or
    /// residency is off).
    pub fn resident_bytes(&self) -> usize {
        self.resident_gauge.load(Ordering::Relaxed)
    }

    /// Number of per-call inputs [`Runtime::execute_prepared`] expects.
    pub fn dynamic_len(&self) -> usize {
        self.dynamic.len()
    }

    fn touch(&self, clock: &AtomicU64) {
        self.last_used
            .store(clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
    }

    fn fixed_slots_match(&self, fixed: &[(usize, &HostTensor)]) -> bool {
        let n_fixed = self.fixed_sig.iter().filter(|f| f.is_some()).count();
        n_fixed == fixed.len()
            && fixed.iter().all(|(slot, _)| {
                matches!(self.fixed_sig.get(*slot), Some(Some(_)))
            })
    }
}

impl std::fmt::Debug for PreparedParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedParams")
            .field("artifact", &self.artifact)
            .field("generation", &self.generation())
            .field("fixed_bytes", &self.fixed_bytes)
            .field("resident_bytes", &self.resident_bytes())
            .field("dynamic", &self.dynamic.len())
            .finish()
    }
}

/// Borrowed-or-owned input binding for [`Runtime::execute_bound`].
pub enum Bind<'a> {
    Ref(&'a HostTensor),
    Own(HostTensor),
}

impl Bind<'_> {
    pub fn tensor(&self) -> &HostTensor {
        match self {
            Bind::Ref(t) => t,
            Bind::Own(t) => t,
        }
    }
}

/// Named I/O helper: assemble the flat input vector of an artifact from a
/// name->tensor lookup, and index outputs by name.
pub struct IoBinder<'a> {
    spec: &'a ArtifactSpec,
}

impl<'a> IoBinder<'a> {
    pub fn new(spec: &'a ArtifactSpec) -> IoBinder<'a> {
        IoBinder { spec }
    }

    /// Build the input vector by calling `lookup` for each manifest input.
    pub fn bind<F>(&self, mut lookup: F) -> Result<Vec<HostTensor>>
    where
        F: FnMut(&IoSpec) -> Result<HostTensor>,
    {
        self.spec
            .inputs
            .iter()
            .map(|s| {
                let t = lookup(s)?;
                if t.shape != s.shape {
                    bail!("binding {}: shape {:?} != {:?}", s.name, t.shape, s.shape);
                }
                Ok(t)
            })
            .collect()
    }

    /// Extract a named output from the flat output vector.
    pub fn output<'b>(
        &self,
        outputs: &'b [HostTensor],
        name: &str,
    ) -> Result<&'b HostTensor> {
        Ok(&outputs[self.spec.output_index(name)?])
    }
}
