//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by the python
//! compile path) and executes them on the CPU PJRT client from the L3 hot
//! path. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> HloModuleProto
//! (text parser reassigns 64-bit ids) -> XlaComputation -> compile -> cached
//! PjRtLoadedExecutable -> execute with Literals built from [`HostTensor`]s.

pub mod manifest;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, ParamSpec};
pub use tensor::{Dtype, HostTensor, TensorData};

/// PJRT executables hold raw pointers; the underlying CPU client is
/// thread-safe, so we mark the cache entry Send+Sync to let the fleet
/// simulator share compiled executables across worker threads.
struct SharedExe(xla::PjRtLoadedExecutable);
// SAFETY: xla_extension's PjRtLoadedExecutable::Execute and the CPU client
// are thread-safe (internal synchronization); the Rust wrapper only lacks
// the auto-traits because of the raw pointer field.
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

/// Cumulative runtime counters (observability for the perf pass).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_ns: u128,
    pub executions: usize,
    pub execute_ns: u128,
    pub h2d_bytes: usize,
    pub d2h_bytes: usize,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<SharedExe>>>,
    /// serializes XLA compilation so concurrent fleet workers requesting
    /// the same artifact produce exactly one executable (double-checked
    /// against `cache` under this lock)
    compile_lock: Mutex<()>,
    stats: Mutex<RuntimeStats>,
}

// SAFETY: see SharedExe — the CPU PJRT client is internally synchronized.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Borrow an artifact's signature directly off the runtime — callers on
    /// hot paths resolve the spec once (or per call, by reference) instead
    /// of cloning `ArtifactSpec` out of the manifest.
    pub fn artifact_spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Compile (or fetch the cached) executable for a manifest artifact.
    fn executable(&self, name: &str) -> Result<Arc<SharedExe>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        // one compiler at a time; re-check the cache once we hold the lock
        let _guard = self.compile_lock.lock().unwrap();
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exe = Arc::new(SharedExe(exe));
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_ns += t0.elapsed().as_nanos();
        }
        crate::debug!("compiled {name} in {:?}", t0.elapsed());
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (e.g. at session start).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate `inputs` against the artifact signature (shape + dtype).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                bail!(
                    "artifact {} input #{i} ({}): shape {:?} != manifest {:?}",
                    spec.name, s.name, t.shape, s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): dtype {:?} != manifest {:?}",
                    spec.name, s.name, t.dtype(), s.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact with host tensors; returns outputs in manifest
    /// order. The AOT path lowers with `return_tuple=True`, so the single
    /// result buffer is a tuple literal that we decompose.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        self.validate(spec, inputs)?;
        let exe = self.executable(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos();

        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&spec.outputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    name, s.name, t.shape, s.shape
                );
            }
        }

        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.execute_ns += exec_ns;
        st.h2d_bytes += inputs.iter().map(|t| t.size_bytes()).sum::<usize>();
        st.d2h_bytes += tensors.iter().map(|t| t.size_bytes()).sum::<usize>();
        Ok(tensors)
    }

    /// Like [`Runtime::execute`] but with borrowed-or-owned inputs, so hot
    /// loops can bind persistent state (params, moments, masks) without
    /// cloning host tensors every step (EXPERIMENTS.md §Perf).
    pub fn execute_bound(&self, name: &str, inputs: &[Bind<'_>]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let t = t.tensor();
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): got {:?} {:?}, manifest {:?} {:?}",
                    spec.name, s.name, t.dtype(), t.shape, s.dtype, s.shape
                );
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.tensor().to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos();
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.execute_ns += exec_ns;
        st.h2d_bytes += inputs.iter().map(|t| t.tensor().size_bytes()).sum::<usize>();
        st.d2h_bytes += tensors.iter().map(|t| t.size_bytes()).sum::<usize>();
        Ok(tensors)
    }

    /// Execute by (kind, config) using the canonical artifact name.
    pub fn execute_kind(
        &self,
        kind: &str,
        config: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let name = self.manifest.artifact_for(kind, config)?.name.clone();
        self.execute(&name, inputs)
    }
}

/// Borrowed-or-owned input binding for [`Runtime::execute_bound`].
pub enum Bind<'a> {
    Ref(&'a HostTensor),
    Own(HostTensor),
}

impl Bind<'_> {
    pub fn tensor(&self) -> &HostTensor {
        match self {
            Bind::Ref(t) => t,
            Bind::Own(t) => t,
        }
    }
}

/// Named I/O helper: assemble the flat input vector of an artifact from a
/// name->tensor lookup, and index outputs by name.
pub struct IoBinder<'a> {
    spec: &'a ArtifactSpec,
}

impl<'a> IoBinder<'a> {
    pub fn new(spec: &'a ArtifactSpec) -> IoBinder<'a> {
        IoBinder { spec }
    }

    /// Build the input vector by calling `lookup` for each manifest input.
    pub fn bind<F>(&self, mut lookup: F) -> Result<Vec<HostTensor>>
    where
        F: FnMut(&IoSpec) -> Result<HostTensor>,
    {
        self.spec
            .inputs
            .iter()
            .map(|s| {
                let t = lookup(s)?;
                if t.shape != s.shape {
                    bail!("binding {}: shape {:?} != {:?}", s.name, t.shape, s.shape);
                }
                Ok(t)
            })
            .collect()
    }

    /// Extract a named output from the flat output vector.
    pub fn output<'b>(
        &self,
        outputs: &'b [HostTensor],
        name: &str,
    ) -> Result<&'b HostTensor> {
        Ok(&outputs[self.spec.output_index(name)?])
    }
}
