//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by the python
//! compile path) and executes them on the CPU PJRT client from the L3 hot
//! path. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> HloModuleProto
//! (text parser reassigns 64-bit ids) -> XlaComputation -> compile -> cached
//! PjRtLoadedExecutable -> execute with Literals built from [`HostTensor`]s.
//!
//! Hot-path structure (the serving tier executes thousands of batches per
//! second against the same parameter set):
//!
//! - the executable cache is an `RwLock` — concurrent workers resolve a
//!   compiled artifact with one uncontended read lock, no serialization;
//! - [`RuntimeStats`] counters are atomics, so stats updates in
//!   `execute`/`execute_bound`/`execute_prepared` never take a lock;
//! - [`Runtime::prepare`] converts an artifact's *persistent* inputs (the
//!   `param:*` tensors of a parameter-set generation) to `xla::Literal`s
//!   once, and [`Runtime::execute_prepared`] then converts only the
//!   per-call dynamic inputs (the padded image batch). Prepared sets are
//!   memoized by `(artifact, generation)` so N tasks serving the same
//!   frozen backbone share one conversion.

pub mod manifest;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, ParamSpec};
pub use tensor::{Dtype, HostTensor, PreparedLiteral, TensorData};

/// Bound on memo slots for prepared parameter sets. Entries are `Weak`,
/// so the memo never pins a retired generation's literals in memory (a
/// full backbone-sized copy each) — it only deduplicates sets some
/// caller still holds alive, e.g. several tasks serving one backbone.
const PREPARED_CACHE_CAP: usize = 32;

/// Process-wide source of content-state generation ids. `ParamStore`
/// draws its per-mutation generations here, and sessions draw ids for
/// *composed* frozen input sets (backbone params + allocation masks) that
/// no single store describes. A single counter means a prepared set keyed
/// on any of these ids can never alias a set built from a different
/// source.
static GENERATION: AtomicU64 = AtomicU64::new(1);

/// Mint a globally unique content-state id (never reused). Key prepared
/// input sets on this when the frozen tensors are constant for the key's
/// lifetime — e.g. one id per fine-tuning session for the (backbone,
/// masks) composition that holds still across every train step.
pub fn next_generation() -> u64 {
    GENERATION.fetch_add(1, Ordering::Relaxed)
}

/// PJRT executables hold raw pointers; the underlying CPU client is
/// thread-safe, so we mark the cache entry Send+Sync to let the fleet
/// simulator share compiled executables across worker threads.
struct SharedExe(xla::PjRtLoadedExecutable);
// SAFETY: xla_extension's PjRtLoadedExecutable::Execute and the CPU client
// are thread-safe (internal synchronization); the Rust wrapper only lacks
// the auto-traits because of the raw pointer field.
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

/// Cumulative runtime counters (observability for the perf pass). This is
/// the snapshot type returned by [`Runtime::stats`]; internally the
/// counters are lock-free atomics so concurrent executor workers never
/// serialize on a stats mutex.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_ns: u128,
    pub executions: usize,
    pub execute_ns: u128,
    pub h2d_bytes: usize,
    pub d2h_bytes: usize,
    /// prepared parameter-set builds ([`Runtime::prepare`] cache misses):
    /// happens at server start and per parameter swap, never per batch
    pub param_prepares: usize,
    /// host bytes converted to literals during those builds
    pub param_prepare_bytes: usize,
    /// [`Runtime::prepare`] calls answered from the generation-keyed cache
    /// (e.g. several tasks sharing one frozen backbone generation)
    pub param_cache_hits: usize,
    /// parameter bytes bound from cached literals across all
    /// [`Runtime::execute_prepared`] calls — conversion work the cache
    /// saved the hot path
    pub param_reuse_bytes: usize,
}

/// Lock-free counter twin of [`RuntimeStats`]. Relaxed ordering is enough:
/// the counters are independent monotonic tallies, not synchronization.
#[derive(Default)]
struct StatCounters {
    compiles: AtomicUsize,
    compile_ns: AtomicU64,
    executions: AtomicUsize,
    execute_ns: AtomicU64,
    h2d_bytes: AtomicUsize,
    d2h_bytes: AtomicUsize,
    param_prepares: AtomicUsize,
    param_prepare_bytes: AtomicUsize,
    param_cache_hits: AtomicUsize,
    param_reuse_bytes: AtomicUsize,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RwLock<HashMap<String, Arc<SharedExe>>>,
    /// serializes XLA compilation so concurrent fleet workers requesting
    /// the same artifact produce exactly one executable (double-checked
    /// against `cache` under this lock)
    compile_lock: Mutex<()>,
    /// live prepared parameter sets, most-recently-inserted last; weak so
    /// a swapped-out generation's literals free as soon as its last user
    /// drops them (see `PREPARED_CACHE_CAP`)
    prepared: Mutex<Vec<Weak<PreparedParams>>>,
    /// serializes parameter-literal conversion so concurrent builders of
    /// the same generation produce exactly one prepared set (same
    /// double-check pattern as `compile_lock`)
    prepare_lock: Mutex<()>,
    stats: StatCounters,
}

// SAFETY: see SharedExe — the CPU PJRT client is internally synchronized.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RwLock::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            prepared: Mutex::new(Vec::new()),
            prepare_lock: Mutex::new(()),
            stats: StatCounters::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Borrow an artifact's signature directly off the runtime — callers on
    /// hot paths resolve the spec once (or per call, by reference) instead
    /// of cloning `ArtifactSpec` out of the manifest.
    pub fn artifact_spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            compiles: self.stats.compiles.load(Ordering::Relaxed),
            compile_ns: self.stats.compile_ns.load(Ordering::Relaxed) as u128,
            executions: self.stats.executions.load(Ordering::Relaxed),
            execute_ns: self.stats.execute_ns.load(Ordering::Relaxed) as u128,
            h2d_bytes: self.stats.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.stats.d2h_bytes.load(Ordering::Relaxed),
            param_prepares: self.stats.param_prepares.load(Ordering::Relaxed),
            param_prepare_bytes: self
                .stats
                .param_prepare_bytes
                .load(Ordering::Relaxed),
            param_cache_hits: self.stats.param_cache_hits.load(Ordering::Relaxed),
            param_reuse_bytes: self.stats.param_reuse_bytes.load(Ordering::Relaxed),
        }
    }

    fn record_execute(&self, exec_ns: u64, in_bytes: usize, out_bytes: usize) {
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats.execute_ns.fetch_add(exec_ns, Ordering::Relaxed);
        self.stats.h2d_bytes.fetch_add(in_bytes, Ordering::Relaxed);
        self.stats.d2h_bytes.fetch_add(out_bytes, Ordering::Relaxed);
    }

    /// Compile (or fetch the cached) executable for a manifest artifact.
    /// The hit path is a single uncontended read lock and an `Arc` clone —
    /// no allocation, no writer exclusion between concurrent readers.
    fn executable(&self, name: &str) -> Result<Arc<SharedExe>> {
        if let Some(exe) = self.cache.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        // one compiler at a time; re-check the cache once we hold the lock
        let _guard = self.compile_lock.lock().unwrap();
        if let Some(exe) = self.cache.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exe = Arc::new(SharedExe(exe));
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        self.stats
            .compile_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        crate::debug!("compiled {name} in {:?}", t0.elapsed());
        self.cache
            .write()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (e.g. at session start).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate `inputs` against the artifact signature (shape + dtype).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                bail!(
                    "artifact {} input #{i} ({}): shape {:?} != manifest {:?}",
                    spec.name, s.name, t.shape, s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): dtype {:?} != manifest {:?}",
                    spec.name, s.name, t.dtype(), s.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact with host tensors; returns outputs in manifest
    /// order. The AOT path lowers with `return_tuple=True`, so the single
    /// result buffer is a tuple literal that we decompose.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        self.validate(spec, inputs)?;
        let exe = self.executable(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;

        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&spec.outputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    name, s.name, t.shape, s.shape
                );
            }
        }

        self.record_execute(
            exec_ns,
            inputs.iter().map(|t| t.size_bytes()).sum::<usize>(),
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        Ok(tensors)
    }

    /// Like [`Runtime::execute`] but with borrowed-or-owned inputs, so hot
    /// loops can bind persistent state (params, moments, masks) without
    /// cloning host tensors every step (EXPERIMENTS.md §Perf).
    pub fn execute_bound(&self, name: &str, inputs: &[Bind<'_>]) -> Result<Vec<HostTensor>> {
        let spec = self.artifact_spec(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let t = t.tensor();
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact {} input #{i} ({}): got {:?} {:?}, manifest {:?} {:?}",
                    spec.name, s.name, t.dtype(), t.shape, s.dtype, s.shape
                );
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.tensor().to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(&literals)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&spec.outputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    name, s.name, t.shape, s.shape
                );
            }
        }
        self.record_execute(
            exec_ns,
            inputs.iter().map(|t| t.tensor().size_bytes()).sum::<usize>(),
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        Ok(tensors)
    }

    /// Execute by (kind, config) using the canonical artifact name. The
    /// name is borrowed straight out of the manifest — no per-call clone.
    pub fn execute_kind(
        &self,
        kind: &str,
        config: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact_for(kind, config)?;
        self.execute(&spec.name, inputs)
    }

    // -- prepared-input execution -------------------------------------------

    /// Convert an artifact's persistent inputs to XLA literals **once** for
    /// a parameter-set generation. `fixed` lists `(input slot, tensor)`
    /// pairs (typically every `param:*` slot of a serving graph);
    /// `generation` must uniquely identify the contents of those tensors
    /// (see `ParamStore::generation`). Repeated calls with the same
    /// `(artifact, generation)` and slot set return the cached set without
    /// converting anything — so several tasks serving the same frozen
    /// backbone share one conversion.
    pub fn prepare(
        &self,
        name: &str,
        generation: u64,
        fixed: &[(usize, &HostTensor)],
    ) -> Result<Arc<PreparedParams>> {
        if let Some(p) = self.prepared_lookup(name, generation, fixed) {
            return Ok(p);
        }
        // one conversion at a time, re-checked under the lock: concurrent
        // builders of the same generation (e.g. parallel server setup over
        // one shared backbone) share a single backbone-sized conversion
        let _guard = self.prepare_lock.lock().unwrap();
        if let Some(p) = self.prepared_lookup(name, generation, fixed) {
            return Ok(p);
        }
        let spec = self.manifest.artifact(name)?;
        let mut lits: Vec<Option<PreparedLiteral>> =
            (0..spec.inputs.len()).map(|_| None).collect();
        let mut fixed_bytes = 0usize;
        for &(slot, t) in fixed {
            let s = spec.inputs.get(slot).with_context(|| {
                format!(
                    "artifact {name}: prepared slot #{slot} out of range \
                     ({} inputs)",
                    spec.inputs.len()
                )
            })?;
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact {name} input #{slot} ({}): got {:?} {:?}, \
                     manifest {:?} {:?}",
                    s.name,
                    t.dtype(),
                    t.shape,
                    s.dtype,
                    s.shape
                );
            }
            if lits[slot].is_some() {
                bail!("artifact {name}: slot #{slot} prepared twice");
            }
            fixed_bytes += t.size_bytes();
            lits[slot] = Some(PreparedLiteral::new(t)?);
        }
        let dynamic: Vec<DynSlot> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| lits[*i].is_none())
            .map(|(i, s)| DynSlot {
                slot: i,
                name: s.name.clone(),
                shape: s.shape.clone(),
                dtype: s.dtype,
            })
            .collect();
        let outputs: Vec<(String, Vec<usize>)> = spec
            .outputs
            .iter()
            .map(|o| (o.name.clone(), o.shape.clone()))
            .collect();
        let exe = self.executable(name)?;
        let prep = Arc::new(PreparedParams {
            artifact: name.to_string(),
            generation,
            exe,
            fixed: lits,
            dynamic,
            outputs,
            fixed_bytes,
        });
        self.stats.param_prepares.fetch_add(1, Ordering::Relaxed);
        self.stats
            .param_prepare_bytes
            .fetch_add(fixed_bytes, Ordering::Relaxed);
        let mut cache = self.prepared.lock().unwrap();
        cache.retain(|w| w.strong_count() > 0);
        if cache.len() >= PREPARED_CACHE_CAP {
            cache.remove(0);
        }
        cache.push(Arc::downgrade(&prep));
        Ok(prep)
    }

    /// Memo lookup for [`Runtime::prepare`]: returns a still-live prepared
    /// set for `(artifact, generation)` with the same fixed-slot
    /// assignment, pruning slots whose last holder released their set
    /// (retired generations must not stay pinned here).
    fn prepared_lookup(
        &self,
        name: &str,
        generation: u64,
        fixed: &[(usize, &HostTensor)],
    ) -> Option<Arc<PreparedParams>> {
        let mut cache = self.prepared.lock().unwrap();
        cache.retain(|w| w.strong_count() > 0);
        let hit = cache.iter().rev().find_map(|w| {
            w.upgrade().filter(|p| {
                p.generation == generation
                    && p.artifact == name
                    && p.fixed_slots_match(fixed)
            })
        });
        if hit.is_some() {
            self.stats.param_cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Execute with a prepared parameter set: only `dynamic` tensors (in
    /// the artifact's input order, skipping prepared slots) are converted
    /// to literals — the per-call conversion cost is proportional to the
    /// batch, not the model. This is the serving hot path.
    pub fn execute_prepared(
        &self,
        prep: &PreparedParams,
        dynamic: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        if dynamic.len() != prep.dynamic.len() {
            bail!(
                "artifact {}: expected {} dynamic inputs, got {}",
                prep.artifact,
                prep.dynamic.len(),
                dynamic.len()
            );
        }
        let mut dyn_lits = Vec::with_capacity(dynamic.len());
        let mut dyn_bytes = 0usize;
        for (t, d) in dynamic.iter().zip(&prep.dynamic) {
            if t.shape != d.shape || t.dtype() != d.dtype {
                bail!(
                    "artifact {} input #{} ({}): got {:?} {:?}, manifest \
                     {:?} {:?}",
                    prep.artifact,
                    d.slot,
                    d.name,
                    t.dtype(),
                    t.shape,
                    d.dtype,
                    d.shape
                );
            }
            dyn_bytes += t.size_bytes();
            dyn_lits.push(t.to_literal()?);
        }
        // slot-ordered bindings: cached parameter literals + fresh dynamics
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(prep.fixed.len());
        let mut di = 0usize;
        for f in &prep.fixed {
            match f {
                Some(pl) => refs.push(pl.literal()),
                None => {
                    refs.push(&dyn_lits[di]);
                    di += 1;
                }
            }
        }
        let t0 = Instant::now();
        let result = prep.exe.0.execute::<&xla::Literal>(&refs)?;
        let outs = result
            .first()
            .and_then(|r| r.first())
            .context("execution returned no buffers")?
            .to_literal_sync()?;
        let parts = outs.to_tuple()?;
        let exec_ns = t0.elapsed().as_nanos() as u64;
        if parts.len() != prep.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, runtime returned {}",
                prep.artifact,
                prep.outputs.len(),
                parts.len()
            );
        }
        let tensors: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, (oname, oshape)) in tensors.iter().zip(&prep.outputs) {
            if &t.shape != oshape {
                bail!(
                    "artifact {} output {}: shape {:?} != manifest {:?}",
                    prep.artifact, oname, t.shape, oshape
                );
            }
        }
        // h2d counts everything bound to the device this execution — the
        // cached literals are still copied host->device by PJRT, only
        // their host-side conversion was saved (tracked separately below)
        self.record_execute(
            exec_ns,
            dyn_bytes + prep.fixed_bytes,
            tensors.iter().map(|t| t.size_bytes()).sum::<usize>(),
        );
        self.stats
            .param_reuse_bytes
            .fetch_add(prep.fixed_bytes, Ordering::Relaxed);
        Ok(tensors)
    }
}

/// One dynamic (per-call) input slot of a [`PreparedParams`] set.
#[derive(Debug, Clone)]
struct DynSlot {
    slot: usize,
    name: String,
    shape: Vec<usize>,
    dtype: Dtype,
}

/// An artifact's persistent inputs frozen as XLA literals, plus everything
/// [`Runtime::execute_prepared`] needs to run without touching the
/// manifest or the executable cache: the resolved executable, the dynamic
/// slots' expected signatures, and the output signatures. Built by
/// [`Runtime::prepare`], shared across worker threads via `Arc`.
pub struct PreparedParams {
    artifact: String,
    generation: u64,
    exe: Arc<SharedExe>,
    /// slot-indexed: `Some` for prepared inputs, `None` for dynamic ones
    fixed: Vec<Option<PreparedLiteral>>,
    /// manifest-order signatures of the dynamic inputs
    dynamic: Vec<DynSlot>,
    /// (name, shape) per output, for validation without the manifest
    outputs: Vec<(String, Vec<usize>)>,
    fixed_bytes: usize,
}

impl PreparedParams {
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    /// The parameter-set generation these literals were converted from.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Host bytes frozen into cached literals — the conversion cost each
    /// `execute_prepared` call avoids.
    pub fn fixed_bytes(&self) -> usize {
        self.fixed_bytes
    }

    /// Number of per-call inputs [`Runtime::execute_prepared`] expects.
    pub fn dynamic_len(&self) -> usize {
        self.dynamic.len()
    }

    fn fixed_slots_match(&self, fixed: &[(usize, &HostTensor)]) -> bool {
        let n_fixed = self.fixed.iter().filter(|f| f.is_some()).count();
        n_fixed == fixed.len()
            && fixed
                .iter()
                .all(|(slot, _)| matches!(self.fixed.get(*slot), Some(Some(_))))
    }
}

impl std::fmt::Debug for PreparedParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedParams")
            .field("artifact", &self.artifact)
            .field("generation", &self.generation)
            .field("fixed_bytes", &self.fixed_bytes)
            .field("dynamic", &self.dynamic.len())
            .finish()
    }
}

/// Borrowed-or-owned input binding for [`Runtime::execute_bound`].
pub enum Bind<'a> {
    Ref(&'a HostTensor),
    Own(HostTensor),
}

impl Bind<'_> {
    pub fn tensor(&self) -> &HostTensor {
        match self {
            Bind::Ref(t) => t,
            Bind::Own(t) => t,
        }
    }
}

/// Named I/O helper: assemble the flat input vector of an artifact from a
/// name->tensor lookup, and index outputs by name.
pub struct IoBinder<'a> {
    spec: &'a ArtifactSpec,
}

impl<'a> IoBinder<'a> {
    pub fn new(spec: &'a ArtifactSpec) -> IoBinder<'a> {
        IoBinder { spec }
    }

    /// Build the input vector by calling `lookup` for each manifest input.
    pub fn bind<F>(&self, mut lookup: F) -> Result<Vec<HostTensor>>
    where
        F: FnMut(&IoSpec) -> Result<HostTensor>,
    {
        self.spec
            .inputs
            .iter()
            .map(|s| {
                let t = lookup(s)?;
                if t.shape != s.shape {
                    bail!("binding {}: shape {:?} != {:?}", s.name, t.shape, s.shape);
                }
                Ok(t)
            })
            .collect()
    }

    /// Extract a named output from the flat output vector.
    pub fn output<'b>(
        &self,
        outputs: &'b [HostTensor],
        name: &str,
    ) -> Result<&'b HostTensor> {
        Ok(&outputs[self.spec.output_index(name)?])
    }
}
