//! Phased, fault-tolerant fleet rounds.
//!
//! [`run_round`] rebuilds the fleet scheduler as an explicit epoch state
//! machine — Join → Warmup → Train → Collect → Cooldown — driven by a
//! single-threaded coordinator that owns ALL round state. Device workers
//! are plain threads speaking a two-channel protocol ([`Cmd`] down,
//! [`Event`] up); because no state is shared, a panicking job cannot
//! poison anything (the old `Mutex`-queue fleet died of exactly that).
//!
//! Robustness properties, each pinned by `tests/integration_rounds.rs`:
//!
//! - **panic isolation** — worker jobs run under `catch_unwind`; a panic
//!   becomes a `Finished { outcome: Err(..) }` event and a retry, never a
//!   coordinator crash or a poisoned lock.
//! - **retry with backoff** — failed attempts requeue up to
//!   [`RoundConfig::max_attempts`] times behind a seeded exponential
//!   backoff with jitter ([`backoff_ms`]), so a transient fault does not
//!   hot-loop and a hard fault terminates as a `Dropped` report.
//! - **straggler reassignment** — attempts running longer than
//!   [`RoundConfig::job_timeout_ms`] are re-dispatched to another
//!   admitting device; whichever attempt finishes first wins, late
//!   results are counted and discarded.
//! - **upload admission** — collected deltas pass
//!   `analysis::check_delta_value` / `check_delta_file` before
//!   acceptance; a corrupt or mismatched upload is rejected and the job
//!   retried.
//! - **quorum** — the round reports `quorum_met` over the admitted job
//!   set, so callers can distinguish "everything converged" from "we
//!   limped home with 60%".
//! - **resumability** — with a [`RoundConfig::delta_dir`], every accepted
//!   job is appended to a versioned JSONL journal next to the drained
//!   delta files; `resume: true` replays accepted work (digest-verified
//!   against the bytes on disk) and re-runs only the remainder,
//!   reproducing bit-identical delta bytes because job outputs are a pure
//!   function of `(job, seed)`, never of device or attempt.
//!
//! Fault injection ([`super::faults::FaultPlan`]) hooks the worker at
//! fixed points and is deterministic per seed, which is what makes the
//! chaos bench (`benches/fleet_faults.rs`) and the CI smoke job
//! reproducible. The default plan injects nothing and costs nothing.

use std::any::Any;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::{self, Finding, Severity};
use crate::edge::{Admission, DeviceProfile};
use crate::runtime::Manifest;
use crate::util::hash::{fnv1a64_hex, seed_with};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::vit::TaskDelta;

use super::faults::FaultPlan;
use super::fleet::{Job, JobReport, JobStatus};

/// Journal file name, created inside [`RoundConfig::delta_dir`].
pub const JOURNAL_FILE: &str = "round.journal";
/// Version stamped on every journal entry; readers reject anything else.
pub const JOURNAL_VERSION: usize = 1;

const MB: f64 = 1024.0 * 1024.0;

// ---------------------------------------------------------------------------
// Phases
// ---------------------------------------------------------------------------

/// The round state machine. Phases are strictly ordered; the coordinator
/// advances only at barriers, and fault injection addresses devices by the
/// phase they die in (`die=DEV@PHASE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundState {
    /// Devices spawn and report in; no-shows are dropped at the deadline.
    Join,
    /// Devices pre-resolve artifacts/executables for the round's
    /// strategies so Train measures training, not compilation.
    Warmup,
    /// Jobs dispatch, retry, and reassign until terminally accounted for.
    Train,
    /// Accepted deltas are integrity-checked and the quorum evaluated.
    Collect,
    /// Channels close; workers drain and exit.
    Cooldown,
}

impl RoundState {
    pub fn name(self) -> &'static str {
        match self {
            RoundState::Join => "join",
            RoundState::Warmup => "warmup",
            RoundState::Train => "train",
            RoundState::Collect => "collect",
            RoundState::Cooldown => "cooldown",
        }
    }

    pub fn parse(s: &str) -> Result<RoundState> {
        match s {
            "join" => Ok(RoundState::Join),
            "warmup" => Ok(RoundState::Warmup),
            "train" => Ok(RoundState::Train),
            "collect" => Ok(RoundState::Collect),
            "cooldown" => Ok(RoundState::Cooldown),
            _ => bail!(
                "unknown phase {s:?} (expected join|warmup|train|collect|\
                 cooldown)"
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Runner abstraction
// ---------------------------------------------------------------------------

/// What one completed job attempt hands back to the coordinator.
#[derive(Debug, Clone)]
pub struct RunOutput {
    pub top1: f64,
    pub top5: f64,
    pub trainable_frac: f64,
    pub sim_energy_j: f64,
    pub sim_step_ms: f64,
    /// The upload: a task delta over the shared backbone. Admission
    /// (`analysis::check_delta_*`) happens in the coordinator, not here.
    pub delta: TaskDelta,
}

/// The work the round engine schedules. The production implementation
/// (`Fleet::run_round`) wraps `FinetuneSession`; tests and the chaos bench
/// use [`SimRunner`], which needs no artifacts.
///
/// Determinism contract: `run` must be a pure function of `(job, seed)`
/// for the *delta* (device and attempt may only influence timing/energy
/// metrics). This is what makes `--resume` bit-identical: a replayed job
/// is never re-run, and a re-run job reproduces the same bytes.
pub trait JobRunner: Sync {
    /// Memory admission for `job` on `device` (no side effects).
    fn admit(&self, job: &Job, device: &'static DeviceProfile) -> Result<Admission>;

    /// Per-device phase work before training starts (compile caches,
    /// artifact resolution). Default: nothing.
    fn warmup(&self, _device: &'static DeviceProfile, _jobs: &[Job]) -> Result<()> {
        Ok(())
    }

    /// Run one attempt of `job` on `device`.
    fn run(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
        attempt: u32,
    ) -> Result<RunOutput>;

    /// Called once as the engine enters each phase, in order. Default:
    /// nothing. The networked runner uses this to broadcast phase frames
    /// to remote participants; in-process runners don't care.
    fn on_phase(&self, _phase: RoundState) {}
}

// ---------------------------------------------------------------------------
// Configuration / results
// ---------------------------------------------------------------------------

/// Synchronous journal-shipping hook: called with every serialized
/// journal line *after* it is locally durable and *before* the write
/// returns to the engine — so, with a hot standby attached, no accept is
/// acknowledged that the standby has not been offered. Must be
/// infallible outward: a dead standby detaches inside the hook, it never
/// fails the round. (`net/server.rs` provides the real implementation;
/// the engine stays transport-agnostic.)
#[derive(Clone)]
pub struct JournalShipper(pub Arc<dyn Fn(&str) + Send + Sync>);

impl std::fmt::Debug for JournalShipper {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("JournalShipper(..)")
    }
}

/// Round engine knobs. `..Default::default()` is the intended spelling for
/// overriding a few.
#[derive(Debug, Clone)]
pub struct RoundConfig {
    /// Seed for backoff jitter and the journal fingerprint (the same seed
    /// the runner derives job outputs from).
    pub seed: u64,
    /// Attempts per job before it is terminally `Dropped`.
    pub max_attempts: u32,
    /// Base retry backoff; attempt `n` waits `base * 2^(n-1) * jitter`.
    pub backoff_ms: u64,
    /// Straggler threshold per attempt; 0 disables reassignment.
    pub job_timeout_ms: u64,
    /// How long devices get to report in.
    pub join_deadline_ms: u64,
    /// How long warmup may take per device.
    pub warmup_deadline_ms: u64,
    /// Whole-Train-phase deadline; 0 disables. At the deadline every
    /// unfinished job is terminally dropped so the round still completes.
    pub train_deadline_ms: u64,
    /// Fraction of *admitted* jobs that must be accepted for
    /// `quorum_met` (1.0 = all).
    pub quorum: f64,
    /// Drain mode: save accepted deltas here (plus the journal) instead
    /// of holding them in report memory.
    pub delta_dir: Option<PathBuf>,
    /// Replay accepted work from an existing journal before running.
    pub resume: bool,
    /// Deterministic fault injection; default injects nothing.
    pub faults: FaultPlan,
    /// Cooperative shutdown flag (e.g. from `util::signal::install`). When
    /// it flips to true the Train loop stops dispatching, terminally drops
    /// every unfinished job with a "shutdown requested" note, and the round
    /// completes normally through Collect/Cooldown.
    pub stop: Option<Arc<AtomicBool>>,
    /// Live journal replication to a hot standby; `None` (the default)
    /// ships nothing. See [`JournalShipper`].
    pub shipper: Option<JournalShipper>,
}

impl Default for RoundConfig {
    fn default() -> Self {
        RoundConfig {
            seed: 42,
            max_attempts: 3,
            backoff_ms: 50,
            job_timeout_ms: 0,
            join_deadline_ms: 30_000,
            warmup_deadline_ms: 120_000,
            train_deadline_ms: 0,
            quorum: 1.0,
            delta_dir: None,
            resume: false,
            faults: FaultPlan::default(),
            stop: None,
            shipper: None,
        }
    }
}

/// Round-level accounting, beside the per-job reports.
#[derive(Debug, Clone, Default)]
pub struct RoundSummary {
    pub accepted: usize,
    pub not_admitted: usize,
    pub dropped: usize,
    /// Jobs restored from the journal instead of re-run.
    pub replayed: usize,
    pub retries: u64,
    pub reassigned: u64,
    pub rejected_uploads: u64,
    pub panics: u64,
    /// Finished attempts that arrived after their job was already
    /// terminal (straggler twins) — counted, then discarded.
    pub late_results: u64,
    pub quorum_met: bool,
    pub quorum_required: usize,
    pub joined_devices: Vec<String>,
    pub dead_devices: Vec<String>,
    pub phase_ms: Vec<(&'static str, f64)>,
    pub wall_ms: f64,
}

/// Everything a round produces: one report per job (every job terminally
/// accounted for) plus the summary.
#[derive(Debug)]
pub struct RoundReport {
    pub reports: Vec<JobReport>,
    pub summary: RoundSummary,
}

// ---------------------------------------------------------------------------
// Worker protocol
// ---------------------------------------------------------------------------

enum Cmd {
    Warmup,
    Run { job_id: usize, attempt: u32, job: Box<Job> },
}

enum Event {
    Joined {
        dev: &'static str,
    },
    Died {
        dev: &'static str,
        phase: RoundState,
    },
    Warmed {
        dev: &'static str,
        error: Option<String>,
    },
    Finished {
        dev: &'static str,
        job_id: usize,
        attempt: u32,
        wall_ms: f64,
        outcome: Result<Box<RunOutput>, String>,
    },
}

fn panic_message(p: &dyn Any) -> &str {
    if let Some(s) = p.downcast_ref::<String>() {
        s
    } else if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Device worker: join, then serve commands until the coordinator drops
/// our channel. All job execution is wrapped in `catch_unwind`, so a
/// panicking runner (or an injected fault) reports as a failed attempt
/// instead of killing the thread mid-protocol.
fn worker(
    profile: &'static DeviceProfile,
    jobs: &[Job],
    runner: &dyn JobRunner,
    faults: FaultPlan,
    rx: Receiver<Cmd>,
    tx: Sender<Event>,
) {
    let dev = profile.name;
    if faults.dies_at(dev, RoundState::Join) {
        let _ = tx.send(Event::Died { dev, phase: RoundState::Join });
        return;
    }
    let _ = tx.send(Event::Joined { dev });
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Warmup => {
                if faults.dies_at(dev, RoundState::Warmup) {
                    let _ =
                        tx.send(Event::Died { dev, phase: RoundState::Warmup });
                    return;
                }
                let res =
                    catch_unwind(AssertUnwindSafe(|| runner.warmup(profile, jobs)));
                let error = match res {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(format!("{e:#}")),
                    Err(p) => {
                        Some(format!("panicked: {}", panic_message(p.as_ref())))
                    }
                };
                let _ = tx.send(Event::Warmed { dev, error });
            }
            Cmd::Run { job_id, attempt, job } => {
                if faults.dies_at(dev, RoundState::Train) {
                    let _ =
                        tx.send(Event::Died { dev, phase: RoundState::Train });
                    return;
                }
                let stall = faults.stall_ms(dev);
                if stall > 0 {
                    std::thread::sleep(Duration::from_millis(stall));
                }
                let t0 = Instant::now();
                let res = catch_unwind(AssertUnwindSafe(|| {
                    if faults.panics(job_id, attempt) {
                        std::panic::panic_any(format!(
                            "injected fault (job {job_id}, attempt {attempt})"
                        ));
                    }
                    runner.run(&job, profile, attempt)
                }));
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                let outcome = match res {
                    Ok(Ok(out)) => Ok(Box::new(out)),
                    Ok(Err(e)) => Err(format!("{e:#}")),
                    Err(p) => {
                        Err(format!("panicked: {}", panic_message(p.as_ref())))
                    }
                };
                let _ = tx.send(Event::Finished {
                    dev,
                    job_id,
                    attempt,
                    wall_ms,
                    outcome,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator state
// ---------------------------------------------------------------------------

struct Inflight {
    dev: &'static str,
    attempt: u32,
    started: Instant,
    timed_out: bool,
}

struct JobSlot {
    job: Job,
    /// Wants (re)dispatch.
    queued: bool,
    attempts: u32,
    /// Backoff gate: no dispatch before this instant.
    not_before: Option<Instant>,
    /// Attempts currently running (more than one after reassignment).
    inflight: Vec<Inflight>,
    last_error: Option<String>,
    last_device: Option<&'static str>,
    /// Terminal outcome; the loop runs until every slot has one.
    report: Option<JobReport>,
}

#[derive(PartialEq)]
enum DevState {
    Spawned,
    Joined,
    Warmed,
    /// Worker thread exited (injected death or closed channel).
    Dead,
    /// Administratively excluded (missed a barrier, failed warmup).
    Dropped,
}

struct DevSlot {
    profile: &'static DeviceProfile,
    tx: Option<Sender<Cmd>>,
    state: DevState,
    busy: Option<usize>,
}

fn end_phase(summary: &mut RoundSummary, t0: &mut Instant, name: &'static str) {
    let now = Instant::now();
    summary
        .phase_ms
        .push((name, now.duration_since(*t0).as_secs_f64() * 1e3));
    *t0 = now;
}

/// The `killprimary@PHASE` fault: the coordinator "dies" entering the
/// phase — the engine bails mid-round with no summary entry, exactly the
/// journal shape a kill -9 leaves behind. A hot standby is expected to
/// detect the lease expiry and promote.
fn kill_primary_check(cfg: &RoundConfig, phase: RoundState) -> Result<()> {
    if cfg.faults.kills_primary_at(phase) {
        bail!(
            "fault injection: primary coordinator killed entering {}",
            phase.name()
        );
    }
    Ok(())
}

fn phase_entry(journal: &mut Journal, name: &'static str, ms: f64) -> Result<()> {
    journal.entry(Json::obj(vec![
        ("v", JOURNAL_VERSION.into()),
        ("kind", "phase".into()),
        ("phase", name.into()),
        ("ms", ms.into()),
    ]))
}

/// Seeded exponential backoff with jitter in `[0.5, 1.5)` so retried jobs
/// don't stampede — deterministic per `(seed, label, attempt)`. Public so
/// remote participants' reconnect loops share the same backoff law as the
/// in-round retry path.
pub fn seeded_backoff_ms(seed: u64, base_ms: u64, label: &str, attempt: u32) -> u64 {
    let base = base_ms.max(1);
    let exp = base.saturating_mul(1u64 << attempt.min(6).saturating_sub(1));
    let label = format!("backoff:{label}:{attempt}");
    let jitter = 0.5 + Rng::new(seed_with(seed, &label)).uniform();
    (exp as f64 * jitter) as u64
}

fn backoff_ms(cfg: &RoundConfig, job_id: usize, attempt: u32) -> u64 {
    seeded_backoff_ms(cfg.seed, cfg.backoff_ms, &job_id.to_string(), attempt)
}

fn retry_or_drop(
    job_id: usize,
    s: &mut JobSlot,
    cfg: &RoundConfig,
    summary: &mut RoundSummary,
    journal: &mut Journal,
) -> Result<()> {
    if s.attempts < cfg.max_attempts {
        s.queued = true;
        s.not_before = Some(
            Instant::now()
                + Duration::from_millis(backoff_ms(cfg, job_id, s.attempts)),
        );
        summary.retries += 1;
    } else if s.inflight.is_empty() {
        // retries exhausted and no straggler twin still running
        drop_terminal(job_id, s, "retries exhausted", journal)?;
    }
    Ok(())
}

fn drop_terminal(
    job_id: usize,
    s: &mut JobSlot,
    reason: &str,
    journal: &mut Journal,
) -> Result<()> {
    let why = match &s.last_error {
        Some(e) => format!("{reason}: {e}"),
        None => reason.to_string(),
    };
    journal.entry(Json::obj(vec![
        ("v", JOURNAL_VERSION.into()),
        ("kind", "drop".into()),
        ("job", job_id.into()),
        ("reason", why.as_str().into()),
    ]))?;
    s.queued = false;
    s.report = Some(terminal_report(
        &s.job,
        s.last_device.unwrap_or("-"),
        JobStatus::Dropped,
        s.attempts,
        Some(why),
        f64::NAN,
    ));
    Ok(())
}

/// A report for a job that never produced accepted output.
fn terminal_report(
    job: &Job,
    device: &str,
    status: JobStatus,
    attempts: u32,
    error: Option<String>,
    required_mb: f64,
) -> JobReport {
    JobReport {
        task: job.task.name.to_string(),
        strategy: job.strategy.name(),
        device: device.to_string(),
        admitted: false,
        required_mb,
        top1: f64::NAN,
        top5: f64::NAN,
        trainable_frac: f64::NAN,
        wall_ms: 0.0,
        sim_energy_j: f64::NAN,
        sim_step_ms: f64::NAN,
        delta: None,
        delta_bytes: 0,
        status,
        attempts,
        error,
        delta_path: None,
        delta_digest: None,
    }
}

// ---------------------------------------------------------------------------
// Upload acceptance
// ---------------------------------------------------------------------------

/// Context for accepting one finished attempt (bundled so the hot recv
/// path stays readable).
struct Accept<'a> {
    job_id: usize,
    attempt: u32,
    job: &'a Job,
    device: &'static str,
    required_mb: f64,
    wall_ms: f64,
    attempts: u32,
}

fn first_error(findings: &[Finding]) -> String {
    findings
        .iter()
        .find(|f| f.severity == Severity::Error)
        .map(|f| format!("{} [{}]: {}", f.code, f.span, f.message))
        .unwrap_or_else(|| "delta admission failed".to_string())
}

/// `job007_syn-pets_taskedge-k2.tedl` — non-alphanumerics sanitized so the
/// name is portable and journal-safe.
fn delta_file_name(job_id: usize, task: &str, strategy: &str) -> String {
    let clean = |s: &str| -> String {
        s.chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
            .collect()
    };
    format!("job{job_id:03}_{}_{}.tedl", clean(task), clean(strategy))
}

/// Admit one finished attempt: validate the delta against the manifest
/// (and, in drain mode, persist it and digest the bytes). `Err` is a
/// *rejection* — the coordinator retries the job.
fn accept_upload(
    manifest: &Manifest,
    cfg: &RoundConfig,
    a: Accept<'_>,
    mut output: RunOutput,
) -> Result<JobReport, String> {
    let corrupt = cfg.faults.corrupts(a.job_id, a.attempt);
    let task = a.job.task.name;

    let (delta, delta_bytes, delta_path, delta_digest) = match &cfg.delta_dir {
        Some(dir) => {
            // Drain mode: persist first, then admit the *file* — exactly
            // what a remote collector holding untrusted bytes would do.
            let name =
                delta_file_name(a.job_id, task, &a.job.strategy.name());
            let tmp = dir.join(format!("{name}.tmp"));
            let fin = dir.join(&name);
            if let Err(e) = output.delta.save(&tmp) {
                return Err(format!("saving delta: {e:#}"));
            }
            if corrupt {
                corrupt_file(&tmp)?;
            }
            let findings = analysis::check_delta_file(manifest, task, &tmp);
            if analysis::has_errors(&findings) {
                let _ = std::fs::remove_file(&tmp);
                return Err(first_error(&findings));
            }
            let bytes = std::fs::read(&tmp)
                .map_err(|e| format!("reading back delta: {e}"))?;
            std::fs::rename(&tmp, &fin)
                .map_err(|e| format!("publishing delta: {e}"))?;
            let digest = fnv1a64_hex(&bytes);
            (None, bytes.len(), Some(fin), Some(digest))
        }
        None => {
            if corrupt {
                // In-memory equivalent of a corrupted upload: the delta
                // no longer names a config the manifest defines.
                output.delta.config_name.push('!');
            }
            let findings =
                analysis::check_delta_value(manifest, task, &output.delta);
            if analysis::has_errors(&findings) {
                return Err(first_error(&findings));
            }
            let bytes = output.delta.file_bytes();
            (Some(output.delta), bytes, None, None)
        }
    };

    Ok(JobReport {
        task: task.to_string(),
        strategy: a.job.strategy.name(),
        device: a.device.to_string(),
        admitted: true,
        required_mb: a.required_mb,
        top1: output.top1,
        top5: output.top5,
        trainable_frac: output.trainable_frac,
        wall_ms: a.wall_ms,
        sim_energy_j: output.sim_energy_j,
        sim_step_ms: output.sim_step_ms,
        delta,
        delta_bytes,
        status: JobStatus::Accepted,
        attempts: a.attempts,
        error: None,
        delta_path,
        delta_digest,
    })
}

/// Flip the magic byte so `TaskDelta::load` deterministically rejects the
/// file (a mid-file flip could land in a value and slip past admission).
fn corrupt_file(path: &Path) -> Result<(), String> {
    let mut bytes =
        std::fs::read(path).map_err(|e| format!("corrupting delta: {e}"))?;
    if let Some(b) = bytes.first_mut() {
        *b ^= 0xff;
    }
    std::fs::write(path, &bytes).map_err(|e| format!("corrupting delta: {e}"))
}

// ---------------------------------------------------------------------------
// Journal
// ---------------------------------------------------------------------------

/// Append-only JSONL journal, flushed per entry and fsynced for entries
/// that record durable outcomes. Lives in the delta dir; when no delta
/// dir is configured the journal is a no-op.
struct Journal {
    w: Option<std::io::BufWriter<std::fs::File>>,
    shipper: Option<JournalShipper>,
}

/// Entry kinds that must survive power loss, not just a process crash:
/// identity (`header`/`resume`), terminal job outcomes, and round
/// closure. Progress markers (phase/assign/fail/straggle/...) are
/// flush-only — losing one degrades to re-running work, never to
/// trusting a stale record, so they don't each pay an fsync.
fn durable_kind(kind: &str) -> bool {
    matches!(
        kind,
        "header" | "resume" | "accept" | "drop" | "not_admitted" | "collect"
            | "summary"
    )
}

impl Journal {
    fn disabled() -> Journal {
        Journal { w: None, shipper: None }
    }

    fn open(path: &Path, shipper: Option<JournalShipper>) -> Result<Journal> {
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal { w: Some(std::io::BufWriter::new(f)), shipper })
    }

    /// Append one entry. Ordering contract: the line is (1) written and
    /// flushed, (2) fsynced when its kind records a durable outcome, and
    /// only then (3) shipped to an attached standby — all before this
    /// returns. An accept the engine proceeds past is therefore on local
    /// disk *and* offered to the standby first.
    fn entry(&mut self, j: Json) -> Result<()> {
        let line = j.to_string();
        if let Some(w) = &mut self.w {
            use std::io::Write;
            writeln!(w, "{line}").context("journal write")?;
            w.flush().context("journal flush")?;
            if j.get("kind").and_then(Json::as_str).is_some_and(durable_kind)
            {
                w.get_ref().sync_all().context("journal fsync")?;
            }
        }
        if let Some(s) = &self.shipper {
            (s.0)(&line);
        }
        Ok(())
    }
}

fn opt_str(o: &Option<String>) -> Json {
    match o {
        Some(s) => Json::Str(s.clone()),
        None => Json::Null,
    }
}

/// Serialize a report for the journal. Floats survive bit-exactly (the
/// JSON substrate prints shortest-round-trip and maps non-finite to
/// null); the delta itself is NOT stored — drain mode keeps it as a file
/// whose digest is recorded here.
fn report_to_json(r: &JobReport) -> Json {
    let file = r
        .delta_path
        .as_ref()
        .and_then(|p| p.file_name())
        .map(|n| n.to_string_lossy().to_string());
    Json::obj(vec![
        ("task", r.task.as_str().into()),
        ("strategy", r.strategy.as_str().into()),
        ("device", r.device.as_str().into()),
        ("admitted", r.admitted.into()),
        ("required_mb", r.required_mb.into()),
        ("top1", r.top1.into()),
        ("top5", r.top5.into()),
        ("trainable_frac", r.trainable_frac.into()),
        ("wall_ms", r.wall_ms.into()),
        ("sim_energy_j", r.sim_energy_j.into()),
        ("sim_step_ms", r.sim_step_ms.into()),
        ("delta_bytes", r.delta_bytes.into()),
        ("status", r.status.name().into()),
        ("attempts", (r.attempts as usize).into()),
        ("error", opt_str(&r.error)),
        ("delta_file", opt_str(&file)),
        ("delta_digest", opt_str(&r.delta_digest)),
    ])
}

fn report_from_json(j: &Json, delta_dir: &Path) -> Result<JobReport> {
    let s = |k: &str| -> Result<String> {
        Ok(j.req(k)?.as_str().with_context(|| k.to_string())?.to_string())
    };
    let f = |k: &str| -> f64 {
        j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
    };
    let os = |k: &str| -> Option<String> {
        j.get(k).and_then(Json::as_str).map(String::from)
    };
    let file = os("delta_file");
    Ok(JobReport {
        task: s("task")?,
        strategy: s("strategy")?,
        device: s("device")?,
        admitted: j.req("admitted")?.as_bool().context("admitted")?,
        required_mb: f("required_mb"),
        top1: f("top1"),
        top5: f("top5"),
        trainable_frac: f("trainable_frac"),
        wall_ms: f("wall_ms"),
        sim_energy_j: f("sim_energy_j"),
        sim_step_ms: f("sim_step_ms"),
        delta_bytes: j.req("delta_bytes")?.as_usize().context("delta_bytes")?,
        status: JobStatus::parse(&s("status")?)?,
        attempts: j.req("attempts")?.as_usize().context("attempts")? as u32,
        error: os("error"),
        delta: None,
        delta_path: file.as_ref().map(|n| delta_dir.join(n)),
        delta_digest: os("delta_digest"),
    })
}

fn header_json(
    cfg: &RoundConfig,
    devices: &[&'static DeviceProfile],
    jobs: &[Job],
) -> Json {
    Json::obj(vec![
        ("v", JOURNAL_VERSION.into()),
        ("kind", "header".into()),
        // u64 seeds don't survive an f64 round trip; store as string
        ("seed", cfg.seed.to_string().into()),
        ("quorum", cfg.quorum.into()),
        ("max_attempts", (cfg.max_attempts as usize).into()),
        ("faults", cfg.faults.summary().into()),
        (
            "devices",
            Json::Arr(
                devices.iter().map(|d| Json::Str(d.name.to_string())).collect(),
            ),
        ),
        (
            "jobs",
            Json::Arr(
                jobs.iter()
                    .map(|jb| {
                        Json::obj(vec![
                            ("task", jb.task.name.into()),
                            ("strategy", jb.strategy.name().into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Replay accepted work from an existing journal. The header must
/// fingerprint-match the resumed invocation (same seed, same ordered job
/// list); accepted entries whose delta file is missing or whose digest
/// disagrees are silently skipped so those jobs simply re-run. A torn
/// final line (the crash that motivated resume) ends the replay cleanly.
fn replay_journal(
    path: &Path,
    delta_dir: &Path,
    cfg: &RoundConfig,
    jobs: &[Job],
) -> Result<BTreeMap<usize, JobReport>> {
    let text = std::fs::read_to_string(path).with_context(|| {
        format!("--resume: cannot read journal {}", path.display())
    })?;
    let mut lines = text.lines();
    let header_line = lines.next().context("--resume: journal is empty")?;
    let header = Json::parse(header_line)
        .map_err(|e| anyhow!("--resume: journal header unreadable: {e}"))?;
    if header.get("kind").and_then(Json::as_str) != Some("header") {
        bail!("--resume: journal does not start with a header entry");
    }
    let v = header.req("v")?.as_usize().context("journal version")?;
    if v != JOURNAL_VERSION {
        bail!("--resume: journal version {v}, this build reads {JOURNAL_VERSION}");
    }
    let seed = header.req("seed")?.as_str().context("journal seed")?;
    if seed != cfg.seed.to_string() {
        bail!(
            "--resume: journal was written with seed {seed}, this run uses \
             {} — resuming would mix incompatible outputs",
            cfg.seed
        );
    }
    let recorded = header.req("jobs")?.as_arr().context("journal jobs")?;
    if recorded.len() != jobs.len() {
        bail!(
            "--resume: journal lists {} job(s), this run has {}",
            recorded.len(),
            jobs.len()
        );
    }
    for (i, (rec, job)) in recorded.iter().zip(jobs).enumerate() {
        let task = rec.get("task").and_then(Json::as_str).unwrap_or("");
        let strat = rec.get("strategy").and_then(Json::as_str).unwrap_or("");
        if task != job.task.name || strat != job.strategy.name() {
            bail!(
                "--resume: job {i} is {}/{} in the journal but {}/{} in this \
                 run — the job list must match exactly",
                task,
                strat,
                job.task.name,
                job.strategy.name()
            );
        }
    }

    let mut restored = BTreeMap::new();
    for line in lines {
        let Ok(j) = Json::parse(line) else {
            break; // torn tail: the write this journal died in
        };
        if j.get("kind").and_then(Json::as_str) != Some("accept") {
            continue;
        }
        let Some(id) = j.get("job").and_then(Json::as_usize) else {
            continue;
        };
        if id >= jobs.len() {
            continue;
        }
        let Some(rep) = j.get("report") else { continue };
        let Ok(r) = report_from_json(rep, delta_dir) else {
            continue;
        };
        // prove the bytes on disk are the bytes that were accepted
        if let (Some(p), Some(want)) = (&r.delta_path, &r.delta_digest) {
            match std::fs::read(p) {
                Ok(bytes) if &fnv1a64_hex(&bytes) == want => {}
                _ => continue, // missing/edited file: job re-runs
            }
        }
        restored.insert(id, r);
    }
    Ok(restored)
}

// ---------------------------------------------------------------------------
// The round engine
// ---------------------------------------------------------------------------

/// Run one fleet round through the full phase machine. Every job in
/// `jobs` is terminally accounted for in the returned reports
/// (`Accepted`, `NotAdmitted`, or `Dropped`) — faults degrade the round,
/// they never abort it. Hard errors are reserved for the coordinator's
/// own invariants (journal I/O, no device surviving Join/Warmup,
/// collected bytes failing their digest).
pub fn run_round(
    manifest: &Manifest,
    devices: &[&'static DeviceProfile],
    jobs: &[Job],
    runner: &dyn JobRunner,
    cfg: &RoundConfig,
) -> Result<RoundReport> {
    if !(0.0..=1.0).contains(&cfg.quorum) {
        bail!("quorum must be in [0, 1], got {}", cfg.quorum);
    }
    if devices.is_empty() {
        bail!("round needs at least one device");
    }
    if cfg.max_attempts == 0 {
        bail!("max_attempts must be >= 1");
    }

    let wall_t0 = Instant::now();
    let mut summary = RoundSummary::default();
    let mut journal = Journal::disabled();
    let mut restored: BTreeMap<usize, JobReport> = BTreeMap::new();

    if let Some(dir) = &cfg.delta_dir {
        std::fs::create_dir_all(dir).with_context(|| {
            format!("creating delta dir {}", dir.display())
        })?;
        let path = dir.join(JOURNAL_FILE);
        if cfg.resume {
            restored = replay_journal(&path, dir, cfg, jobs)?;
            summary.replayed = restored.len();
            journal = Journal::open(&path, cfg.shipper.clone())?;
            journal.entry(Json::obj(vec![
                ("v", JOURNAL_VERSION.into()),
                ("kind", "resume".into()),
                ("replayed", summary.replayed.into()),
            ]))?;
        } else {
            if path.exists() {
                bail!(
                    "journal {} already exists — pass --resume to continue \
                     it, or point --delta-dir at a fresh directory",
                    path.display()
                );
            }
            journal = Journal::open(&path, cfg.shipper.clone())?;
            journal.entry(header_json(cfg, devices, jobs))?;
        }
    } else if cfg.resume {
        bail!("--resume requires --delta-dir (the journal lives beside the drained deltas)");
    }

    let mut slots: Vec<JobSlot> = jobs
        .iter()
        .enumerate()
        .map(|(i, job)| {
            let report = restored.remove(&i);
            JobSlot {
                job: job.clone(),
                queued: report.is_none(),
                attempts: report.as_ref().map_or(0, |r| r.attempts),
                not_before: None,
                inflight: Vec::new(),
                last_error: None,
                last_device: None,
                report,
            }
        })
        .collect();

    let mut phase_t0 = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let (tx_ev, rx_ev) = channel::<Event>();
        let mut devs: Vec<DevSlot> = Vec::with_capacity(devices.len());
        for &profile in devices {
            let (tx_cmd, rx_cmd) = channel::<Cmd>();
            let tx_ev = tx_ev.clone();
            let faults = cfg.faults.clone();
            scope.spawn(move || worker(profile, jobs, runner, faults, rx_cmd, tx_ev));
            devs.push(DevSlot {
                profile,
                tx: Some(tx_cmd),
                state: DevState::Spawned,
                busy: None,
            });
        }
        drop(tx_ev);
        let dev_index = |devs: &[DevSlot], name: &str| -> Option<usize> {
            devs.iter().position(|d| d.profile.name == name)
        };

        // ---- Join -------------------------------------------------------
        kill_primary_check(cfg, RoundState::Join)?;
        runner.on_phase(RoundState::Join);
        let join_deadline =
            Instant::now() + Duration::from_millis(cfg.join_deadline_ms.max(1));
        let mut outstanding = devs.len();
        while outstanding > 0 {
            let now = Instant::now();
            if now >= join_deadline {
                break;
            }
            match rx_ev.recv_timeout(join_deadline - now) {
                Ok(Event::Joined { dev }) => {
                    if let Some(i) = dev_index(&devs, dev) {
                        devs[i].state = DevState::Joined;
                    }
                    outstanding -= 1;
                }
                Ok(Event::Died { dev, .. }) => {
                    if let Some(i) = dev_index(&devs, dev) {
                        devs[i].state = DevState::Dead;
                        devs[i].tx = None;
                    }
                    summary.dead_devices.push(dev.to_string());
                    outstanding -= 1;
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        for d in devs.iter_mut() {
            if d.state == DevState::Spawned {
                crate::info!(
                    "[round] device {} missed the join deadline; dropped",
                    d.profile.name
                );
                d.state = DevState::Dropped;
                d.tx = None;
            }
        }
        summary.joined_devices = devs
            .iter()
            .filter(|d| d.state == DevState::Joined)
            .map(|d| d.profile.name.to_string())
            .collect();
        if summary.joined_devices.is_empty() {
            bail!("no device joined the round within {} ms", cfg.join_deadline_ms);
        }
        end_phase(&mut summary, &mut phase_t0, "join");
        if let Some((name, ms)) = summary.phase_ms.last().copied() {
            phase_entry(&mut journal, name, ms)?;
        }

        // ---- Warmup -----------------------------------------------------
        kill_primary_check(cfg, RoundState::Warmup)?;
        runner.on_phase(RoundState::Warmup);
        let mut waiting = 0usize;
        for d in devs.iter_mut() {
            if d.state != DevState::Joined {
                continue;
            }
            let ok = d.tx.as_ref().is_some_and(|tx| tx.send(Cmd::Warmup).is_ok());
            if ok {
                waiting += 1;
            } else {
                d.state = DevState::Dead;
                d.tx = None;
                summary.dead_devices.push(d.profile.name.to_string());
            }
        }
        let warm_deadline = Instant::now()
            + Duration::from_millis(cfg.warmup_deadline_ms.max(1));
        while waiting > 0 {
            let now = Instant::now();
            if now >= warm_deadline {
                break;
            }
            match rx_ev.recv_timeout(warm_deadline - now) {
                Ok(Event::Warmed { dev, error }) => {
                    if let Some(i) = dev_index(&devs, dev) {
                        match error {
                            None => devs[i].state = DevState::Warmed,
                            Some(e) => {
                                crate::info!(
                                    "[round] device {dev} failed warmup: {e}"
                                );
                                devs[i].state = DevState::Dropped;
                                devs[i].tx = None;
                            }
                        }
                    }
                    waiting -= 1;
                }
                Ok(Event::Died { dev, .. }) => {
                    if let Some(i) = dev_index(&devs, dev) {
                        devs[i].state = DevState::Dead;
                        devs[i].tx = None;
                    }
                    summary.dead_devices.push(dev.to_string());
                    waiting -= 1;
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        for d in devs.iter_mut() {
            if d.state == DevState::Joined {
                crate::info!(
                    "[round] device {} missed the warmup deadline; dropped",
                    d.profile.name
                );
                d.state = DevState::Dropped;
                d.tx = None;
            }
        }
        if !devs.iter().any(|d| d.state == DevState::Warmed) {
            bail!("no device survived warmup");
        }
        end_phase(&mut summary, &mut phase_t0, "warmup");
        if let Some((name, ms)) = summary.phase_ms.last().copied() {
            phase_entry(&mut journal, name, ms)?;
        }

        // ---- Pre-train admission ---------------------------------------
        // One probe per (job, warmed device); results are reused by the
        // dispatch loop so a retry never re-runs admission.
        let mut admissions: Vec<Vec<Option<Admission>>> =
            vec![vec![None; devs.len()]; slots.len()];
        let mut admit_errors: Vec<Option<String>> = vec![None; slots.len()];
        for (j, s) in slots.iter().enumerate() {
            if s.report.is_some() {
                continue;
            }
            for (di, d) in devs.iter().enumerate() {
                if d.state != DevState::Warmed {
                    continue;
                }
                match runner.admit(&s.job, d.profile) {
                    Ok(a) => admissions[j][di] = Some(a),
                    Err(e) => admit_errors[j] = Some(format!("{e:#}")),
                }
            }
        }
        for (j, s) in slots.iter_mut().enumerate() {
            if s.report.is_some() || admissions[j].iter().flatten().any(|a| a.fits)
            {
                continue;
            }
            let required_mb = admissions[j]
                .iter()
                .flatten()
                .next()
                .map_or(f64::NAN, |a| a.required_bytes as f64 / MB);
            let why = admit_errors[j]
                .clone()
                .unwrap_or_else(|| "no device admits this job".to_string());
            journal.entry(Json::obj(vec![
                ("v", JOURNAL_VERSION.into()),
                ("kind", "not_admitted".into()),
                ("job", j.into()),
                ("reason", why.as_str().into()),
            ]))?;
            s.queued = false;
            s.report = Some(terminal_report(
                &s.job,
                "-",
                JobStatus::NotAdmitted,
                0,
                Some(why),
                required_mb,
            ));
        }

        // ---- Train ------------------------------------------------------
        kill_primary_check(cfg, RoundState::Train)?;
        runner.on_phase(RoundState::Train);
        let train_deadline = (cfg.train_deadline_ms > 0).then(|| {
            Instant::now() + Duration::from_millis(cfg.train_deadline_ms)
        });
        loop {
            if slots.iter().all(|s| s.report.is_some()) {
                break;
            }
            // cooperative shutdown: stop dispatching, account every
            // unfinished job, and let the round complete through
            // Collect/Cooldown so the journal stays coherent
            if cfg.stop.as_ref().is_some_and(|f| f.load(Ordering::SeqCst)) {
                for (j, s) in slots.iter_mut().enumerate() {
                    if s.report.is_none() {
                        drop_terminal(j, s, "shutdown requested", &mut journal)?;
                    }
                }
                break;
            }
            let now = Instant::now();

            if let Some(dl) = train_deadline {
                if now >= dl {
                    for (j, s) in slots.iter_mut().enumerate() {
                        if s.report.is_none() {
                            drop_terminal(
                                j,
                                s,
                                "round deadline exceeded",
                                &mut journal,
                            )?;
                        }
                    }
                    break;
                }
            }

            // straggler scan: attempts over the timeout are re-dispatched
            // to another device; the slow attempt keeps running and its
            // late result is discarded
            if cfg.job_timeout_ms > 0 {
                for (j, s) in slots.iter_mut().enumerate() {
                    if s.report.is_some() {
                        continue;
                    }
                    let mut straggling = None;
                    for fl in s.inflight.iter_mut() {
                        let ms =
                            now.duration_since(fl.started).as_millis() as u64;
                        if !fl.timed_out && ms >= cfg.job_timeout_ms {
                            fl.timed_out = true;
                            straggling = Some(fl.dev);
                        }
                    }
                    if let Some(dev) = straggling {
                        if !s.queued && s.attempts < cfg.max_attempts {
                            s.queued = true;
                            s.not_before = None;
                            summary.reassigned += 1;
                            journal.entry(Json::obj(vec![
                                ("v", JOURNAL_VERSION.into()),
                                ("kind", "straggle".into()),
                                ("job", j.into()),
                                ("device", dev.into()),
                            ]))?;
                        }
                    }
                }
            }

            // dispatch: each idle warmed device takes the first eligible job
            for (di, d) in devs.iter_mut().enumerate() {
                if d.state != DevState::Warmed || d.busy.is_some() {
                    continue;
                }
                let dev_name = d.profile.name;
                let pick = slots.iter().enumerate().position(|(j, s)| {
                    s.report.is_none()
                        && s.queued
                        && s.not_before.map_or(true, |t| now >= t)
                        && !s.inflight.iter().any(|f| f.dev == dev_name)
                        && admissions[j][di].as_ref().is_some_and(|a| a.fits)
                });
                let Some(j) = pick else { continue };
                let s = &mut slots[j];
                s.attempts += 1;
                let attempt = s.attempts;
                let sent = d.tx.as_ref().is_some_and(|tx| {
                    tx.send(Cmd::Run {
                        job_id: j,
                        attempt,
                        job: Box::new(s.job.clone()),
                    })
                    .is_ok()
                });
                if sent {
                    s.queued = false;
                    s.not_before = None;
                    s.inflight.push(Inflight {
                        dev: dev_name,
                        attempt,
                        started: now,
                        timed_out: false,
                    });
                    s.last_device = Some(dev_name);
                    d.busy = Some(j);
                    journal.entry(Json::obj(vec![
                        ("v", JOURNAL_VERSION.into()),
                        ("kind", "assign".into()),
                        ("job", j.into()),
                        ("attempt", (attempt as usize).into()),
                        ("device", dev_name.into()),
                    ]))?;
                } else {
                    s.attempts -= 1;
                    d.state = DevState::Dead;
                    d.tx = None;
                    summary.dead_devices.push(dev_name.to_string());
                }
            }

            // unrunnable sweep: a queued job with no attempt in flight and
            // no surviving device that admits it can never finish
            for (j, s) in slots.iter_mut().enumerate() {
                if s.report.is_some() || !s.queued || !s.inflight.is_empty() {
                    continue;
                }
                let runnable = devs.iter().enumerate().any(|(di, d)| {
                    d.state == DevState::Warmed
                        && admissions[j][di].as_ref().is_some_and(|a| a.fits)
                });
                if !runnable {
                    drop_terminal(
                        j,
                        s,
                        "no admitting device remains",
                        &mut journal,
                    )?;
                }
            }
            if slots.iter().all(|s| s.report.is_some()) {
                break;
            }

            // sleep until the next actionable instant (backoff expiry,
            // straggler deadline, round deadline) or the next event
            let mut wake = train_deadline;
            for s in &slots {
                if s.report.is_some() {
                    continue;
                }
                if s.queued {
                    if let Some(t) = s.not_before {
                        wake = Some(wake.map_or(t, |w| w.min(t)));
                    }
                }
                if cfg.job_timeout_ms > 0 {
                    for fl in &s.inflight {
                        if !fl.timed_out {
                            let t = fl.started
                                + Duration::from_millis(cfg.job_timeout_ms);
                            wake = Some(wake.map_or(t, |w| w.min(t)));
                        }
                    }
                }
            }
            let mut wait = wake
                .map_or(Duration::from_secs(60), |w| {
                    w.saturating_duration_since(now)
                })
                .max(Duration::from_millis(1));
            // with a stop flag installed, poll it often enough that a
            // signal drains the round promptly instead of after the next
            // event
            if cfg.stop.is_some() {
                wait = wait.min(Duration::from_millis(200));
            }

            match rx_ev.recv_timeout(wait) {
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    for (j, s) in slots.iter_mut().enumerate() {
                        if s.report.is_none() {
                            drop_terminal(
                                j,
                                s,
                                "device pool disconnected",
                                &mut journal,
                            )?;
                        }
                    }
                    break;
                }
                Ok(Event::Died { dev, phase }) => {
                    summary.dead_devices.push(dev.to_string());
                    let Some(di) = dev_index(&devs, dev) else { continue };
                    devs[di].state = DevState::Dead;
                    devs[di].tx = None;
                    if let Some(j) = devs[di].busy.take() {
                        let s = &mut slots[j];
                        s.inflight.retain(|f| f.dev != dev);
                        if s.report.is_none() && !s.queued {
                            s.queued = true;
                            s.not_before = None;
                            summary.reassigned += 1;
                            journal.entry(Json::obj(vec![
                                ("v", JOURNAL_VERSION.into()),
                                ("kind", "death".into()),
                                ("device", dev.into()),
                                ("phase", phase.name().into()),
                                ("job", j.into()),
                            ]))?;
                        }
                    }
                }
                Ok(Event::Finished { dev, job_id, attempt, wall_ms, outcome }) => {
                    let di = dev_index(&devs, dev);
                    if let Some(di) = di {
                        if devs[di].busy == Some(job_id) {
                            devs[di].busy = None;
                        }
                    }
                    let s = &mut slots[job_id];
                    s.inflight
                        .retain(|f| !(f.dev == dev && f.attempt == attempt));
                    if s.report.is_some() {
                        summary.late_results += 1;
                        continue;
                    }
                    match outcome {
                        Err(msg) => {
                            if msg.starts_with("panicked") {
                                summary.panics += 1;
                            }
                            journal.entry(Json::obj(vec![
                                ("v", JOURNAL_VERSION.into()),
                                ("kind", "fail".into()),
                                ("job", job_id.into()),
                                ("attempt", (attempt as usize).into()),
                                ("device", dev.into()),
                                ("error", msg.as_str().into()),
                            ]))?;
                            s.last_error = Some(msg);
                            retry_or_drop(
                                job_id,
                                s,
                                cfg,
                                &mut summary,
                                &mut journal,
                            )?;
                        }
                        Ok(out) => {
                            let required_mb = di
                                .and_then(|di| admissions[job_id][di].as_ref())
                                .map_or(f64::NAN, |a| {
                                    a.required_bytes as f64 / MB
                                });
                            let acc = Accept {
                                job_id,
                                attempt,
                                job: &s.job,
                                device: dev,
                                required_mb,
                                wall_ms,
                                attempts: s.attempts,
                            };
                            match accept_upload(manifest, cfg, acc, *out) {
                                Ok(report) => {
                                    journal.entry(Json::obj(vec![
                                        ("v", JOURNAL_VERSION.into()),
                                        ("kind", "accept".into()),
                                        ("job", job_id.into()),
                                        ("report", report_to_json(&report)),
                                    ]))?;
                                    s.queued = false;
                                    s.report = Some(report);
                                }
                                Err(why) => {
                                    summary.rejected_uploads += 1;
                                    journal.entry(Json::obj(vec![
                                        ("v", JOURNAL_VERSION.into()),
                                        ("kind", "reject".into()),
                                        ("job", job_id.into()),
                                        ("attempt", (attempt as usize).into()),
                                        ("device", dev.into()),
                                        ("error", why.as_str().into()),
                                    ]))?;
                                    s.last_error = Some(why);
                                    retry_or_drop(
                                        job_id,
                                        s,
                                        cfg,
                                        &mut summary,
                                        &mut journal,
                                    )?;
                                }
                            }
                        }
                    }
                }
                Ok(_) => {} // late join/warm chatter: ignore
            }
        }
        end_phase(&mut summary, &mut phase_t0, "train");
        if let Some((name, ms)) = summary.phase_ms.last().copied() {
            phase_entry(&mut journal, name, ms)?;
        }

        // ---- Collect ----------------------------------------------------
        kill_primary_check(cfg, RoundState::Collect)?;
        runner.on_phase(RoundState::Collect);
        // Re-verify every accepted drained delta against its recorded
        // digest: the journal must never claim bytes the disk doesn't hold.
        for s in &slots {
            let Some(r) = &s.report else { continue };
            if let (Some(p), Some(want)) = (&r.delta_path, &r.delta_digest) {
                let bytes = std::fs::read(p).with_context(|| {
                    format!("collect: reading accepted delta {}", p.display())
                })?;
                let got = fnv1a64_hex(&bytes);
                if &got != want {
                    bail!(
                        "collect: {} digest {} does not match accepted {}",
                        p.display(),
                        got,
                        want
                    );
                }
            }
        }
        let admitted = slots
            .iter()
            .filter(|s| {
                s.report
                    .as_ref()
                    .is_some_and(|r| r.status != JobStatus::NotAdmitted)
            })
            .count();
        let accepted = slots
            .iter()
            .filter(|s| {
                s.report
                    .as_ref()
                    .is_some_and(|r| r.status == JobStatus::Accepted)
            })
            .count();
        summary.quorum_required =
            ((cfg.quorum * admitted as f64).ceil() as usize).min(admitted);
        summary.quorum_met = accepted >= summary.quorum_required;
        journal.entry(Json::obj(vec![
            ("v", JOURNAL_VERSION.into()),
            ("kind", "collect".into()),
            ("accepted", accepted.into()),
            ("required", summary.quorum_required.into()),
            ("met", summary.quorum_met.into()),
        ]))?;
        end_phase(&mut summary, &mut phase_t0, "collect");
        if let Some((name, ms)) = summary.phase_ms.last().copied() {
            phase_entry(&mut journal, name, ms)?;
        }

        // ---- Cooldown ---------------------------------------------------
        kill_primary_check(cfg, RoundState::Cooldown)?;
        runner.on_phase(RoundState::Cooldown);
        // Dropping every command channel is the shutdown signal; workers
        // drain and exit, and the scope joins them on the way out.
        for d in devs.iter_mut() {
            d.tx = None;
        }
        Ok(())
    })?;

    end_phase(&mut summary, &mut phase_t0, "cooldown");
    if let Some((name, ms)) = summary.phase_ms.last().copied() {
        phase_entry(&mut journal, name, ms)?;
    }

    summary.accepted = 0;
    summary.not_admitted = 0;
    summary.dropped = 0;
    let mut reports: Vec<JobReport> = Vec::with_capacity(slots.len());
    for s in slots {
        let r = match s.report {
            Some(r) => r,
            // unreachable by construction (the train loop never exits with
            // an unfinished slot), but a lost job must still be visible
            None => terminal_report(
                &s.job,
                s.last_device.unwrap_or("-"),
                JobStatus::Dropped,
                s.attempts,
                Some("round ended without a terminal outcome".to_string()),
                f64::NAN,
            ),
        };
        match r.status {
            JobStatus::Accepted => summary.accepted += 1,
            JobStatus::NotAdmitted => summary.not_admitted += 1,
            JobStatus::Dropped => summary.dropped += 1,
        }
        reports.push(r);
    }
    reports.sort_by(|a, b| {
        a.task.cmp(&b.task).then(a.strategy.cmp(&b.strategy))
    });
    summary.wall_ms = wall_t0.elapsed().as_secs_f64() * 1e3;
    journal.entry(Json::obj(vec![
        ("v", JOURNAL_VERSION.into()),
        ("kind", "summary".into()),
        ("accepted", summary.accepted.into()),
        ("not_admitted", summary.not_admitted.into()),
        ("dropped", summary.dropped.into()),
        ("replayed", summary.replayed.into()),
        ("retries", (summary.retries as usize).into()),
        ("reassigned", (summary.reassigned as usize).into()),
        ("rejected_uploads", (summary.rejected_uploads as usize).into()),
        ("panics", (summary.panics as usize).into()),
        ("quorum_met", summary.quorum_met.into()),
    ]))?;

    Ok(RoundReport { reports, summary })
}

// ---------------------------------------------------------------------------
// SimRunner — an artifact-free JobRunner for tests and the chaos bench
// ---------------------------------------------------------------------------

/// Config name inside [`SimRunner`]'s synthetic manifest.
pub const SIM_CONFIG: &str = "sim";

/// A tiny self-consistent manifest (no artifacts, no files on disk): just
/// enough parameter table for `check_delta_*` admission and the memory /
/// cost models to be exercised for real.
const SIM_MANIFEST: &str = r#"{
    "version": 1,
    "batch": 2,
    "configs": {
        "sim": {
            "image_size": 8, "patch_size": 4, "dim": 4, "depth": 1,
            "heads": 1, "mlp_ratio": 2, "num_classes": 10, "channels": 3,
            "prompt_len": 2, "adapter_dim": 2, "lora_rank": 2,
            "num_params": 66,
            "params": [
                {"name": "blocks0/w", "shape": [4, 4], "init": "normal",
                 "masked": true, "stat": null},
                {"name": "head/kernel", "shape": [4, 10], "init": "zeros",
                 "masked": false, "stat": null},
                {"name": "head/bias", "shape": [10], "init": "zeros",
                 "masked": false, "stat": null}
            ],
            "lora_targets": ["blocks0/w"],
            "adapters": []
        }
    },
    "artifacts": []
}"#;

/// Deterministic simulated job runner: no PJRT, no artifacts, no
/// filesystem. Deltas are a pure function of `(seed, task, strategy)` —
/// independent of device and attempt — which is exactly the determinism
/// contract [`run_round`]'s resume path relies on, so the property tests
/// can assert bit-identical replays. Admission and the cost model are the
/// real ones ([`crate::peft::MemoryFootprint`], [`crate::edge`]).
pub struct SimRunner {
    manifest: Manifest,
    seed: u64,
    /// Simulated per-attempt work (lets stall/straggler tests control
    /// relative timing).
    pub work_ms: u64,
    /// Force every admission probe to refuse (NotAdmitted-path testing).
    pub deny: bool,
}

impl SimRunner {
    pub fn new(seed: u64) -> Result<SimRunner> {
        Ok(SimRunner {
            manifest: Manifest::parse(SIM_MANIFEST)
                .context("parsing SimRunner manifest")?,
            seed,
            work_ms: 0,
            deny: false,
        })
    }

    /// The synthetic manifest, for passing to [`run_round`].
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

fn sim_host(rng: &mut Rng, shape: &[usize]) -> crate::runtime::HostTensor {
    let n: usize = shape.iter().product();
    crate::runtime::HostTensor {
        shape: shape.to_vec(),
        data: crate::runtime::TensorData::F32(rng.normal_vec(n, 0.02)),
    }
}

impl JobRunner for SimRunner {
    fn admit(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
    ) -> Result<Admission> {
        if self.deny {
            return Ok(Admission {
                fits: false,
                required_bytes: device.memory_bytes.saturating_mul(2),
                available_bytes: device.memory_bytes,
                headroom: 0.5,
            });
        }
        let cfg = self.manifest.config(SIM_CONFIG)?;
        let trainable =
            crate::peft::accounting::estimate_trainable(&job.strategy, cfg);
        let fp = crate::peft::MemoryFootprint::compute(
            cfg,
            trainable,
            self.manifest.batch,
        );
        Ok(crate::edge::admit(device, &fp))
    }

    fn run(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
        _attempt: u32,
    ) -> Result<RunOutput> {
        if self.work_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.work_ms));
        }
        let cfg = self.manifest.config(SIM_CONFIG)?;
        let sname = job.strategy.name();
        let label = format!("sim:{}:{sname}", job.task.name);
        let mut rng = Rng::new(seed_with(self.seed, &label));

        let mut delta = TaskDelta::new(SIM_CONFIG);
        delta.task = job.task.name.to_string();
        delta.strategy = sname;
        match job.strategy.family() {
            crate::peft::Family::Lora => {
                delta.lora.insert(
                    "blocks0/w".to_string(),
                    crate::vit::LoraFactorDelta {
                        b: sim_host(&mut rng, &[4, 2]),
                        a: sim_host(&mut rng, &[2, 4]),
                        mask: crate::masking::Mask::ones(&[4, 4]),
                    },
                );
                delta
                    .dense
                    .insert("head/kernel".to_string(), sim_host(&mut rng, &[4, 10]));
            }
            crate::peft::Family::Vpt | crate::peft::Family::Adapter => {
                delta
                    .extra
                    .insert("task/prompt".to_string(), sim_host(&mut rng, &[2, 4]));
                delta
                    .dense
                    .insert("head/kernel".to_string(), sim_host(&mut rng, &[4, 10]));
            }
            crate::peft::Family::Dense => {
                let mut idx: Vec<u32> = (0..16).collect();
                rng.shuffle(&mut idx);
                idx.truncate(4);
                idx.sort_unstable();
                let values = rng.normal_vec(4, 0.02);
                delta.sparse.insert(
                    "blocks0/w".to_string(),
                    crate::vit::SparseTensorDelta {
                        shape: vec![4, 4],
                        indices: idx,
                        values,
                    },
                );
                delta
                    .dense
                    .insert("head/kernel".to_string(), sim_host(&mut rng, &[4, 10]));
                delta
                    .dense
                    .insert("head/bias".to_string(), sim_host(&mut rng, &[10]));
            }
        }

        let top1 = 0.4 + 0.5 * rng.uniform();
        let top5 = (top1 + 0.3).min(1.0);
        let trainable =
            crate::peft::accounting::estimate_trainable(&job.strategy, cfg);
        let trainable_frac = trainable as f64 / cfg.num_params.max(1) as f64;
        let tokens = (cfg.image_size / cfg.patch_size).pow(2) + 1;
        let flops = crate::edge::step_flops(
            cfg.dim,
            cfg.depth,
            cfg.mlp_ratio,
            tokens,
            self.manifest.batch,
        );
        let sim_step_ms = flops / (device.gflops * 1e9) * 1e3;
        let sim_energy_j =
            crate::edge::step_energy_joules(flops, device.gflops_per_joule)
                * 10.0;
        Ok(RunOutput {
            top1,
            top5,
            trainable_frac,
            sim_energy_j,
            sim_step_ms,
            delta,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::TrainConfig;
    use crate::data::task_by_name;
    use crate::edge::DEVICE_PROFILES;
    use crate::peft::Strategy;

    fn job(task: &str, strategy: Strategy) -> Job {
        Job {
            task: task_by_name(task).unwrap().clone(),
            strategy,
            train_cfg: TrainConfig::default(),
            n_train: 8,
            n_eval: 4,
        }
    }

    #[test]
    fn round_state_names_round_trip() {
        for p in [
            RoundState::Join,
            RoundState::Warmup,
            RoundState::Train,
            RoundState::Collect,
            RoundState::Cooldown,
        ] {
            assert_eq!(RoundState::parse(p.name()).unwrap(), p);
        }
        assert!(RoundState::parse("nowhere").is_err());
    }

    #[test]
    fn backoff_grows_is_jittered_and_deterministic() {
        let cfg = RoundConfig { backoff_ms: 100, ..RoundConfig::default() };
        let a1 = backoff_ms(&cfg, 0, 1);
        let a2 = backoff_ms(&cfg, 0, 2);
        let a3 = backoff_ms(&cfg, 0, 3);
        // jitter keeps each attempt within [0.5x, 1.5x) of its base
        assert!((50..150).contains(&a1), "{a1}");
        assert!((100..300).contains(&a2), "{a2}");
        assert!((200..600).contains(&a3), "{a3}");
        assert_eq!(a1, backoff_ms(&cfg, 0, 1));
        assert_ne!(backoff_ms(&cfg, 0, 1), backoff_ms(&cfg, 1, 1));
    }

    #[test]
    fn sim_round_accepts_all_jobs_without_faults() {
        let runner = SimRunner::new(7).unwrap();
        let jobs = vec![
            job("syn-pets", Strategy::TaskEdge { k: 2 }),
            job("syn-dtd", Strategy::Lora),
            job("syn-eurosat", Strategy::Vpt),
        ];
        let cfg = RoundConfig { seed: 7, ..RoundConfig::default() };
        let out = run_round(
            runner.manifest(),
            &[&DEVICE_PROFILES[0]],
            &jobs,
            &runner,
            &cfg,
        )
        .unwrap();
        assert_eq!(out.reports.len(), 3);
        assert_eq!(out.summary.accepted, 3);
        assert!(out.summary.quorum_met);
        assert_eq!(out.summary.joined_devices.len(), 1);
        for r in &out.reports {
            assert_eq!(r.status, JobStatus::Accepted);
            assert_eq!(r.attempts, 1);
            assert!(r.delta.is_some());
            assert!(r.delta_bytes > 0);
        }
        let phases: Vec<&str> =
            out.summary.phase_ms.iter().map(|(n, _)| *n).collect();
        assert_eq!(phases, ["join", "warmup", "train", "collect", "cooldown"]);
    }

    #[test]
    fn sim_deltas_are_pure_functions_of_job_and_seed() {
        let runner = SimRunner::new(11).unwrap();
        let j = job("syn-pets", Strategy::TaskEdge { k: 2 });
        let a = runner.run(&j, &DEVICE_PROFILES[0], 1).unwrap();
        let b = runner.run(&j, &DEVICE_PROFILES[2], 5).unwrap();
        assert_eq!(a.delta, b.delta, "delta must not depend on device/attempt");
    }

    #[test]
    fn report_json_round_trips() {
        let runner = SimRunner::new(3).unwrap();
        let j = job("syn-dtd", Strategy::TaskEdge { k: 2 });
        let out = runner.run(&j, &DEVICE_PROFILES[0], 1).unwrap();
        let cfg = RoundConfig::default();
        let acc = Accept {
            job_id: 0,
            attempt: 1,
            job: &j,
            device: DEVICE_PROFILES[0].name,
            required_mb: 1.5,
            wall_ms: 12.25,
            attempts: 1,
        };
        let report =
            accept_upload(runner.manifest(), &cfg, acc, out).unwrap();
        let text = report_to_json(&report).to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = report_from_json(&parsed, Path::new("/tmp")).unwrap();
        assert_eq!(back.task, report.task);
        assert_eq!(back.strategy, report.strategy);
        assert_eq!(back.status, report.status);
        assert_eq!(back.top1.to_bits(), report.top1.to_bits());
        assert_eq!(back.wall_ms.to_bits(), report.wall_ms.to_bits());
        assert_eq!(back.delta_bytes, report.delta_bytes);
    }

    #[test]
    fn corrupt_upload_is_rejected_in_memory_mode() {
        let runner = SimRunner::new(3).unwrap();
        let j = job("syn-dtd", Strategy::TaskEdge { k: 2 });
        let out = runner.run(&j, &DEVICE_PROFILES[0], 1).unwrap();
        let cfg = RoundConfig {
            faults: FaultPlan::parse("corrupt@0", 3).unwrap(),
            ..RoundConfig::default()
        };
        let acc = Accept {
            job_id: 0,
            attempt: 1,
            job: &j,
            device: DEVICE_PROFILES[0].name,
            required_mb: 1.5,
            wall_ms: 1.0,
            attempts: 1,
        };
        let err = accept_upload(runner.manifest(), &cfg, acc, out)
            .expect_err("corrupted upload must be rejected");
        assert!(err.contains("delta."), "{err}");
    }
}
