//! L3 coordination: the TaskEdge fine-tuning pipeline (Calibrate -> Score
//! -> Allocate -> Train -> Eval), upstream pretraining, and the edge fleet
//! scheduler — phased fault-tolerant rounds with memory admission control,
//! deterministic fault injection, and a resumable round journal.

pub mod faults;
pub mod fleet;
pub mod pretrain;
pub mod rounds;
pub mod session;

pub use faults::FaultPlan;
pub use fleet::{Fleet, Job, JobReport, JobStatus, SessionRunner};
pub use pretrain::{pretrain, PretrainConfig, PretrainReport};
pub use rounds::{
    run_round, seeded_backoff_ms, JobRunner, RoundConfig, RoundReport,
    RoundState, RoundSummary, RunOutput, SimRunner,
};
pub use session::{FinetuneSession, Phase, SessionResult, TrainConfig};
