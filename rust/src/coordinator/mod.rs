//! L3 coordination: the TaskEdge fine-tuning pipeline (Calibrate -> Score
//! -> Allocate -> Train -> Eval), upstream pretraining, and the edge fleet
//! scheduler with memory admission control.

pub mod fleet;
pub mod pretrain;
pub mod session;

pub use fleet::{Fleet, Job, JobReport};
pub use pretrain::{pretrain, PretrainConfig, PretrainReport};
pub use session::{FinetuneSession, Phase, SessionResult, TrainConfig};
