//! Fleet scheduler: a heterogeneous pool of edge devices running
//! fine-tuning jobs under memory admission control (the edge-side systems
//! contribution: TaskEdge's tiny optimizer state is what lets jobs fit on
//! small devices at all).
//!
//! Scheduling, fault tolerance, and the resumable round journal live in
//! [`super::rounds`]; this module owns the job/report vocabulary and the
//! production [`JobRunner`] that drives real `FinetuneSession`s over the
//! shared PJRT runtime (compiled executables are cached once and reused
//! across devices). Per-device *simulated* time and energy come from the
//! cost model; real wall time is also recorded.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::rounds::{
    run_round, JobRunner, RoundConfig, RoundReport, RunOutput,
};
use crate::coordinator::session::{FinetuneSession, TrainConfig};
use crate::data::{generate_task, TaskSpec};
use crate::edge::{admit, step_energy_joules, step_flops, Admission, DeviceProfile};
use crate::peft::{self, MemoryFootprint, Strategy};
use crate::runtime::Runtime;
use crate::vit::{ParamStore, TaskDelta};

#[derive(Debug, Clone)]
pub struct Job {
    pub task: TaskSpec,
    pub strategy: Strategy,
    pub train_cfg: TrainConfig,
    pub n_train: usize,
    pub n_eval: usize,
}

/// Terminal outcome of one job in a round. Every job ends in exactly one
/// of these — faults degrade a round, they never lose a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    /// Ran, and its delta passed admission.
    Accepted,
    /// No surviving device admits its memory footprint.
    NotAdmitted,
    /// Retries exhausted, round deadline hit, or device pool lost.
    Dropped,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Accepted => "accepted",
            JobStatus::NotAdmitted => "not_admitted",
            JobStatus::Dropped => "dropped",
        }
    }

    pub fn parse(s: &str) -> Result<JobStatus> {
        match s {
            "accepted" => Ok(JobStatus::Accepted),
            "not_admitted" => Ok(JobStatus::NotAdmitted),
            "dropped" => Ok(JobStatus::Dropped),
            _ => bail!("unknown job status {s:?}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct JobReport {
    pub task: String,
    pub strategy: String,
    pub device: String,
    pub admitted: bool,
    pub required_mb: f64,
    pub top1: f64,
    pub top5: f64,
    pub trainable_frac: f64,
    pub wall_ms: f64,
    pub sim_energy_j: f64,
    pub sim_step_ms: f64,
    /// The fine-tuned task as a sparse delta over the shared backbone —
    /// what an edge device actually uploads (None when not accepted, and
    /// None in drain mode, where the delta lives at `delta_path` instead).
    /// Sparse-strategy deltas are tiny; only the `full` ablation baseline
    /// approaches model size, and callers sweeping `full` at scale should
    /// drain to disk via `RoundConfig::delta_dir`.
    pub delta: Option<TaskDelta>,
    /// exact serialized size of `delta` (0 when not accepted)
    pub delta_bytes: usize,
    /// terminal outcome (`admitted`/`delta` are projections of this)
    pub status: JobStatus,
    /// attempts consumed (1 on a clean first run)
    pub attempts: u32,
    /// last failure message for `Dropped`/`NotAdmitted` jobs
    pub error: Option<String>,
    /// drain mode: where the accepted delta file was saved
    pub delta_path: Option<PathBuf>,
    /// drain mode: FNV-1a digest of the saved bytes (journal integrity)
    pub delta_digest: Option<String>,
}

pub struct Fleet {
    pub devices: Vec<&'static DeviceProfile>,
}

impl Fleet {
    pub fn new(devices: Vec<&'static DeviceProfile>) -> Fleet {
        Fleet { devices }
    }

    /// Run all jobs across the device pool with default round settings
    /// (no faults, no journal). Kept as the simple entry point; callers
    /// needing resume/fault/quorum control use [`Fleet::run_round`].
    pub fn run(
        &self,
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        jobs: Vec<Job>,
        seed: u64,
    ) -> Result<Vec<JobReport>> {
        let cfg = RoundConfig { seed, ..RoundConfig::default() };
        Ok(self.run_round(rt, config_name, backbone, jobs, &cfg)?.reports)
    }

    /// Run one phased, fault-tolerant round (see [`super::rounds`]).
    pub fn run_round(
        &self,
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        jobs: Vec<Job>,
        cfg: &RoundConfig,
    ) -> Result<RoundReport> {
        let runner = SessionRunner {
            rt,
            config_name: config_name.to_string(),
            backbone,
            seed: cfg.seed,
        };
        run_round(
            runner.rt.manifest(),
            &self.devices,
            &jobs,
            &runner,
            cfg,
        )
    }
}

/// The production [`JobRunner`]: each attempt is a full `FinetuneSession`
/// over the shared runtime. Deltas depend only on `(job, seed)` — device
/// and attempt shape the timing/energy metrics, never the tuned bytes —
/// which is the determinism contract the round journal's resume relies on.
///
/// Public so a remote participant (`taskedge participate`) can run the
/// same production sessions against a backbone streamed over the wire.
pub struct SessionRunner {
    rt: Arc<Runtime>,
    config_name: String,
    backbone: Arc<ParamStore>,
    seed: u64,
}

impl SessionRunner {
    pub fn new(
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        seed: u64,
    ) -> SessionRunner {
        SessionRunner {
            rt,
            config_name: config_name.to_string(),
            backbone,
            seed,
        }
    }
}

impl JobRunner for SessionRunner {
    fn admit(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
    ) -> Result<Admission> {
        let cfg = self.rt.manifest().config(&self.config_name)?;
        let est = peft::accounting::estimate_trainable(&job.strategy, cfg);
        let footprint =
            MemoryFootprint::compute(cfg, est, self.rt.manifest().batch);
        Ok(admit(device, &footprint))
    }

    fn warmup(&self, _device: &'static DeviceProfile, jobs: &[Job]) -> Result<()> {
        // one compile pass per distinct strategy; the runtime's executable
        // cache makes the per-device repeats free
        let mut seen = BTreeSet::new();
        for job in jobs {
            if !seen.insert(job.strategy.name()) {
                continue;
            }
            FinetuneSession::new(
                &self.rt,
                &self.config_name,
                job.strategy.clone(),
                job.train_cfg.clone(),
            )?
            .warmup()?;
        }
        Ok(())
    }

    fn run(
        &self,
        job: &Job,
        device: &'static DeviceProfile,
        _attempt: u32,
    ) -> Result<RunOutput> {
        let cfg = self.rt.manifest().config(&self.config_name)?;
        let batch = self.rt.manifest().batch;
        let (train, eval) = generate_task(
            &job.task,
            cfg.image_size,
            job.n_train,
            job.n_eval,
            self.seed,
        )?;
        let mut session = FinetuneSession::new(
            &self.rt,
            &self.config_name,
            job.strategy.clone(),
            job.train_cfg.clone(),
        )?;
        let result = session.run(&self.backbone, &train, &eval, job.task.name)?;

        // Simulated device-side cost: FLOPs / device throughput + energy.
        let tokens = (cfg.image_size / cfg.patch_size).pow(2) + 1;
        let flops = step_flops(cfg.dim, cfg.depth, cfg.mlp_ratio, tokens, batch);
        let sim_step_ms = flops / (device.gflops * 1e9) * 1e3;
        let steps = result.record.curve.iter().map(|e| e.steps).sum::<usize>();
        let sim_energy_j =
            step_energy_joules(flops, device.gflops_per_joule) * steps as f64;

        Ok(RunOutput {
            top1: result.record.best_top1(),
            top5: result.record.best_top5(),
            trainable_frac: result.trainable_frac,
            sim_energy_j,
            sim_step_ms,
            delta: result.delta,
        })
    }
}
