//! Fleet scheduler: simulates a heterogeneous pool of edge devices, each
//! running fine-tuning jobs under memory admission control (the edge-side
//! systems contribution: TaskEdge's tiny optimizer state is what lets jobs
//! fit on small devices at all).
//!
//! Devices are worker threads sharing the PJRT runtime (compiled
//! executables are cached once and reused); per-device *simulated* time and
//! energy come from the cost model, real wall time is also recorded.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::session::{FinetuneSession, TrainConfig};
use crate::data::{generate_task, TaskSpec};
use crate::edge::{admit, step_energy_joules, step_flops, DeviceProfile};
use crate::peft::{self, MemoryFootprint, Strategy};
use crate::runtime::Runtime;
use crate::vit::{ParamStore, TaskDelta};

#[derive(Debug, Clone)]
pub struct Job {
    pub task: TaskSpec,
    pub strategy: Strategy,
    pub train_cfg: TrainConfig,
    pub n_train: usize,
    pub n_eval: usize,
}

#[derive(Debug)]
pub struct JobReport {
    pub task: String,
    pub strategy: String,
    pub device: String,
    pub admitted: bool,
    pub required_mb: f64,
    pub top1: f64,
    pub top5: f64,
    pub trainable_frac: f64,
    pub wall_ms: f64,
    pub sim_energy_j: f64,
    pub sim_step_ms: f64,
    /// The fine-tuned task as a sparse delta over the shared backbone —
    /// what an edge device actually uploads (None when not admitted).
    /// Deliberately held in memory: the fleet is the collection point for
    /// the serving tier (ROADMAP delta-transport item). Sparse-strategy
    /// deltas are tiny; only the `full` ablation baseline approaches model
    /// size, and callers that sweep `full` at scale should drain reports
    /// to disk via `TaskDelta::save` as they arrive.
    pub delta: Option<TaskDelta>,
    /// exact serialized size of `delta` (0 when not admitted)
    pub delta_bytes: usize,
}

pub struct Fleet {
    pub devices: Vec<&'static DeviceProfile>,
}

impl Fleet {
    pub fn new(devices: Vec<&'static DeviceProfile>) -> Fleet {
        Fleet { devices }
    }

    /// Run all jobs across the device pool (one worker thread per device;
    /// each device pulls the next job whose footprint it admits).
    pub fn run(
        &self,
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        jobs: Vec<Job>,
        seed: u64,
    ) -> Result<Vec<JobReport>> {
        let queue = Arc::new(Mutex::new(VecDeque::from(jobs)));
        let reports = Arc::new(Mutex::new(Vec::new()));
        let config_name = config_name.to_string();

        std::thread::scope(|scope| {
            for profile in &self.devices {
                let queue = queue.clone();
                let reports = reports.clone();
                let rt = rt.clone();
                let backbone = backbone.clone();
                let config_name = config_name.clone();
                scope.spawn(move || {
                    loop {
                        let job = {
                            let mut q = queue.lock().unwrap();
                            match q.pop_front() {
                                Some(j) => j,
                                None => break,
                            }
                        };
                        let report = run_one(
                            &rt, &config_name, &backbone, &job, profile, seed,
                        );
                        match report {
                            Ok(r) => reports.lock().unwrap().push(r),
                            Err(e) => {
                                crate::info!(
                                    "[fleet:{}] job {} failed: {e:#}",
                                    profile.name,
                                    job.task.name
                                );
                            }
                        }
                    }
                });
            }
        });

        let mut out = Arc::try_unwrap(reports)
            .map_err(|_| anyhow::anyhow!("reports still shared"))?
            .into_inner()
            .unwrap();
        out.sort_by(|a, b| a.task.cmp(&b.task).then(a.strategy.cmp(&b.strategy)));
        Ok(out)
    }
}

fn run_one(
    rt: &Runtime,
    config_name: &str,
    backbone: &ParamStore,
    job: &Job,
    profile: &'static DeviceProfile,
    seed: u64,
) -> Result<JobReport> {
    let cfg = rt.manifest().config(config_name)?;
    let batch = rt.manifest().batch;

    // Admission: analytic footprint from the strategy's trainable estimate.
    let est_trainable = peft::accounting::estimate_trainable(&job.strategy, cfg);
    let footprint = MemoryFootprint::compute(cfg, est_trainable, batch);
    let adm = admit(profile, &footprint);
    let required_mb = adm.required_bytes as f64 / (1024.0 * 1024.0);
    if !adm.fits {
        return Ok(JobReport {
            task: job.task.name.to_string(),
            strategy: job.strategy.name(),
            device: profile.name.to_string(),
            admitted: false,
            required_mb,
            top1: f64::NAN,
            top5: f64::NAN,
            trainable_frac: f64::NAN,
            wall_ms: 0.0,
            sim_energy_j: f64::NAN,
            sim_step_ms: f64::NAN,
            delta: None,
            delta_bytes: 0,
        });
    }

    let (train, eval) =
        generate_task(&job.task, cfg.image_size, job.n_train, job.n_eval, seed)?;
    let mut session = FinetuneSession::new(
        rt,
        config_name,
        job.strategy.clone(),
        job.train_cfg.clone(),
    )?;
    let t0 = std::time::Instant::now();
    let result = session.run(backbone, &train, &eval, job.task.name)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Simulated device-side cost: FLOPs / device throughput + energy.
    let tokens = (cfg.image_size / cfg.patch_size).pow(2) + 1;
    let flops = step_flops(cfg.dim, cfg.depth, cfg.mlp_ratio, tokens, batch);
    let sim_step_ms = flops / (profile.gflops * 1e9) * 1e3;
    let steps = result.record.curve.iter().map(|e| e.steps).sum::<usize>();
    let sim_energy_j =
        step_energy_joules(flops, profile.gflops_per_joule) * steps as f64;

    // What leaves the device: a sparse TaskDelta, not a full ParamStore.
    let delta_bytes = result.delta.file_bytes();
    Ok(JobReport {
        task: job.task.name.to_string(),
        strategy: job.strategy.name(),
        device: profile.name.to_string(),
        admitted: true,
        required_mb,
        top1: result.record.best_top1(),
        top5: result.record.best_top5(),
        trainable_frac: result.trainable_frac,
        wall_ms,
        sim_energy_j,
        sim_step_ms,
        delta: Some(result.delta),
        delta_bytes,
    })
}
