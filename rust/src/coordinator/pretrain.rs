//! Upstream pretraining: the backbone is trained from scratch in-repo on
//! the synthetic multi-domain corpus (the paper's ImageNet-21k checkpoint
//! is gated — DESIGN.md §2). Uses the `train_sgd` artifact with all-ones
//! masks (i.e. dense training through the same masked-update kernels).

use anyhow::{bail, Result};

use crate::data::{Batcher, Dataset};
use crate::masking::Mask;
use crate::metrics::LrSchedule;
use crate::runtime::{HostTensor, IoBinder, Runtime};
use crate::util::rng::Rng;
use crate::vit::ParamStore;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_frac: f32,
    pub seed: u64,
    /// log the loss every k steps
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            steps: 300,
            lr: 0.05,
            weight_decay: 1e-4,
            warmup_frac: 0.1,
            seed: 42,
            log_every: 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PretrainReport {
    /// (step, mean loss over the logging window, mean acc)
    pub loss_curve: Vec<(usize, f64, f64)>,
    pub final_loss: f64,
    pub steps: usize,
}

/// Train `params` in place on the corpus; returns the loss curve.
pub fn pretrain(
    rt: &Runtime,
    config_name: &str,
    params: &mut ParamStore,
    corpus: &Dataset,
    cfg: &PretrainConfig,
) -> Result<PretrainReport> {
    let mcfg = rt.manifest().config(config_name)?;
    let batch = rt.manifest().batch;
    if corpus.image_size != mcfg.image_size {
        bail!("corpus image size {} != config {}", corpus.image_size, mcfg.image_size);
    }
    let spec = rt.manifest().artifact_for("train_sgd", config_name)?.clone();

    // Dense pretraining = all-ones masks through the same sparse kernels.
    let ones: Vec<(String, HostTensor)> = mcfg
        .params
        .iter()
        .map(|p| (p.name.clone(), Mask::ones(&p.shape).to_tensor()))
        .collect();
    let ones: std::collections::BTreeMap<String, HostTensor> =
        ones.into_iter().collect();
    let mut mom = ParamStore::zeros_like(mcfg);

    let sched = LrSchedule::new(
        cfg.lr,
        (cfg.steps as f32 * cfg.warmup_frac) as usize,
        cfg.steps,
    );
    let mut rng = Rng::new(cfg.seed);
    let mut batcher = Batcher::new(corpus.n, batch, rng.next_u64());

    let mut report = PretrainReport {
        loss_curve: Vec::new(),
        final_loss: f64::NAN,
        steps: cfg.steps,
    };
    let mut win_loss = 0.0;
    let mut win_acc = 0.0;
    let mut win_n = 0usize;

    for step in 0..cfg.steps {
        let ids = batcher.next_batch();
        let (images, labels) = corpus.batch(&ids)?;
        let lr = sched.at(step);
        let binder = IoBinder::new(&spec);
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if let Some(p) = io.name.strip_prefix("mask:") {
                Ok(ones[p].clone())
            } else if let Some(p) = io.name.strip_prefix("mom:") {
                Ok(mom.get(p)?.clone())
            } else {
                match io.name.as_str() {
                    "images" => Ok(images.clone()),
                    "labels" => Ok(labels.clone()),
                    "lr" => Ok(HostTensor::scalar_f32(lr)),
                    "wd" => Ok(HostTensor::scalar_f32(cfg.weight_decay)),
                    other => bail!("unexpected train_sgd input {other}"),
                }
            }
        })?;
        let outputs = rt.execute(&spec.name, &inputs)?;
        for (out, os) in outputs.iter().zip(&spec.outputs) {
            if let Some(p) = os.name.strip_prefix("param:") {
                params.set(p, out.clone())?;
            } else if let Some(p) = os.name.strip_prefix("mom:") {
                mom.set(p, out.clone())?;
            } else if os.name == "loss" {
                win_loss += out.item_f32()? as f64;
                win_n += 1;
            } else if os.name == "n_correct" {
                win_acc += out.item_f32()? as f64 / batch as f64;
            }
        }
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            let mean = win_loss / win_n.max(1) as f64;
            let acc = win_acc / win_n.max(1) as f64;
            crate::info!("[pretrain] step {:>5} loss {:.4} acc {:.3} lr {:.4}",
                         step + 1, mean, acc, lr);
            report.loss_curve.push((step + 1, mean, acc));
            report.final_loss = mean;
            win_loss = 0.0;
            win_acc = 0.0;
            win_n = 0;
        }
    }
    Ok(report)
}
