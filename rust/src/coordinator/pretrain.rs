//! Upstream pretraining: the backbone is trained from scratch in-repo on
//! the synthetic multi-domain corpus (the paper's ImageNet-21k checkpoint
//! is gated — DESIGN.md §2). Uses the `train_sgd` artifact with all-ones
//! masks (i.e. dense training through the same masked-update kernels).

use anyhow::{bail, Result};

use crate::data::{Dataset, Prefetcher};
use crate::masking::Mask;
use crate::metrics::LrSchedule;
use crate::runtime::{next_generation, HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::vit::ParamStore;

use super::session::{OutSink, Routing, StepCtx, StepPlan};

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_frac: f32,
    pub seed: u64,
    /// log the loss every k steps
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            steps: 300,
            lr: 0.05,
            weight_decay: 1e-4,
            warmup_frac: 0.1,
            seed: 42,
            log_every: 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PretrainReport {
    /// (step, mean loss over the logging window, mean acc)
    pub loss_curve: Vec<(usize, f64, f64)>,
    pub final_loss: f64,
    pub steps: usize,
}

/// Train `params` in place on the corpus; returns the loss curve.
pub fn pretrain(
    rt: &Runtime,
    config_name: &str,
    params: &mut ParamStore,
    corpus: &Dataset,
    cfg: &PretrainConfig,
) -> Result<PretrainReport> {
    let mcfg = rt.manifest().config(config_name)?;
    let batch = rt.manifest().batch;
    if corpus.image_size != mcfg.image_size {
        bail!("corpus image size {} != config {}", corpus.image_size, mcfg.image_size);
    }
    let spec = rt.manifest().artifact_for("train_sgd", config_name)?;

    // Dense pretraining = all-ones masks through the same sparse kernels.
    let ones: std::collections::BTreeMap<String, HostTensor> = mcfg
        .params
        .iter()
        .map(|p| (p.name.clone(), Mask::ones(&p.shape).to_tensor()))
        .collect();
    let mut mom = ParamStore::zeros_like(mcfg);

    // One StepPlan under the session's Dense routing — the same
    // frozen-slot skip walk the fine-tuning loops compile, not a second
    // local copy of the classification logic. The all-ones masks are the
    // only per-run-constant inputs here (params/momentum train every
    // step) and they are model-sized: frozen once as cached literals +
    // resident device buffers under a freshly minted generation, so the
    // prepared set can never alias another source.
    let prep_gen = next_generation();
    let plan = StepPlan::compile(
        rt,
        spec,
        Routing::Dense,
        Some(prep_gen),
        &StepCtx { masks: Some(&ones), ..StepCtx::default() },
    )?;
    let wd_t = HostTensor::scalar_f32(cfg.weight_decay);

    let sched = LrSchedule::new(
        cfg.lr,
        (cfg.steps as f32 * cfg.warmup_frac) as usize,
        cfg.steps,
    );
    let mut rng = Rng::new(cfg.seed);
    // batch assembly overlaps device execution; the worker draws from the
    // identical Batcher id stream the inline loop used
    let mut prefetch =
        Prefetcher::spawn(corpus, batch, rng.next_u64(), cfg.steps);

    let mut report = PretrainReport {
        loss_curve: Vec::new(),
        final_loss: f64::NAN,
        steps: cfg.steps,
    };
    let mut win_loss = 0.0;
    let mut win_acc = 0.0;
    let mut win_n = 0usize;

    for step in 0..cfg.steps {
        let (images, labels) = prefetch.next()?;
        let lr = sched.at(step);
        let lr_t = HostTensor::scalar_f32(lr);
        let ctx = StepCtx {
            params: Some(&*params),
            masks: Some(&ones),
            mom: Some(&mom),
            images: Some(&images),
            labels: Some(&labels),
            lr: Some(&lr_t),
            wd: Some(&wd_t),
            ..StepCtx::default()
        };
        let outputs = plan.execute(rt, &ctx)?;
        for (out, sink) in outputs.into_iter().zip(&plan.sinks) {
            match sink {
                OutSink::Param(p) => params.set(p, out)?,
                OutSink::Mom(p) => mom.set(p, out)?,
                OutSink::Loss => {
                    win_loss += out.item_f32()? as f64;
                    win_n += 1;
                }
                OutSink::NCorrect => {
                    win_acc += out.item_f32()? as f64 / batch as f64;
                }
                OutSink::Skip => {}
                other => bail!("unexpected train_sgd output sink {other:?}"),
            }
        }
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            let mean = win_loss / win_n.max(1) as f64;
            let acc = win_acc / win_n.max(1) as f64;
            crate::info!("[pretrain] step {:>5} loss {:.4} acc {:.3} lr {:.4}",
                         step + 1, mean, acc, lr);
            report.loss_curve.push((step + 1, mean, acc));
            report.final_loss = mean;
            win_loss = 0.0;
            win_acc = 0.0;
            win_n = 0;
        }
    }
    Ok(report)
}
