//! Upstream pretraining: the backbone is trained from scratch in-repo on
//! the synthetic multi-domain corpus (the paper's ImageNet-21k checkpoint
//! is gated — DESIGN.md §2). Uses the `train_sgd` artifact with all-ones
//! masks (i.e. dense training through the same masked-update kernels).

use anyhow::{bail, Result};

use crate::data::{Batcher, Dataset};
use crate::masking::Mask;
use crate::metrics::LrSchedule;
use crate::runtime::{next_generation, HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::vit::ParamStore;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_frac: f32,
    pub seed: u64,
    /// log the loss every k steps
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            steps: 300,
            lr: 0.05,
            weight_decay: 1e-4,
            warmup_frac: 0.1,
            seed: 42,
            log_every: 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PretrainReport {
    /// (step, mean loss over the logging window, mean acc)
    pub loss_curve: Vec<(usize, f64, f64)>,
    pub final_loss: f64,
    pub steps: usize,
}

/// Train `params` in place on the corpus; returns the loss curve.
pub fn pretrain(
    rt: &Runtime,
    config_name: &str,
    params: &mut ParamStore,
    corpus: &Dataset,
    cfg: &PretrainConfig,
) -> Result<PretrainReport> {
    let mcfg = rt.manifest().config(config_name)?;
    let batch = rt.manifest().batch;
    if corpus.image_size != mcfg.image_size {
        bail!("corpus image size {} != config {}", corpus.image_size, mcfg.image_size);
    }
    let spec = rt.manifest().artifact_for("train_sgd", config_name)?;

    // Dense pretraining = all-ones masks through the same sparse kernels.
    let ones: Vec<(String, HostTensor)> = mcfg
        .params
        .iter()
        .map(|p| (p.name.clone(), Mask::ones(&p.shape).to_tensor()))
        .collect();
    let ones: std::collections::BTreeMap<String, HostTensor> =
        ones.into_iter().collect();
    let mut mom = ParamStore::zeros_like(mcfg);

    // Slot routing resolved once (the session loops compile full
    // StepPlans; pretraining has one artifact and enum dispatch is all it
    // needs): inputs bind by reference, outputs move into the stores — no
    // per-step tensor clones or string-prefix matching. The all-ones
    // masks are the only per-step-constant inputs here (params/momentum
    // train every step), and they are model-sized: freeze them as device
    // literals once instead of re-converting them every step.
    enum Src {
        Param(String),
        Mask(String),
        Mom(String),
        Images,
        Labels,
        Lr,
        Wd,
    }
    enum Sink {
        Param(String),
        Mom(String),
        Loss,
        NCorrect,
        Skip,
    }
    let srcs: Vec<Src> = spec
        .inputs
        .iter()
        .map(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(Src::Param(p.to_string()))
            } else if let Some(p) = io.name.strip_prefix("mask:") {
                Ok(Src::Mask(p.to_string()))
            } else if let Some(p) = io.name.strip_prefix("mom:") {
                Ok(Src::Mom(p.to_string()))
            } else {
                match io.name.as_str() {
                    "images" => Ok(Src::Images),
                    "labels" => Ok(Src::Labels),
                    "lr" => Ok(Src::Lr),
                    "wd" => Ok(Src::Wd),
                    other => bail!("unexpected train_sgd input {other}"),
                }
            }
        })
        .collect::<Result<_>>()?;
    let sinks: Vec<Sink> = spec
        .outputs
        .iter()
        .map(|os| {
            if let Some(p) = os.name.strip_prefix("param:") {
                Sink::Param(p.to_string())
            } else if let Some(p) = os.name.strip_prefix("mom:") {
                Sink::Mom(p.to_string())
            } else if os.name == "loss" {
                Sink::Loss
            } else if os.name == "n_correct" {
                Sink::NCorrect
            } else {
                Sink::Skip
            }
        })
        .collect();
    // mask slots frozen once for the whole pretraining run (the ones
    // tensors never change; the id is freshly minted so the prepared set
    // can never alias another source)
    let frozen: Vec<usize> = srcs
        .iter()
        .enumerate()
        .filter(|(_, s)| matches!(s, Src::Mask(_)))
        .map(|(i, _)| i)
        .collect();
    let fixed: Vec<(usize, &HostTensor)> = frozen
        .iter()
        .map(|&i| match &srcs[i] {
            Src::Mask(p) => (i, &ones[p]),
            _ => unreachable!("frozen indices are mask slots"),
        })
        .collect();
    let prep = rt.prepare(&spec.name, next_generation(), &fixed)?;
    let wd_t = HostTensor::scalar_f32(cfg.weight_decay);

    let sched = LrSchedule::new(
        cfg.lr,
        (cfg.steps as f32 * cfg.warmup_frac) as usize,
        cfg.steps,
    );
    let mut rng = Rng::new(cfg.seed);
    let mut batcher = Batcher::new(corpus.n, batch, rng.next_u64());

    let mut report = PretrainReport {
        loss_curve: Vec::new(),
        final_loss: f64::NAN,
        steps: cfg.steps,
    };
    let mut win_loss = 0.0;
    let mut win_acc = 0.0;
    let mut win_n = 0usize;

    for step in 0..cfg.steps {
        let ids = batcher.next_batch();
        let (images, labels) = corpus.batch(&ids)?;
        let lr = sched.at(step);
        let lr_t = HostTensor::scalar_f32(lr);
        // dynamic slots in manifest order, skipping the frozen mask slots
        let mut dynamics: Vec<&HostTensor> =
            Vec::with_capacity(srcs.len() - frozen.len());
        let mut f = 0usize;
        for (i, s) in srcs.iter().enumerate() {
            if f < frozen.len() && frozen[f] == i {
                f += 1;
                continue;
            }
            dynamics.push(match s {
                Src::Param(p) => params.get(p)?,
                Src::Mask(p) => &ones[p],
                Src::Mom(p) => mom.get(p)?,
                Src::Images => &images,
                Src::Labels => &labels,
                Src::Lr => &lr_t,
                Src::Wd => &wd_t,
            });
        }
        let outputs = rt.execute_prepared(&prep, &dynamics)?;
        drop(dynamics);
        for (out, sink) in outputs.into_iter().zip(&sinks) {
            match sink {
                Sink::Param(p) => params.set(p, out)?,
                Sink::Mom(p) => mom.set(p, out)?,
                Sink::Loss => {
                    win_loss += out.item_f32()? as f64;
                    win_n += 1;
                }
                Sink::NCorrect => {
                    win_acc += out.item_f32()? as f64 / batch as f64;
                }
                Sink::Skip => {}
            }
        }
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            let mean = win_loss / win_n.max(1) as f64;
            let acc = win_acc / win_n.max(1) as f64;
            crate::info!("[pretrain] step {:>5} loss {:.4} acc {:.3} lr {:.4}",
                         step + 1, mean, acc, lr);
            report.loss_curve.push((step + 1, mean, acc));
            report.final_loss = mean;
            win_loss = 0.0;
            win_acc = 0.0;
            win_n = 0;
        }
    }
    Ok(report)
}
