//! The fine-tuning session state machine — TaskEdge's Alg. 1 as an edge
//! coordinator pipeline:
//!
//!   Calibrate -> Score -> Allocate -> Train -> Eval
//!
//! One session = one (task, strategy) pair on one backbone. All compute
//! graphs are AOT artifacts executed through the PJRT runtime; this module
//! only assembles named tensors per the manifest and accumulates metrics.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::{Batcher, Dataset};
use crate::masking::{GradAccumulator, Mask, StatAccumulator};
use crate::metrics::{EpochMetrics, LrSchedule, RunRecord};
use crate::peft::{self, Family, Strategy};
use crate::runtime::{HostTensor, IoBinder, ModelConfig, Runtime};
use crate::util::rng::Rng;
use crate::vit::{lora_shapes, LoraFactorDelta, ParamStore, TaskDelta};

/// Session hyperparameters (paper §IV-B: Adam, cosine decay, warmup).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// fraction of total steps used for linear warmup (paper: 10/100 epochs)
    pub warmup_frac: f32,
    pub seed: u64,
    /// batches of train data used for activation calibration
    pub calib_batches: usize,
    /// evaluate every k epochs (last epoch always evaluated)
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 20,
            lr: 1e-3,
            weight_decay: 1e-4,
            warmup_frac: 0.1,
            seed: 0,
            calib_batches: 8,
            eval_every: 1,
        }
    }
}

/// Session phases (observable progress for the fleet scheduler / CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Init,
    Calibrate,
    Score,
    Allocate,
    Train,
    Eval,
    Done,
}

#[derive(Debug)]
pub struct SessionResult {
    pub record: RunRecord,
    pub trainable_params: usize,
    pub trainable_frac: f64,
    pub masks: BTreeMap<String, Mask>,
    /// The fine-tuned task as a sparse difference from the backbone — the
    /// only parameter state a session hands upward. Checkpoint it with
    /// [`TaskDelta::save`]. Dense/LoRA-family deltas serve directly via
    /// `Server::from_delta`; VPT/adapter deltas carry their prompt/adapter
    /// state in `extra`, which the fwd graph cannot consume (the server
    /// constructor rejects them).
    pub delta: TaskDelta,
    pub calib_wall_ms: f64,
    pub train_wall_ms: f64,
}

pub struct FinetuneSession<'a> {
    rt: &'a Runtime,
    cfg: &'a ModelConfig,
    strategy: Strategy,
    train_cfg: TrainConfig,
    pub phase: Phase,
}

impl<'a> FinetuneSession<'a> {
    pub fn new(
        rt: &'a Runtime,
        config_name: &str,
        strategy: Strategy,
        train_cfg: TrainConfig,
    ) -> Result<FinetuneSession<'a>> {
        let cfg = rt.manifest().config(config_name)?;
        Ok(FinetuneSession { rt, cfg, strategy, train_cfg, phase: Phase::Init })
    }

    pub fn config(&self) -> &ModelConfig {
        self.cfg
    }

    /// Run the full pipeline on `backbone` (not mutated; dense training
    /// operates on a task-local copy with a freshly initialized head).
    pub fn run(
        &mut self,
        backbone: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
    ) -> Result<SessionResult> {
        let mut rng = Rng::new(self.train_cfg.seed ^ 0xf1ee7);
        let batch = self.rt.manifest().batch;
        if train.image_size != self.cfg.image_size {
            bail!(
                "dataset image size {} != config {}",
                train.image_size,
                self.cfg.image_size
            );
        }

        // Task-local parameters: fresh head per downstream task.
        let mut params = backbone.clone();
        params.reinit_head(&mut rng.fork("head"))?;

        // ---- Phase 1-2: calibration statistics (Alg. 1 steps 1-2) -------
        let t_cal = Instant::now();
        self.phase = Phase::Calibrate;
        let colnorms = if self.strategy.needs_calibration() {
            Some(self.calibrate(&params, train, batch)?)
        } else {
            None
        };
        let grad_scores = if self.strategy.needs_grad_scores() {
            Some(self.grad_scores(&params, train, batch)?)
        } else {
            None
        };
        let calib_wall_ms = t_cal.elapsed().as_secs_f64() * 1e3;

        // ---- Phase 3: allocation (Alg. 1 step 3) -------------------------
        self.phase = Phase::Allocate;
        let masks = self.strategy.build_masks(
            self.cfg,
            &params,
            colnorms.as_ref(),
            grad_scores.as_ref(),
            &mut rng.fork("alloc"),
        )?;
        let trainable = peft::trainable_params(&self.strategy, self.cfg, &masks);
        let frac = peft::trainable_fraction(&self.strategy, self.cfg, &masks);
        crate::info!(
            "[{}] strategy {} trainable {} ({:.4}%)",
            task_name,
            self.strategy.name(),
            trainable,
            frac * 100.0
        );

        // ---- Phase 4-5: sparse fine-tuning + eval ------------------------
        // Every family returns its tuned state as a TaskDelta against the
        // frozen backbone: full ParamStores never leave the session.
        self.phase = Phase::Train;
        let t_train = Instant::now();
        let (record, mut delta) = match self.strategy.family() {
            Family::Dense => {
                let (record, tuned) = self.train_dense(
                    params, &masks, train, eval, task_name, batch, &mut rng,
                )?;
                let delta = TaskDelta::extract(backbone, &tuned, &masks)?;
                (record, delta)
            }
            Family::Lora => {
                let (record, lb, la) = self.train_lora(
                    &params, &masks, train, eval, task_name, batch, &mut rng,
                )?;
                // fresh head (reinit) rides as a dense plane; factors +
                // masks carry the (B·A)⊙M weight delta of Eq. 6
                let mut delta = TaskDelta::diff(backbone, &params)?;
                for (name, b) in lb {
                    let a = la[&name].clone();
                    let mask = masks
                        .get(&name)
                        .with_context(|| format!("no lora mask for {name}"))?
                        .clone();
                    delta.lora.insert(name, LoraFactorDelta { b, a, mask });
                }
                (record, delta)
            }
            Family::Vpt => {
                let (record, state) = self.train_vpt(
                    &params, train, eval, task_name, batch, &mut rng,
                )?;
                (record, aux_delta(backbone, state)?)
            }
            Family::Adapter => {
                let (record, state) = self.train_adapter(
                    &params, train, eval, task_name, batch, &mut rng,
                )?;
                (record, aux_delta(backbone, state)?)
            }
        };
        delta.strategy = self.strategy.name();
        delta.task = task_name.to_string();
        let train_wall_ms = t_train.elapsed().as_secs_f64() * 1e3;
        self.phase = Phase::Done;

        let mut record = record;
        record.trainable_params = trainable;
        record.trainable_frac = frac;
        Ok(SessionResult {
            record,
            trainable_params: trainable,
            trainable_frac: frac,
            masks,
            delta,
            calib_wall_ms,
            train_wall_ms,
        })
    }

    // -----------------------------------------------------------------
    // Calibration
    // -----------------------------------------------------------------

    /// Run the calibrate artifact over the first `calib_batches` train
    /// batches, accumulating squared column norms per stat.
    fn calibrate(
        &self,
        params: &ParamStore,
        train: &Dataset,
        batch: usize,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let spec = self.rt.manifest().artifact_for("calibrate", &self.cfg.name)?;
        let art = spec.name.clone();
        let mut accs: BTreeMap<String, StatAccumulator> = BTreeMap::new();
        for out in &spec.outputs {
            let stat = out
                .name
                .strip_prefix("stat:")
                .context("calibrate outputs must be stat:*")?;
            accs.insert(stat.to_string(), StatAccumulator::new(out.shape[0]));
        }
        let mut batcher = Batcher::new(train.n, batch, self.train_cfg.seed ^ 0xca11b);
        let spec = spec.clone();
        for _ in 0..self.train_cfg.calib_batches {
            let ids = batcher.next_batch();
            let (images, _) = train.batch(&ids)?;
            let binder = IoBinder::new(&spec);
            let inputs = binder.bind(|io| {
                if let Some(p) = io.name.strip_prefix("param:") {
                    Ok(params.get(p)?.clone())
                } else if io.name == "images" {
                    Ok(images.clone())
                } else {
                    bail!("unexpected calibrate input {}", io.name)
                }
            })?;
            let outputs = self.rt.execute(&art, &inputs)?;
            for (out, spec_out) in outputs.iter().zip(&spec.outputs) {
                let stat = spec_out.name.strip_prefix("stat:").unwrap();
                accs.get_mut(stat).unwrap().add(out.f32s()?)?;
            }
        }
        Ok(accs
            .into_iter()
            .map(|(k, acc)| (k, acc.colnorms()))
            .collect())
    }

    /// GPS baseline scores: accumulated |∇W| over calibration batches.
    fn grad_scores(
        &self,
        params: &ParamStore,
        train: &Dataset,
        batch: usize,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let spec = self
            .rt
            .manifest()
            .artifact_for("grad_scores", &self.cfg.name)?
            .clone();
        let mut accs: BTreeMap<String, GradAccumulator> = BTreeMap::new();
        for out in &spec.outputs {
            let name = out
                .name
                .strip_prefix("gradmag:")
                .context("grad_scores outputs must be gradmag:*")?;
            accs.insert(name.to_string(), GradAccumulator::new(out.numel()));
        }
        let mut batcher = Batcher::new(train.n, batch, self.train_cfg.seed ^ 0x96ad);
        for _ in 0..self.train_cfg.calib_batches {
            let ids = batcher.next_batch();
            let (images, labels) = train.batch(&ids)?;
            let binder = IoBinder::new(&spec);
            let inputs = binder.bind(|io| {
                if let Some(p) = io.name.strip_prefix("param:") {
                    Ok(params.get(p)?.clone())
                } else if io.name == "images" {
                    Ok(images.clone())
                } else if io.name == "labels" {
                    Ok(labels.clone())
                } else {
                    bail!("unexpected grad_scores input {}", io.name)
                }
            })?;
            let outputs = self.rt.execute(&spec.name, &inputs)?;
            for (out, spec_out) in outputs.iter().zip(&spec.outputs) {
                let name = spec_out.name.strip_prefix("gradmag:").unwrap();
                accs.get_mut(name).unwrap().add(out.f32s()?)?;
            }
        }
        Ok(accs.into_iter().map(|(k, a)| (k, a.scores())).collect())
    }

    // -----------------------------------------------------------------
    // Dense-family training (TaskEdge + selective baselines)
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_dense(
        &self,
        mut params: ParamStore,
        masks: &BTreeMap<String, Mask>,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, ParamStore)> {
        let spec = self
            .rt
            .manifest()
            .artifact_for("train_adam", &self.cfg.name)?
            .clone();
        let mut m = ParamStore::zeros_like(self.cfg);
        let mut v = ParamStore::zeros_like(self.cfg);

        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mut batcher = Batcher::new(train.n, batch, rng.next_u64());
        let mask_tensors: BTreeMap<&String, HostTensor> =
            masks.iter().map(|(k, mk)| (k, mk.to_tensor())).collect();

        let mut record = self.new_record(task_name);
        let mut step = 0usize;
        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for _ in 0..steps_per_epoch {
                let ids = batcher.next_batch();
                let (images, labels) = train.batch(&ids)?;
                let lr = sched.at(step);
                step += 1;
                // hot path: borrow persistent state instead of cloning
                // ~4x model size per step (EXPERIMENTS.md §Perf)
                let inputs: Vec<crate::runtime::Bind<'_>> = spec
                    .inputs
                    .iter()
                    .map(|io| {
                        use crate::runtime::Bind;
                        if let Some(p) = io.name.strip_prefix("param:") {
                            Ok(Bind::Ref(params.get(p)?))
                        } else if let Some(p) = io.name.strip_prefix("mask:") {
                            mask_tensors
                                .get(&p.to_string())
                                .map(Bind::Ref)
                                .with_context(|| format!("no mask for {p}"))
                        } else if let Some(p) = io.name.strip_prefix("adam_m:") {
                            Ok(Bind::Ref(m.get(p)?))
                        } else if let Some(p) = io.name.strip_prefix("adam_v:") {
                            Ok(Bind::Ref(v.get(p)?))
                        } else {
                            match io.name.as_str() {
                                "step" => Ok(Bind::Own(HostTensor::scalar_f32(step as f32))),
                                "images" => Ok(Bind::Ref(&images)),
                                "labels" => Ok(Bind::Ref(&labels)),
                                "lr" => Ok(Bind::Own(HostTensor::scalar_f32(lr))),
                                "wd" => Ok(Bind::Own(HostTensor::scalar_f32(
                                    self.train_cfg.weight_decay,
                                ))),
                                other => bail!("unexpected train input {other}"),
                            }
                        }
                    })
                    .collect::<Result<_>>()?;
                let outputs = self.rt.execute_bound(&spec.name, &inputs)?;
                drop(inputs);
                // write back params / moments (moving the tensors — the
                // state vectors are ~4x the model size per step, so an
                // extra clone here is measurable; EXPERIMENTS.md §Perf);
                // grab loss + counts
                for (out, os) in outputs.into_iter().zip(&spec.outputs) {
                    if os.name == "loss" {
                        loss_sum += out.item_f32()? as f64;
                    } else if os.name == "n_correct" {
                        correct += out.item_f32()? as f64;
                    } else if let Some(p) = os.name.strip_prefix("param:") {
                        params.set(p, out)?;
                    } else if let Some(p) = os.name.strip_prefix("adam_m:") {
                        m.set(p, out)?;
                    } else if let Some(p) = os.name.strip_prefix("adam_v:") {
                        v.set(p, out)?;
                    }
                }
            }
            let em = self.maybe_eval(epoch, &params, eval, batch, |imgs, labs| {
                self.eval_dense(&params, imgs, labs)
            })?;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss: loss_sum / steps_per_epoch as f64,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
            crate::debug!(
                "[{task_name}] epoch {epoch} loss {:.4} top1 {:.3}",
                record.curve.last().unwrap().train_loss,
                em.1
            );
        }
        Ok((record, params))
    }

    fn eval_dense(
        &self,
        params: &ParamStore,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<(f64, f64, f64)> {
        let spec = self.rt.manifest().artifact_for("eval", &self.cfg.name)?.clone();
        let binder = IoBinder::new(&spec);
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else if io.name == "labels" {
                Ok(labels.clone())
            } else {
                bail!("unexpected eval input {}", io.name)
            }
        })?;
        let outputs = self.rt.execute(&spec.name, &inputs)?;
        Ok((
            binder.output(&outputs, "loss_sum")?.item_f32()? as f64,
            binder.output(&outputs, "n_correct")?.item_f32()? as f64,
            binder.output(&outputs, "top5_correct")?.item_f32()? as f64,
        ))
    }

    // -----------------------------------------------------------------
    // LoRA family (Eq. 6)
    // -----------------------------------------------------------------

    /// Returns the record plus the trained (B, A) factor maps keyed by
    /// target — the session folds them into the task's `TaskDelta`.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn train_lora(
        &self,
        params: &ParamStore,
        masks: &BTreeMap<String, Mask>,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(
        RunRecord,
        BTreeMap<String, HostTensor>,
        BTreeMap<String, HostTensor>,
    )> {
        // Task-local LoRA state: B zeros, A ~ N(0, 1/r).
        let shapes = lora_shapes(self.cfg);
        let r = self.cfg.lora_rank;
        let mut lb: BTreeMap<String, HostTensor> = BTreeMap::new();
        let mut la: BTreeMap<String, HostTensor> = BTreeMap::new();
        let mut mom: BTreeMap<String, HostTensor> = BTreeMap::new(); // mb/vb/ma/va keyed by "{grp}:{name}"
        let mut arng = rng.fork("lora_a");
        for (name, b_shape, a_shape) in &shapes {
            lb.insert(name.clone(), HostTensor::zeros(b_shape));
            let a_data = arng.normal_vec(a_shape.iter().product(), 1.0 / r as f32);
            la.insert(name.clone(), HostTensor::from_f32(a_shape, a_data)?);
            for grp in ["mb", "vb"] {
                mom.insert(format!("{grp}:{name}"), HostTensor::zeros(b_shape));
            }
            for grp in ["ma", "va"] {
                mom.insert(format!("{grp}:{name}"), HostTensor::zeros(a_shape));
            }
        }
        let mask_tensors: BTreeMap<String, HostTensor> =
            masks.iter().map(|(k, mk)| (k.clone(), mk.to_tensor())).collect();

        let spec = self
            .rt
            .manifest()
            .artifact_for("lora_train", &self.cfg.name)?
            .clone();
        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mut batcher = Batcher::new(train.n, batch, rng.next_u64());
        let mut record = self.new_record(task_name);
        let mut step = 0usize;

        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for _ in 0..steps_per_epoch {
                let ids = batcher.next_batch();
                let (images, labels) = train.batch(&ids)?;
                let lr = sched.at(step);
                step += 1;
                let binder = IoBinder::new(&spec);
                let inputs = binder.bind(|io| {
                    if let Some(p) = io.name.strip_prefix("param:") {
                        Ok(params.get(p)?.clone())
                    } else if let Some(p) = io.name.strip_prefix("lora_b:") {
                        Ok(lb[p].clone())
                    } else if let Some(p) = io.name.strip_prefix("lora_a:") {
                        Ok(la[p].clone())
                    } else if let Some(p) = io.name.strip_prefix("mask:") {
                        mask_tensors
                            .get(p)
                            .cloned()
                            .with_context(|| format!("no mask for {p}"))
                    } else if io.name.starts_with("mb:")
                        || io.name.starts_with("vb:")
                        || io.name.starts_with("ma:")
                        || io.name.starts_with("va:")
                    {
                        Ok(mom[&io.name].clone())
                    } else {
                        match io.name.as_str() {
                            "step" => Ok(HostTensor::scalar_f32(step as f32)),
                            "images" => Ok(images.clone()),
                            "labels" => Ok(labels.clone()),
                            "lr" => Ok(HostTensor::scalar_f32(lr)),
                            "wd" => Ok(HostTensor::scalar_f32(
                                self.train_cfg.weight_decay,
                            )),
                            other => bail!("unexpected lora input {other}"),
                        }
                    }
                })?;
                let outputs = self.rt.execute(&spec.name, &inputs)?;
                for (out, os) in outputs.iter().zip(&spec.outputs) {
                    if let Some(p) = os.name.strip_prefix("lora_b:") {
                        lb.insert(p.to_string(), out.clone());
                    } else if let Some(p) = os.name.strip_prefix("lora_a:") {
                        la.insert(p.to_string(), out.clone());
                    } else if os.name.starts_with("mb:")
                        || os.name.starts_with("vb:")
                        || os.name.starts_with("ma:")
                        || os.name.starts_with("va:")
                    {
                        mom.insert(os.name.clone(), out.clone());
                    } else if os.name == "loss" {
                        loss_sum += out.item_f32()? as f64;
                    } else if os.name == "n_correct" {
                        correct += out.item_f32()? as f64;
                    }
                }
            }
            let em = self.maybe_eval(epoch, params, eval, batch, |imgs, labs| {
                self.eval_lora(params, &lb, &la, &mask_tensors, imgs, labs)
            })?;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss: loss_sum / steps_per_epoch as f64,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        Ok((record, lb, la))
    }

    fn eval_lora(
        &self,
        params: &ParamStore,
        lb: &BTreeMap<String, HostTensor>,
        la: &BTreeMap<String, HostTensor>,
        mask_tensors: &BTreeMap<String, HostTensor>,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<(f64, f64, f64)> {
        let spec = self
            .rt
            .manifest()
            .artifact_for("lora_eval", &self.cfg.name)?
            .clone();
        let binder = IoBinder::new(&spec);
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if let Some(p) = io.name.strip_prefix("lora_b:") {
                Ok(lb[p].clone())
            } else if let Some(p) = io.name.strip_prefix("lora_a:") {
                Ok(la[p].clone())
            } else if let Some(p) = io.name.strip_prefix("mask:") {
                Ok(mask_tensors[p].clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else if io.name == "labels" {
                Ok(labels.clone())
            } else {
                bail!("unexpected lora_eval input {}", io.name)
            }
        })?;
        let outputs = self.rt.execute(&spec.name, &inputs)?;
        Ok((
            binder.output(&outputs, "loss_sum")?.item_f32()? as f64,
            binder.output(&outputs, "n_correct")?.item_f32()? as f64,
            binder.output(&outputs, "top5_correct")?.item_f32()? as f64,
        ))
    }

    // -----------------------------------------------------------------
    // VPT family
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_vpt(
        &self,
        params: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let mut prng = rng.fork("prompt");
        let prompt_shape = [self.cfg.prompt_len, self.cfg.dim];
        let mut state: BTreeMap<String, HostTensor> = BTreeMap::new();
        state.insert(
            "prompt".into(),
            HostTensor::from_f32(
                &prompt_shape,
                (0..prompt_shape.iter().product::<usize>())
                    .map(|_| prng.trunc_normal_f32(0.02))
                    .collect(),
            )?,
        );
        state.insert("head_w".into(), params.get("head.w")?.clone());
        state.insert("head_b".into(), params.get("head.b")?.clone());
        for grp in ["m", "v"] {
            for t in ["prompt", "head_w", "head_b"] {
                let shape = state[t].shape.clone();
                state.insert(format!("{grp}:{t}"), HostTensor::zeros(&shape));
            }
        }

        let spec = self
            .rt
            .manifest()
            .artifact_for("vpt_train", &self.cfg.name)?
            .clone();
        self.train_aux_family(
            params, state, spec, "vpt_eval", train, eval, task_name, batch, rng,
        )
    }

    // -----------------------------------------------------------------
    // Adapter family
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_adapter(
        &self,
        params: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let mut arng = rng.fork("adapter");
        let mut state: BTreeMap<String, HostTensor> = BTreeMap::new();
        for (name, shape) in &self.cfg.adapters {
            // down.w trunc normal; up.w and biases zero (identity at init)
            let key = format!("adapter:{name}");
            let numel: usize = shape.iter().product();
            let t = if name.ends_with("down.w") {
                HostTensor::from_f32(
                    shape,
                    (0..numel).map(|_| arng.trunc_normal_f32(0.02)).collect(),
                )?
            } else {
                HostTensor::zeros(shape)
            };
            state.insert(key, t);
        }
        state.insert("head_w".into(), params.get("head.w")?.clone());
        state.insert("head_b".into(), params.get("head.b")?.clone());
        let keys: Vec<String> = state.keys().cloned().collect();
        for grp in ["m", "v"] {
            for t in &keys {
                let shape = state[t].shape.clone();
                state.insert(format!("{grp}:{t}"), HostTensor::zeros(&shape));
            }
        }

        let spec = self
            .rt
            .manifest()
            .artifact_for("adapter_train", &self.cfg.name)?
            .clone();
        self.train_aux_family(
            params, state, spec, "adapter_eval", train, eval, task_name, batch,
            rng,
        )
    }

    /// Shared train loop for families whose trainable state is a flat named
    /// map (VPT, Adapter): inputs/outputs are matched by manifest names.
    /// Returns the final state so the session can fold it into a TaskDelta.
    #[allow(clippy::too_many_arguments)]
    fn train_aux_family(
        &self,
        params: &ParamStore,
        mut state: BTreeMap<String, HostTensor>,
        spec: crate::runtime::ArtifactSpec,
        eval_kind: &str,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mut batcher = Batcher::new(train.n, batch, rng.next_u64());
        let mut record = self.new_record(task_name);
        let mut step = 0usize;

        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for _ in 0..steps_per_epoch {
                let ids = batcher.next_batch();
                let (images, labels) = train.batch(&ids)?;
                let lr = sched.at(step);
                step += 1;
                let binder = IoBinder::new(&spec);
                let inputs = binder.bind(|io| {
                    if let Some(p) = io.name.strip_prefix("param:") {
                        Ok(params.get(p)?.clone())
                    } else if let Some(t) = state.get(&io.name) {
                        Ok(t.clone())
                    } else {
                        match io.name.as_str() {
                            "step" => Ok(HostTensor::scalar_f32(step as f32)),
                            "images" => Ok(images.clone()),
                            "labels" => Ok(labels.clone()),
                            "lr" => Ok(HostTensor::scalar_f32(lr)),
                            "wd" => Ok(HostTensor::scalar_f32(
                                self.train_cfg.weight_decay,
                            )),
                            other => bail!("unexpected aux input {other}"),
                        }
                    }
                })?;
                let outputs = self.rt.execute(&spec.name, &inputs)?;
                for (out, os) in outputs.iter().zip(&spec.outputs) {
                    if os.name == "loss" {
                        loss_sum += out.item_f32()? as f64;
                    } else if os.name == "n_correct" {
                        correct += out.item_f32()? as f64;
                    } else if os.name == "top5_correct" {
                        // ignored per-step
                    } else {
                        state.insert(os.name.clone(), out.clone());
                    }
                }
            }
            let em = self.maybe_eval(epoch, params, eval, batch, |imgs, labs| {
                self.eval_aux_family(params, &state, eval_kind, imgs, labs)
            })?;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss: loss_sum / steps_per_epoch as f64,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        Ok((record, state))
    }

    fn eval_aux_family(
        &self,
        params: &ParamStore,
        state: &BTreeMap<String, HostTensor>,
        eval_kind: &str,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<(f64, f64, f64)> {
        let spec = self
            .rt
            .manifest()
            .artifact_for(eval_kind, &self.cfg.name)?
            .clone();
        let binder = IoBinder::new(&spec);
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(params.get(p)?.clone())
            } else if let Some(t) = state.get(&io.name) {
                Ok(t.clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else if io.name == "labels" {
                Ok(labels.clone())
            } else {
                bail!("unexpected {eval_kind} input {}", io.name)
            }
        })?;
        let outputs = self.rt.execute(&spec.name, &inputs)?;
        Ok((
            binder.output(&outputs, "loss_sum")?.item_f32()? as f64,
            binder.output(&outputs, "n_correct")?.item_f32()? as f64,
            binder.output(&outputs, "top5_correct")?.item_f32()? as f64,
        ))
    }

    // -----------------------------------------------------------------
    // Shared eval driver
    // -----------------------------------------------------------------

    /// Evaluate on `eval` in exact batches (eval sets are generated as a
    /// multiple of the AOT batch size so no padding is needed). Returns
    /// (mean_loss, top1, top5); skipped epochs return the previous values.
    fn maybe_eval<F>(
        &self,
        epoch: usize,
        _params: &ParamStore,
        eval: &Dataset,
        batch: usize,
        mut eval_batch: F,
    ) -> Result<(f64, f64, f64)>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<(f64, f64, f64)>,
    {
        let last = epoch + 1 == self.train_cfg.epochs;
        if !last && (epoch + 1) % self.train_cfg.eval_every != 0 {
            return Ok((f64::NAN, f64::NAN, f64::NAN));
        }
        if eval.n % batch != 0 {
            bail!(
                "eval set size {} must be a multiple of batch {batch} \
                 (generate eval splits rounded up)",
                eval.n
            );
        }
        let mut loss = 0.0;
        let mut top1 = 0.0;
        let mut top5 = 0.0;
        for chunk_start in (0..eval.n).step_by(batch) {
            let ids: Vec<usize> = (chunk_start..chunk_start + batch).collect();
            let (images, labels) = eval.batch(&ids)?;
            let (l, c1, c5) = eval_batch(&images, &labels)?;
            loss += l;
            top1 += c1;
            top5 += c5;
        }
        let n = eval.n as f64;
        Ok((loss / n, top1 / n, top5 / n))
    }

    fn new_record(&self, task_name: &str) -> RunRecord {
        RunRecord {
            name: format!("{task_name}/{}", self.strategy.name()),
            task: task_name.to_string(),
            strategy: self.strategy.name(),
            ..Default::default()
        }
    }
}

/// Fold an aux-family (VPT/Adapter) final state map into a [`TaskDelta`]:
/// the trained head tensors become dense backbone planes, prompt/adapter
/// tensors ride in `extra` (they have no backbone slot), and the optimizer
/// moments (`m:*` / `v:*`) are dropped — they are session state, not task
/// state.
fn aux_delta(
    backbone: &ParamStore,
    state: BTreeMap<String, HostTensor>,
) -> Result<TaskDelta> {
    let mut delta = TaskDelta::new(&backbone.config_name);
    for (k, t) in state {
        if k.starts_with("m:") || k.starts_with("v:") {
            continue;
        }
        match k.as_str() {
            "head_w" => {
                delta.dense.insert("head.w".into(), t);
            }
            "head_b" => {
                delta.dense.insert("head.b".into(), t);
            }
            _ => {
                delta.extra.insert(k, t);
            }
        }
    }
    Ok(delta)
}
