//! The fine-tuning session state machine — TaskEdge's Alg. 1 as an edge
//! coordinator pipeline:
//!
//!   Calibrate -> Score -> Allocate -> Train -> Eval
//!
//! One session = one (task, strategy) pair on one backbone. All compute
//! graphs are AOT artifacts executed through the PJRT runtime; this module
//! only assembles named tensors per the manifest and accumulates metrics.
//!
//! # The prepared-training hot path
//!
//! A session executes thousands of train/calibrate/eval steps against
//! inputs that mostly *never change*: the frozen backbone (`param:*` for
//! the LoRA/VPT/Adapter families and every calibration/eval pass) and the
//! allocation masks (`mask:*`). Two structures keep that work out of the
//! per-step loop:
//!
//! - **`StepPlan`** — compiled once per artifact per session. Each input
//!   slot is classified (by `Routing`, the family's naming contract) into
//!   an enum-dispatched `SlotSrc`; each output slot into an `OutSink`. The
//!   per-step cost is an enum match per slot instead of a chain of
//!   string-prefix comparisons, and write-back *moves* output tensors into
//!   the stores (no clones).
//! - **Prepared literals** — the plan's frozen slots are converted to XLA
//!   literals once per session via [`Runtime::prepare`], keyed on a
//!   content-state generation (`ParamStore::generation` for pure-backbone
//!   sets, a freshly minted [`next_generation`] id for composed
//!   backbone+mask sets). Steps then convert only the batch tensors and
//!   scalars (`Runtime::execute_prepared`), so
//!   `RuntimeStats::param_prepares` stays O(1) per session for the
//!   frozen-backbone families — asserted by `tests/integration_prepared.rs`
//!   and `benches/hotpath.rs`. Dense-family training mutates `param:*`
//!   every step, so only its masks are frozen; its eval pass freezes the
//!   *current* parameters once on the first evaluated epoch and then
//!   refreshes that same set in place via [`Runtime::donate_writeback`]
//!   (new literals + resident buffers installed, then the generation
//!   bumps) instead of re-preparing per epoch.
//!
//! Batch assembly is overlapped with device execution by the
//! double-buffered `Prefetcher` (`data/prefetch.rs`): while the device
//! runs step *t*, a worker thread gathers the batch for *t+1* from the
//! same deterministic `Batcher` id stream the inline path used.
//!
//! `TrainConfig::prepared_io = false` selects the per-step conversion path
//! (same plans, no frozen literals). Both paths are bit-identical — the
//! same executables see the same input values — which
//! `tests/integration_prepared.rs` asserts and `benches/hotpath.rs` uses
//! as the measured baseline.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::{Batcher, Dataset, Prefetcher};
use crate::masking::{GradAccumulator, Mask, StatAccumulator};
use crate::metrics::{EpochMetrics, LrSchedule, RunRecord};
use crate::peft::{self, Family, Strategy};
use crate::runtime::{
    next_generation, ArtifactSpec, Bind, HostTensor, ModelConfig,
    PreparedParams, Runtime,
};
use crate::util::rng::Rng;
use crate::vit::{lora_shapes, LoraFactorDelta, ParamStore, TaskDelta};

/// Session hyperparameters (paper §IV-B: Adam, cosine decay, warmup).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// fraction of total steps used for linear warmup (paper: 10/100 epochs)
    pub warmup_frac: f32,
    pub seed: u64,
    /// batches of train data used for activation calibration
    pub calib_batches: usize,
    /// evaluate every k epochs (last epoch always evaluated)
    pub eval_every: usize,
    /// Convert the session's frozen inputs (backbone params, masks) to
    /// device literals once and reuse them every step (the default).
    /// `false` re-converts everything per step — numerically identical,
    /// kept as the measured baseline for `benches/hotpath.rs` and the
    /// equivalence tests.
    pub prepared_io: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 20,
            lr: 1e-3,
            weight_decay: 1e-4,
            warmup_frac: 0.1,
            seed: 0,
            calib_batches: 8,
            eval_every: 1,
            prepared_io: true,
        }
    }
}

/// Session phases (observable progress for the fleet scheduler / CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Init,
    Calibrate,
    Score,
    Allocate,
    Train,
    Eval,
    Done,
}

#[derive(Debug)]
pub struct SessionResult {
    pub record: RunRecord,
    pub trainable_params: usize,
    pub trainable_frac: f64,
    pub masks: BTreeMap<String, Mask>,
    /// The fine-tuned task as a sparse difference from the backbone — the
    /// only parameter state a session hands upward. Checkpoint it with
    /// [`TaskDelta::save`]. Dense/LoRA-family deltas serve directly via
    /// `Server::from_delta`; VPT/adapter deltas carry their prompt/adapter
    /// state in `extra`, which the fwd graph cannot consume (the server
    /// constructor rejects them).
    pub delta: TaskDelta,
    pub calib_wall_ms: f64,
    pub train_wall_ms: f64,
}

// ---------------------------------------------------------------------------
// Step plans: per-artifact input/output routing compiled once per session
// ---------------------------------------------------------------------------

/// The input-naming contract of an artifact family: which prefixes its
/// graph uses and which of those slots hold still for the plan's lifetime
/// (and are therefore frozen as device literals on the prepared path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Routing {
    /// `train_adam`/`train_sgd`: params+moments trained (dynamic), masks
    /// frozen
    Dense,
    /// dense `eval`: params frozen *for one eval pass* (the plan is
    /// re-prepared per evaluated epoch on the current generation)
    DenseEval,
    /// `lora_train`/`lora_eval`: backbone+masks frozen, factors+moments
    /// dynamic
    Lora,
    /// `vpt_*`/`adapter_*`: backbone frozen, named state map dynamic
    Aux,
    /// `calibrate`: backbone frozen, images only
    Calibrate,
    /// `grad_scores`: backbone frozen, images+labels
    GradScores,
}

/// Where an input slot's tensor comes from on each step. Resolved once at
/// plan compile time — the per-step cost is one enum dispatch per slot
/// instead of a string-prefix chain.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum SlotSrc {
    /// `param:*` — the session's parameter store
    Param(String),
    /// `mask:*` — the allocation's mask tensors
    Mask(String),
    /// `adam_m:*` — first-moment store (dense family)
    AdamM(String),
    /// `adam_v:*` — second-moment store (dense family)
    AdamV(String),
    /// `mom:*` — SGD momentum store (dense pretraining, `train_sgd`)
    Mom(String),
    /// any named tensor in the family's flat state map, keyed by the io
    /// name verbatim (LoRA factors+moments, VPT/adapter state)
    State(String),
    Images,
    Labels,
    Step,
    Lr,
    Wd,
}

/// Where an output lands after each step. `Skip` covers outputs the
/// driver reads positionally (eval triples, calibration stats) or ignores
/// (per-step top-5 counts).
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum OutSink {
    Loss,
    NCorrect,
    Skip,
    Param(String),
    AdamM(String),
    AdamV(String),
    Mom(String),
    State(String),
}

pub(crate) const LORA_STATE_PREFIXES: [&str; 6] =
    ["lora_b:", "lora_a:", "mb:", "vb:", "ma:", "va:"];

/// Classify one input slot under a routing: `(source, frozen)`. Unknown
/// names are a hard error — a graph input the session cannot source is a
/// manifest/session mismatch, caught at plan compile time instead of step
/// one.
pub(crate) fn classify_input(routing: Routing, name: &str) -> Result<(SlotSrc, bool)> {
    use Routing as R;
    use SlotSrc::*;
    if name == "images" {
        return Ok((Images, false));
    }
    if name == "labels" && routing != R::Calibrate {
        return Ok((Labels, false));
    }
    if let Some(p) = name.strip_prefix("param:") {
        // dense-family training moves params every step; every other
        // routing sees parameters that hold still for the plan's lifetime
        return Ok((Param(p.to_string()), routing != R::Dense));
    }
    if matches!(routing, R::Dense | R::Lora) {
        if let Some(p) = name.strip_prefix("mask:") {
            return Ok((Mask(p.to_string()), true));
        }
    }
    if routing == R::Dense {
        if let Some(p) = name.strip_prefix("adam_m:") {
            return Ok((AdamM(p.to_string()), false));
        }
        if let Some(p) = name.strip_prefix("adam_v:") {
            return Ok((AdamV(p.to_string()), false));
        }
        if let Some(p) = name.strip_prefix("mom:") {
            return Ok((Mom(p.to_string()), false));
        }
    }
    if matches!(routing, R::Dense | R::Lora | R::Aux) {
        match name {
            "step" => return Ok((Step, false)),
            "lr" => return Ok((Lr, false)),
            "wd" => return Ok((Wd, false)),
            _ => {}
        }
    }
    match routing {
        R::Lora if LORA_STATE_PREFIXES.iter().any(|p| name.starts_with(p)) => {
            Ok((State(name.to_string()), false))
        }
        // aux-family state is a flat named map (prompt, head_w, adapter:*,
        // m:*/v:* moments): route any remaining name there; a typo fails
        // at first resolution with the offending key
        R::Aux => Ok((State(name.to_string()), false)),
        _ => bail!("unexpected {routing:?} input {name:?}"),
    }
}

/// Classify one output slot. Never errors: drivers that read positionally
/// (calibrate/grad/eval) take `Skip` for everything, and unknown train
/// outputs are ignored exactly as the pre-plan loops ignored them.
pub(crate) fn classify_output(routing: Routing, name: &str) -> OutSink {
    use OutSink::*;
    use Routing as R;
    if matches!(routing, R::Calibrate | R::GradScores | R::DenseEval) {
        return Skip;
    }
    match name {
        "loss" => return Loss,
        "n_correct" => return NCorrect,
        _ => {}
    }
    match routing {
        R::Dense => {
            if let Some(p) = name.strip_prefix("param:") {
                return Param(p.to_string());
            }
            if let Some(p) = name.strip_prefix("adam_m:") {
                return AdamM(p.to_string());
            }
            if let Some(p) = name.strip_prefix("adam_v:") {
                return AdamV(p.to_string());
            }
            if let Some(p) = name.strip_prefix("mom:") {
                return Mom(p.to_string());
            }
        }
        R::Lora => {
            if LORA_STATE_PREFIXES.iter().any(|p| name.starts_with(p)) {
                return State(name.to_string());
            }
        }
        R::Aux if !matches!(name, "loss_sum" | "top5_correct") => {
            return State(name.to_string());
        }
        _ => {}
    }
    Skip
}

/// The named tensors a step can draw from: a struct of optional borrows
/// built (cheaply) per step / per plan-compile. One resolver replaces the
/// per-family binding closures; fields left `None` simply make the
/// corresponding slots unresolvable, which classification already rules
/// out per routing.
#[derive(Default, Clone, Copy)]
pub(crate) struct StepCtx<'t> {
    pub(crate) params: Option<&'t ParamStore>,
    pub(crate) masks: Option<&'t BTreeMap<String, HostTensor>>,
    pub(crate) adam_m: Option<&'t ParamStore>,
    pub(crate) adam_v: Option<&'t ParamStore>,
    /// SGD momentum store (`mom:*` — dense pretraining)
    pub(crate) mom: Option<&'t ParamStore>,
    pub(crate) state: Option<&'t BTreeMap<String, HostTensor>>,
    pub(crate) images: Option<&'t HostTensor>,
    pub(crate) labels: Option<&'t HostTensor>,
    pub(crate) step: Option<&'t HostTensor>,
    pub(crate) lr: Option<&'t HostTensor>,
    pub(crate) wd: Option<&'t HostTensor>,
}

impl<'t> StepCtx<'t> {
    fn resolve(&self, src: &SlotSrc) -> Result<&'t HostTensor> {
        match src {
            SlotSrc::Param(p) => self
                .params
                .context("artifact reads params this step does not bind")?
                .get(p),
            SlotSrc::Mask(p) => self
                .masks
                .and_then(|m| m.get(p))
                .with_context(|| format!("no mask tensor for {p:?}")),
            SlotSrc::AdamM(p) => self
                .adam_m
                .context("artifact reads adam_m state this step does not bind")?
                .get(p),
            SlotSrc::AdamV(p) => self
                .adam_v
                .context("artifact reads adam_v state this step does not bind")?
                .get(p),
            SlotSrc::Mom(p) => self
                .mom
                .context("artifact reads momentum state this step does not bind")?
                .get(p),
            SlotSrc::State(k) => self
                .state
                .and_then(|s| s.get(k))
                .with_context(|| format!("no session state tensor {k:?}")),
            SlotSrc::Images => self.images.context("no images bound this step"),
            SlotSrc::Labels => self.labels.context("no labels bound this step"),
            SlotSrc::Step => self.step.context("no step scalar bound"),
            SlotSrc::Lr => self.lr.context("no lr scalar bound"),
            SlotSrc::Wd => self.wd.context("no wd scalar bound"),
        }
    }
}

/// An artifact's step schedule, compiled once per session: every input
/// slot resolved to a [`SlotSrc`], every output to an [`OutSink`], and —
/// on the prepared path — the frozen slots converted to device literals.
#[derive(Clone)]
pub(crate) struct StepPlan {
    artifact: String,
    /// every input slot in manifest order
    srcs: Vec<SlotSrc>,
    /// ascending indices of slots frozen under this plan's routing
    frozen: Vec<usize>,
    /// `Some` on the prepared path: frozen slots as cached literals (and,
    /// by default, resident device buffers)
    prep: Option<Arc<PreparedParams>>,
    pub(crate) sinks: Vec<OutSink>,
}

impl StepPlan {
    /// Classify `spec`'s slots under `routing`; with `generation: Some`,
    /// also freeze the frozen slots via [`Runtime::prepare`], resolving
    /// their tensors from `frozen_ctx`.
    pub(crate) fn compile(
        rt: &Runtime,
        spec: &ArtifactSpec,
        routing: Routing,
        generation: Option<u64>,
        frozen_ctx: &StepCtx<'_>,
    ) -> Result<StepPlan> {
        let mut srcs = Vec::with_capacity(spec.inputs.len());
        let mut frozen = Vec::new();
        for (i, io) in spec.inputs.iter().enumerate() {
            let (src, freeze) = classify_input(routing, &io.name)
                .with_context(|| format!("compiling plan for {}", spec.name))?;
            if freeze {
                frozen.push(i);
            }
            srcs.push(src);
        }
        let sinks = spec
            .outputs
            .iter()
            .map(|o| classify_output(routing, &o.name))
            .collect();
        let plan = StepPlan {
            artifact: spec.name.clone(),
            srcs,
            frozen,
            prep: None,
            sinks,
        };
        match generation {
            Some(generation) => plan.prepared(rt, generation, frozen_ctx),
            None => Ok(plan),
        }
    }

    /// A copy of this plan with the frozen slots converted (or fetched
    /// from the runtime's generation-keyed cache) for `generation`.
    fn prepared(
        &self,
        rt: &Runtime,
        generation: u64,
        frozen_ctx: &StepCtx<'_>,
    ) -> Result<StepPlan> {
        let fixed = self
            .frozen
            .iter()
            .map(|&i| Ok((i, frozen_ctx.resolve(&self.srcs[i])?)))
            .collect::<Result<Vec<_>>>()?;
        let prep = rt.prepare(&self.artifact, generation, &fixed)?;
        Ok(StepPlan { prep: Some(prep), ..self.clone() })
    }

    /// The frozen slots re-resolved from `ctx` — the update list a dense
    /// session donates into its prepared eval set when the parameters
    /// move between evaluated epochs ([`Runtime::donate_writeback`]).
    fn donation_updates<'t>(
        &self,
        ctx: &StepCtx<'t>,
    ) -> Result<Vec<(usize, &'t HostTensor)>> {
        self.frozen
            .iter()
            .map(|&i| Ok((i, ctx.resolve(&self.srcs[i])?)))
            .collect()
    }

    /// Run one step. On the prepared path only the dynamic slots are
    /// resolved (and converted); otherwise every slot is bound by
    /// reference and converted this call (`Runtime::execute_bound`).
    pub(crate) fn execute(&self, rt: &Runtime, ctx: &StepCtx<'_>) -> Result<Vec<HostTensor>> {
        match &self.prep {
            Some(prep) => {
                let mut dynamics: Vec<&HostTensor> =
                    Vec::with_capacity(prep.dynamic_len());
                let mut f = 0usize;
                for (i, src) in self.srcs.iter().enumerate() {
                    if f < self.frozen.len() && self.frozen[f] == i {
                        f += 1;
                        continue;
                    }
                    dynamics.push(ctx.resolve(src)?);
                }
                rt.execute_prepared(prep, &dynamics)
            }
            None => {
                let binds = self
                    .srcs
                    .iter()
                    .map(|src| Ok(Bind::Ref(ctx.resolve(src)?)))
                    .collect::<Result<Vec<_>>>()?;
                rt.execute_bound(&self.artifact, &binds)
            }
        }
    }
}

/// An eval artifact's plan plus the positions of its three summary
/// outputs, resolved once instead of by-name per batch.
#[derive(Clone)]
struct EvalPlan {
    plan: StepPlan,
    i_loss: usize,
    i_top1: usize,
    i_top5: usize,
}

impl EvalPlan {
    fn new(spec: &ArtifactSpec, plan: StepPlan) -> Result<EvalPlan> {
        Ok(EvalPlan {
            i_loss: spec.output_index("loss_sum")?,
            i_top1: spec.output_index("n_correct")?,
            i_top5: spec.output_index("top5_correct")?,
            plan,
        })
    }

    fn read(&self, outs: &[HostTensor]) -> Result<(f64, f64, f64)> {
        Ok((
            outs[self.i_loss].item_f32()? as f64,
            outs[self.i_top1].item_f32()? as f64,
            outs[self.i_top5].item_f32()? as f64,
        ))
    }
}

/// Eval cadence predicate: epochs `eval_every - 1, 2*eval_every - 1, ...`
/// plus the final epoch.
fn eval_epoch(epochs: usize, eval_every: usize, epoch: usize) -> bool {
    epoch + 1 == epochs || (epoch + 1) % eval_every == 0
}

pub struct FinetuneSession<'a> {
    rt: &'a Runtime,
    cfg: &'a ModelConfig,
    strategy: Strategy,
    train_cfg: TrainConfig,
    pub phase: Phase,
}

impl<'a> FinetuneSession<'a> {
    pub fn new(
        rt: &'a Runtime,
        config_name: &str,
        strategy: Strategy,
        train_cfg: TrainConfig,
    ) -> Result<FinetuneSession<'a>> {
        let cfg = rt.manifest().config(config_name)?;
        Ok(FinetuneSession { rt, cfg, strategy, train_cfg, phase: Phase::Init })
    }

    pub fn config(&self) -> &ModelConfig {
        self.cfg
    }

    /// Pre-resolve and compile every executable this session's strategy
    /// will touch, so a fleet round's Warmup phase absorbs compilation and
    /// its Train phase measures training. Idempotent — the runtime's
    /// executable cache makes repeat calls free.
    pub fn warmup(&self) -> Result<()> {
        let mut kinds: Vec<&str> = Vec::new();
        if self.strategy.needs_calibration() {
            kinds.push("calibrate");
        }
        if self.strategy.needs_grad_scores() {
            kinds.push("grad_scores");
        }
        match self.strategy.family() {
            Family::Dense => kinds.extend(["train_adam", "eval"]),
            Family::Lora => kinds.extend(["lora_train", "lora_eval"]),
            Family::Vpt => kinds.extend(["vpt_train", "vpt_eval"]),
            Family::Adapter => kinds.extend(["adapter_train", "adapter_eval"]),
        }
        let mut names: Vec<&str> = Vec::with_capacity(kinds.len());
        for kind in kinds {
            names.push(
                self.rt.manifest().artifact_for(kind, &self.cfg.name)?.name.as_str(),
            );
        }
        self.rt.warmup(&names)
    }

    /// `Some(generation)` when the prepared path is on — the compile-time
    /// switch every plan construction funnels through.
    fn prep_gen(&self, generation: u64) -> Option<u64> {
        self.train_cfg.prepared_io.then_some(generation)
    }

    /// Eval cadence: every `eval_every` epochs, and always the last.
    fn should_eval(&self, epoch: usize) -> bool {
        eval_epoch(self.train_cfg.epochs, self.train_cfg.eval_every, epoch)
    }

    /// Run the full pipeline on `backbone` (not mutated; dense training
    /// operates on a task-local copy with a freshly initialized head).
    pub fn run(
        &mut self,
        backbone: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
    ) -> Result<SessionResult> {
        let mut rng = Rng::new(self.train_cfg.seed ^ 0xf1ee7);
        let batch = self.rt.manifest().batch;
        if train.image_size != self.cfg.image_size {
            bail!(
                "dataset image size {} != config {}",
                train.image_size,
                self.cfg.image_size
            );
        }

        // Task-local parameters: fresh head per downstream task.
        let mut params = backbone.clone();
        params.reinit_head(&mut rng.fork("head"))?;

        // ---- Phase 1-2: calibration statistics (Alg. 1 steps 1-2) -------
        let t_cal = Instant::now();
        self.phase = Phase::Calibrate;
        let colnorms = if self.strategy.needs_calibration() {
            Some(self.calibrate(&params, train, batch)?)
        } else {
            None
        };
        let grad_scores = if self.strategy.needs_grad_scores() {
            Some(self.grad_scores(&params, train, batch)?)
        } else {
            None
        };
        let calib_wall_ms = t_cal.elapsed().as_secs_f64() * 1e3;

        // ---- Phase 3: allocation (Alg. 1 step 3) -------------------------
        self.phase = Phase::Allocate;
        let masks = self.strategy.build_masks(
            self.cfg,
            &params,
            colnorms.as_ref(),
            grad_scores.as_ref(),
            &mut rng.fork("alloc"),
        )?;
        let trainable = peft::trainable_params(&self.strategy, self.cfg, &masks);
        let frac = peft::trainable_fraction(&self.strategy, self.cfg, &masks);
        crate::info!(
            "[{}] strategy {} trainable {} ({:.4}%)",
            task_name,
            self.strategy.name(),
            trainable,
            frac * 100.0
        );

        // ---- Phase 4-5: sparse fine-tuning + eval ------------------------
        // Every family returns its tuned state as a TaskDelta against the
        // frozen backbone: full ParamStores never leave the session.
        self.phase = Phase::Train;
        let t_train = Instant::now();
        let (record, mut delta) = match self.strategy.family() {
            Family::Dense => {
                let (record, tuned) = self.train_dense(
                    params, &masks, train, eval, task_name, batch, &mut rng,
                )?;
                let delta = TaskDelta::extract(backbone, &tuned, &masks)?;
                (record, delta)
            }
            Family::Lora => {
                let (record, lb, mut la) = self.train_lora(
                    &params, &masks, train, eval, task_name, batch, &mut rng,
                )?;
                // fresh head (reinit) rides as a dense plane; factors +
                // masks carry the (B·A)⊙M weight delta of Eq. 6
                let mut delta = TaskDelta::diff(backbone, &params)?;
                for (name, b) in lb {
                    let a = la
                        .remove(&name)
                        .with_context(|| format!("no lora A factor for {name}"))?;
                    let mask = masks
                        .get(&name)
                        .with_context(|| format!("no lora mask for {name}"))?
                        .clone();
                    delta.lora.insert(name, LoraFactorDelta { b, a, mask });
                }
                (record, delta)
            }
            Family::Vpt => {
                let (record, state) = self.train_vpt(
                    &params, train, eval, task_name, batch, &mut rng,
                )?;
                (record, aux_delta(backbone, state)?)
            }
            Family::Adapter => {
                let (record, state) = self.train_adapter(
                    &params, train, eval, task_name, batch, &mut rng,
                )?;
                (record, aux_delta(backbone, state)?)
            }
        };
        delta.strategy = self.strategy.name();
        delta.task = task_name.to_string();
        let train_wall_ms = t_train.elapsed().as_secs_f64() * 1e3;
        self.phase = Phase::Done;

        let mut record = record;
        record.trainable_params = trainable;
        record.trainable_frac = frac;
        Ok(SessionResult {
            record,
            trainable_params: trainable,
            trainable_frac: frac,
            masks,
            delta,
            calib_wall_ms,
            train_wall_ms,
        })
    }

    // -----------------------------------------------------------------
    // Calibration
    // -----------------------------------------------------------------

    /// Run the calibrate artifact over the first `calib_batches` train
    /// batches, accumulating squared column norms per stat. The frozen
    /// backbone is prepared once; only the image batch converts per step.
    fn calibrate(
        &self,
        params: &ParamStore,
        train: &Dataset,
        batch: usize,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let spec = self.rt.manifest().artifact_for("calibrate", &self.cfg.name)?;
        let mut accs: BTreeMap<String, StatAccumulator> = BTreeMap::new();
        let mut stat_names = Vec::with_capacity(spec.outputs.len());
        for out in &spec.outputs {
            let stat = out
                .name
                .strip_prefix("stat:")
                .context("calibrate outputs must be stat:*")?;
            accs.insert(stat.to_string(), StatAccumulator::new(out.shape[0]));
            stat_names.push(stat.to_string());
        }
        let frozen_ctx = StepCtx { params: Some(params), ..StepCtx::default() };
        let plan = StepPlan::compile(
            self.rt,
            spec,
            Routing::Calibrate,
            self.prep_gen(params.generation()),
            &frozen_ctx,
        )?;
        let mut batcher = Batcher::new(train.n, batch, self.train_cfg.seed ^ 0xca11b);
        for _ in 0..self.train_cfg.calib_batches {
            let ids = batcher.next_batch();
            let (images, _) = train.batch(&ids)?;
            let ctx = StepCtx {
                params: Some(params),
                images: Some(&images),
                ..StepCtx::default()
            };
            let outputs = plan.execute(self.rt, &ctx)?;
            for (out, stat) in outputs.iter().zip(&stat_names) {
                accs.get_mut(stat)
                    .with_context(|| format!("no accumulator for stat {stat:?}"))?
                    .add(out.f32s()?)?;
            }
        }
        Ok(accs
            .into_iter()
            .map(|(k, acc)| (k, acc.colnorms()))
            .collect())
    }

    /// GPS baseline scores: accumulated |∇W| over calibration batches.
    fn grad_scores(
        &self,
        params: &ParamStore,
        train: &Dataset,
        batch: usize,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let spec = self
            .rt
            .manifest()
            .artifact_for("grad_scores", &self.cfg.name)?;
        let mut accs: BTreeMap<String, GradAccumulator> = BTreeMap::new();
        let mut grad_names = Vec::with_capacity(spec.outputs.len());
        for out in &spec.outputs {
            let name = out
                .name
                .strip_prefix("gradmag:")
                .context("grad_scores outputs must be gradmag:*")?;
            accs.insert(name.to_string(), GradAccumulator::new(out.numel()));
            grad_names.push(name.to_string());
        }
        let frozen_ctx = StepCtx { params: Some(params), ..StepCtx::default() };
        let plan = StepPlan::compile(
            self.rt,
            spec,
            Routing::GradScores,
            self.prep_gen(params.generation()),
            &frozen_ctx,
        )?;
        let mut batcher = Batcher::new(train.n, batch, self.train_cfg.seed ^ 0x96ad);
        for _ in 0..self.train_cfg.calib_batches {
            let ids = batcher.next_batch();
            let (images, labels) = train.batch(&ids)?;
            let ctx = StepCtx {
                params: Some(params),
                images: Some(&images),
                labels: Some(&labels),
                ..StepCtx::default()
            };
            let outputs = plan.execute(self.rt, &ctx)?;
            for (out, name) in outputs.iter().zip(&grad_names) {
                accs.get_mut(name)
                    .with_context(|| format!("no accumulator for {name:?}"))?
                    .add(out.f32s()?)?;
            }
        }
        Ok(accs.into_iter().map(|(k, a)| (k, a.scores())).collect())
    }

    // -----------------------------------------------------------------
    // Dense-family training (TaskEdge + selective baselines)
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_dense(
        &self,
        mut params: ParamStore,
        masks: &BTreeMap<String, Mask>,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, ParamStore)> {
        let spec = self
            .rt
            .manifest()
            .artifact_for("train_adam", &self.cfg.name)?;
        let mut m = ParamStore::zeros_like(self.cfg);
        let mut v = ParamStore::zeros_like(self.cfg);

        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mask_tensors: BTreeMap<String, HostTensor> =
            masks.iter().map(|(k, mk)| (k.clone(), mk.to_tensor())).collect();

        // masks hold still for the whole session: freeze them once under a
        // fresh composed-set generation; params/moments flow through
        // dynamic slots (they move every step)
        let plan = StepPlan::compile(
            self.rt,
            spec,
            Routing::Dense,
            self.prep_gen(next_generation()),
            &StepCtx { masks: Some(&mask_tensors), ..StepCtx::default() },
        )?;
        // eval template: routing compiled once; the frozen-params set is
        // built on the first evaluated epoch and donation-refreshed (in
        // place, under the then-current generation) on later ones
        let eval_spec = self.rt.manifest().artifact_for("eval", &self.cfg.name)?;
        let eval_template = EvalPlan::new(
            eval_spec,
            StepPlan::compile(
                self.rt,
                eval_spec,
                Routing::DenseEval,
                None,
                &StepCtx::default(),
            )?,
        )?;

        let mut prefetch =
            Prefetcher::spawn(train, batch, rng.next_u64(), total_steps);
        let wd_t = HostTensor::scalar_f32(self.train_cfg.weight_decay);
        let mut record = self.new_record(task_name);
        // the prepared eval set persists across evaluated epochs: built
        // once, then refreshed in place by donation (the params moved, the
        // plan did not)
        let mut eval_prepared: Option<EvalPlan> = None;
        let mut step = 0usize;
        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            // overlap eval-batch assembly with the tail of this epoch's
            // train steps: the eval chunks are deterministic sequential
            // ranges, so a background worker can gather them while the
            // device is still training (bounded to double-buffer depth)
            let mut eval_fetch = self
                .should_eval(epoch)
                .then(|| Prefetcher::spawn_eval(eval, batch));
            for _ in 0..steps_per_epoch {
                let (images, labels) = prefetch.next()?;
                let lr = sched.at(step);
                step += 1;
                let step_t = HostTensor::scalar_f32(step as f32);
                let lr_t = HostTensor::scalar_f32(lr);
                let ctx = StepCtx {
                    params: Some(&params),
                    masks: Some(&mask_tensors),
                    adam_m: Some(&m),
                    adam_v: Some(&v),
                    images: Some(&images),
                    labels: Some(&labels),
                    step: Some(&step_t),
                    lr: Some(&lr_t),
                    wd: Some(&wd_t),
                    ..StepCtx::default()
                };
                let outputs = plan.execute(self.rt, &ctx)?;
                // write back params / moments (moving the tensors — the
                // state vectors are ~4x the model size per step, so an
                // extra clone here is measurable); grab loss + counts
                for (out, sink) in outputs.into_iter().zip(&plan.sinks) {
                    match sink {
                        OutSink::Loss => loss_sum += out.item_f32()? as f64,
                        OutSink::NCorrect => correct += out.item_f32()? as f64,
                        OutSink::Skip => {}
                        OutSink::Param(p) => params.set(p, out)?,
                        OutSink::AdamM(p) => m.set(p, out)?,
                        OutSink::AdamV(p) => v.set(p, out)?,
                        other => {
                            bail!("dense artifact has no sink {other:?}")
                        }
                    }
                }
            }
            let em = match eval_fetch.as_mut() {
                Some(fetch) => {
                    if self.train_cfg.prepared_io {
                        // params moved this epoch: refresh the prepared
                        // eval set under their *current* generation.
                        // First evaluated epoch builds the set; later ones
                        // donate the new params into it in place — the
                        // frozen slots are re-converted and re-uploaded,
                        // but nothing is re-prepared or re-registered
                        let frozen_ctx = StepCtx {
                            params: Some(&params),
                            ..StepCtx::default()
                        };
                        let donated = match &eval_prepared {
                            Some(ep) => match &ep.plan.prep {
                                Some(prep) => {
                                    let updates =
                                        ep.plan.donation_updates(&frozen_ctx)?;
                                    self.rt.donate_writeback(
                                        prep,
                                        params.generation(),
                                        &updates,
                                    )?;
                                    true
                                }
                                None => false,
                            },
                            None => false,
                        };
                        if !donated {
                            eval_prepared = Some(EvalPlan {
                                plan: eval_template.plan.prepared(
                                    self.rt,
                                    params.generation(),
                                    &frozen_ctx,
                                )?,
                                ..eval_template.clone()
                            });
                        }
                    }
                    let eplan =
                        match (&eval_prepared, self.train_cfg.prepared_io) {
                            (Some(ep), true) => ep,
                            _ => &eval_template,
                        };
                    self.eval_pass_from(eval, batch, fetch, |images, labels| {
                        let ctx = StepCtx {
                            params: Some(&params),
                            images: Some(images),
                            labels: Some(labels),
                            ..StepCtx::default()
                        };
                        let outs = eplan.plan.execute(self.rt, &ctx)?;
                        eplan.read(&outs)
                    })?
                }
                None => (f64::NAN, f64::NAN, f64::NAN),
            };
            let train_loss = loss_sum / steps_per_epoch as f64;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
            crate::debug!(
                "[{task_name}] epoch {epoch} loss {train_loss:.4} top1 {:.3}",
                em.1
            );
        }
        Ok((record, params))
    }

    // -----------------------------------------------------------------
    // LoRA family (Eq. 6)
    // -----------------------------------------------------------------

    /// Returns the record plus the trained (B, A) factor maps keyed by
    /// target — the session folds them into the task's `TaskDelta`.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn train_lora(
        &self,
        params: &ParamStore,
        masks: &BTreeMap<String, Mask>,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(
        RunRecord,
        BTreeMap<String, HostTensor>,
        BTreeMap<String, HostTensor>,
    )> {
        // Task-local LoRA state keyed by the io names verbatim: factors
        // (lora_b/lora_a — B zeros, A ~ N(0, 1/r)) and Adam moments
        // (mb/vb/ma/va) in one flat map so step I/O moves tensors in and
        // out without re-keying.
        let shapes = lora_shapes(self.cfg);
        let r = self.cfg.lora_rank;
        let mut state: BTreeMap<String, HostTensor> = BTreeMap::new();
        let mut arng = rng.fork("lora_a");
        for (name, b_shape, a_shape) in &shapes {
            state.insert(format!("lora_b:{name}"), HostTensor::zeros(b_shape));
            let a_data = arng.normal_vec(a_shape.iter().product(), 1.0 / r as f32);
            state.insert(
                format!("lora_a:{name}"),
                HostTensor::from_f32(a_shape, a_data)?,
            );
            for grp in ["mb", "vb"] {
                state.insert(format!("{grp}:{name}"), HostTensor::zeros(b_shape));
            }
            for grp in ["ma", "va"] {
                state.insert(format!("{grp}:{name}"), HostTensor::zeros(a_shape));
            }
        }
        let mask_tensors: BTreeMap<String, HostTensor> =
            masks.iter().map(|(k, mk)| (k.clone(), mk.to_tensor())).collect();

        let spec = self
            .rt
            .manifest()
            .artifact_for("lora_train", &self.cfg.name)?;
        // the frozen set here composes backbone + masks — no single store
        // describes it, so the session mints one content-state id for it;
        // train and eval share it (the cache keys on artifact name too)
        let session_gen = next_generation();
        let frozen_ctx = StepCtx {
            params: Some(params),
            masks: Some(&mask_tensors),
            ..StepCtx::default()
        };
        let plan = StepPlan::compile(
            self.rt,
            spec,
            Routing::Lora,
            self.prep_gen(session_gen),
            &frozen_ctx,
        )?;
        let eval_spec = self
            .rt
            .manifest()
            .artifact_for("lora_eval", &self.cfg.name)?;
        let eval_plan = EvalPlan::new(
            eval_spec,
            StepPlan::compile(
                self.rt,
                eval_spec,
                Routing::Lora,
                self.prep_gen(session_gen),
                &frozen_ctx,
            )?,
        )?;

        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mut prefetch =
            Prefetcher::spawn(train, batch, rng.next_u64(), total_steps);
        let wd_t = HostTensor::scalar_f32(self.train_cfg.weight_decay);
        let mut record = self.new_record(task_name);
        let mut step = 0usize;

        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for _ in 0..steps_per_epoch {
                let (images, labels) = prefetch.next()?;
                let lr = sched.at(step);
                step += 1;
                let step_t = HostTensor::scalar_f32(step as f32);
                let lr_t = HostTensor::scalar_f32(lr);
                let ctx = StepCtx {
                    params: Some(params),
                    masks: Some(&mask_tensors),
                    state: Some(&state),
                    images: Some(&images),
                    labels: Some(&labels),
                    step: Some(&step_t),
                    lr: Some(&lr_t),
                    wd: Some(&wd_t),
                    ..StepCtx::default()
                };
                let outputs = plan.execute(self.rt, &ctx)?;
                // factors + moments move back into the state map (these
                // were per-step clones before the plan refactor)
                for (out, sink) in outputs.into_iter().zip(&plan.sinks) {
                    match sink {
                        OutSink::Loss => loss_sum += out.item_f32()? as f64,
                        OutSink::NCorrect => correct += out.item_f32()? as f64,
                        OutSink::Skip => {}
                        OutSink::State(k) => {
                            *state
                                .get_mut(k)
                                .with_context(|| format!("no lora state {k:?}"))? =
                                out;
                        }
                        other => bail!("unexpected lora output sink {other:?}"),
                    }
                }
            }
            let em = self.eval_or_skip(epoch, eval, batch, |images, labels| {
                let ctx = StepCtx {
                    params: Some(params),
                    masks: Some(&mask_tensors),
                    state: Some(&state),
                    images: Some(images),
                    labels: Some(labels),
                    ..StepCtx::default()
                };
                let outs = eval_plan.plan.execute(self.rt, &ctx)?;
                eval_plan.read(&outs)
            })?;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss: loss_sum / steps_per_epoch as f64,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }

        let mut lb = BTreeMap::new();
        let mut la = BTreeMap::new();
        for (k, t) in state {
            if let Some(n) = k.strip_prefix("lora_b:") {
                lb.insert(n.to_string(), t);
            } else if let Some(n) = k.strip_prefix("lora_a:") {
                la.insert(n.to_string(), t);
            }
        }
        Ok((record, lb, la))
    }

    // -----------------------------------------------------------------
    // VPT family
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_vpt(
        &self,
        params: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let mut prng = rng.fork("prompt");
        let prompt_shape = [self.cfg.prompt_len, self.cfg.dim];
        let mut state: BTreeMap<String, HostTensor> = BTreeMap::new();
        state.insert(
            "prompt".into(),
            HostTensor::from_f32(
                &prompt_shape,
                (0..prompt_shape.iter().product::<usize>())
                    .map(|_| prng.trunc_normal_f32(0.02))
                    .collect(),
            )?,
        );
        state.insert("head_w".into(), params.get("head.w")?.clone());
        state.insert("head_b".into(), params.get("head.b")?.clone());
        for grp in ["m", "v"] {
            for t in ["prompt", "head_w", "head_b"] {
                let shape = state[t].shape.clone();
                state.insert(format!("{grp}:{t}"), HostTensor::zeros(&shape));
            }
        }

        let spec = self
            .rt
            .manifest()
            .artifact_for("vpt_train", &self.cfg.name)?;
        self.train_aux_family(
            params, state, spec, "vpt_eval", train, eval, task_name, batch, rng,
        )
    }

    // -----------------------------------------------------------------
    // Adapter family
    // -----------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn train_adapter(
        &self,
        params: &ParamStore,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let mut arng = rng.fork("adapter");
        let mut state: BTreeMap<String, HostTensor> = BTreeMap::new();
        for (name, shape) in &self.cfg.adapters {
            // down.w trunc normal; up.w and biases zero (identity at init)
            let key = format!("adapter:{name}");
            let numel: usize = shape.iter().product();
            let t = if name.ends_with("down.w") {
                HostTensor::from_f32(
                    shape,
                    (0..numel).map(|_| arng.trunc_normal_f32(0.02)).collect(),
                )?
            } else {
                HostTensor::zeros(shape)
            };
            state.insert(key, t);
        }
        state.insert("head_w".into(), params.get("head.w")?.clone());
        state.insert("head_b".into(), params.get("head.b")?.clone());
        let keys: Vec<String> = state.keys().cloned().collect();
        for grp in ["m", "v"] {
            for t in &keys {
                let shape = state[t].shape.clone();
                state.insert(format!("{grp}:{t}"), HostTensor::zeros(&shape));
            }
        }

        let spec = self
            .rt
            .manifest()
            .artifact_for("adapter_train", &self.cfg.name)?;
        self.train_aux_family(
            params, state, spec, "adapter_eval", train, eval, task_name, batch,
            rng,
        )
    }

    /// Shared train loop for families whose trainable state is a flat named
    /// map (VPT, Adapter). The backbone is frozen for the whole session —
    /// prepared once per artifact on the params' own generation — and the
    /// state tensors move through dynamic slots.
    /// Returns the final state so the session can fold it into a TaskDelta.
    #[allow(clippy::too_many_arguments)]
    fn train_aux_family(
        &self,
        params: &ParamStore,
        mut state: BTreeMap<String, HostTensor>,
        spec: &ArtifactSpec,
        eval_kind: &str,
        train: &Dataset,
        eval: &Dataset,
        task_name: &str,
        batch: usize,
        rng: &mut Rng,
    ) -> Result<(RunRecord, BTreeMap<String, HostTensor>)> {
        let frozen_ctx = StepCtx { params: Some(params), ..StepCtx::default() };
        let plan = StepPlan::compile(
            self.rt,
            spec,
            Routing::Aux,
            self.prep_gen(params.generation()),
            &frozen_ctx,
        )?;
        let eval_spec = self
            .rt
            .manifest()
            .artifact_for(eval_kind, &self.cfg.name)?;
        let eval_plan = EvalPlan::new(
            eval_spec,
            StepPlan::compile(
                self.rt,
                eval_spec,
                Routing::Aux,
                self.prep_gen(params.generation()),
                &frozen_ctx,
            )?,
        )?;

        let steps_per_epoch = train.n.div_ceil(batch);
        let total_steps = steps_per_epoch * self.train_cfg.epochs;
        let sched = LrSchedule::new(
            self.train_cfg.lr,
            (total_steps as f32 * self.train_cfg.warmup_frac) as usize,
            total_steps,
        );
        let mut prefetch =
            Prefetcher::spawn(train, batch, rng.next_u64(), total_steps);
        let wd_t = HostTensor::scalar_f32(self.train_cfg.weight_decay);
        let mut record = self.new_record(task_name);
        let mut step = 0usize;

        for epoch in 0..self.train_cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for _ in 0..steps_per_epoch {
                let (images, labels) = prefetch.next()?;
                let lr = sched.at(step);
                step += 1;
                let step_t = HostTensor::scalar_f32(step as f32);
                let lr_t = HostTensor::scalar_f32(lr);
                let ctx = StepCtx {
                    params: Some(params),
                    state: Some(&state),
                    images: Some(&images),
                    labels: Some(&labels),
                    step: Some(&step_t),
                    lr: Some(&lr_t),
                    wd: Some(&wd_t),
                    ..StepCtx::default()
                };
                let outputs = plan.execute(self.rt, &ctx)?;
                // updated state moves back into the map (was a per-step
                // clone per output before the plan refactor)
                for (out, sink) in outputs.into_iter().zip(&plan.sinks) {
                    match sink {
                        OutSink::Loss => loss_sum += out.item_f32()? as f64,
                        OutSink::NCorrect => correct += out.item_f32()? as f64,
                        OutSink::Skip => {}
                        OutSink::State(k) => {
                            *state
                                .get_mut(k)
                                .with_context(|| format!("no aux state {k:?}"))? =
                                out;
                        }
                        other => bail!("unexpected aux output sink {other:?}"),
                    }
                }
            }
            let em = self.eval_or_skip(epoch, eval, batch, |images, labels| {
                let ctx = StepCtx {
                    params: Some(params),
                    state: Some(&state),
                    images: Some(images),
                    labels: Some(labels),
                    ..StepCtx::default()
                };
                let outs = eval_plan.plan.execute(self.rt, &ctx)?;
                eval_plan.read(&outs)
            })?;
            record.curve.push(EpochMetrics {
                epoch,
                train_loss: loss_sum / steps_per_epoch as f64,
                train_acc: correct / (steps_per_epoch * batch) as f64,
                eval_loss: em.0,
                eval_top1: em.1,
                eval_top5: em.2,
                steps: steps_per_epoch,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        Ok((record, state))
    }

    // -----------------------------------------------------------------
    // Shared eval driver
    // -----------------------------------------------------------------

    /// Per-epoch eval step for loops whose eval plan is fixed for the
    /// whole session (LoRA/aux): a full pass on eval epochs, otherwise
    /// the NaN sentinel triple (serialized as `null` — see util/json.rs).
    /// Dense training refreshes its eval plan per pass (donation) and
    /// prefetches eval batches, so it branches on
    /// [`FinetuneSession::should_eval`] itself.
    fn eval_or_skip<F>(
        &self,
        epoch: usize,
        eval: &Dataset,
        batch: usize,
        eval_batch: F,
    ) -> Result<(f64, f64, f64)>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<(f64, f64, f64)>,
    {
        if !self.should_eval(epoch) {
            return Ok((f64::NAN, f64::NAN, f64::NAN));
        }
        self.eval_pass(eval, batch, eval_batch)
    }

    /// Evaluate on `eval` in exact batches (eval sets are generated as a
    /// multiple of the AOT batch size so no padding is needed). Returns
    /// (mean_loss, top1, top5).
    fn eval_pass<F>(
        &self,
        eval: &Dataset,
        batch: usize,
        mut eval_batch: F,
    ) -> Result<(f64, f64, f64)>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<(f64, f64, f64)>,
    {
        if eval.n % batch != 0 {
            bail!(
                "eval set size {} must be a multiple of batch {batch} \
                 (generate eval splits rounded up)",
                eval.n
            );
        }
        let mut loss = 0.0;
        let mut top1 = 0.0;
        let mut top5 = 0.0;
        for chunk_start in (0..eval.n).step_by(batch) {
            let ids: Vec<usize> = (chunk_start..chunk_start + batch).collect();
            let (images, labels) = eval.batch(&ids)?;
            let (l, c1, c5) = eval_batch(&images, &labels)?;
            loss += l;
            top1 += c1;
            top5 += c5;
        }
        let n = eval.n as f64;
        Ok((loss / n, top1 / n, top5 / n))
    }

    /// Like [`FinetuneSession::eval_pass`] but consuming pre-assembled
    /// batches from an eval prefetcher spawned at epoch start
    /// ([`Prefetcher::spawn_eval`]). The chunks are the same sequential
    /// ranges the inline path gathers, so the metrics are bit-identical —
    /// only the assembly overlaps the epoch's train tail.
    fn eval_pass_from<F>(
        &self,
        eval: &Dataset,
        batch: usize,
        fetch: &mut Prefetcher,
        mut eval_batch: F,
    ) -> Result<(f64, f64, f64)>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<(f64, f64, f64)>,
    {
        if eval.n % batch != 0 {
            bail!(
                "eval set size {} must be a multiple of batch {batch} \
                 (generate eval splits rounded up)",
                eval.n
            );
        }
        let mut loss = 0.0;
        let mut top1 = 0.0;
        let mut top5 = 0.0;
        for _ in (0..eval.n).step_by(batch) {
            let (images, labels) = fetch.next()?;
            let (l, c1, c5) = eval_batch(&images, &labels)?;
            loss += l;
            top1 += c1;
            top5 += c5;
        }
        let n = eval.n as f64;
        Ok((loss / n, top1 / n, top5 / n))
    }

    fn new_record(&self, task_name: &str) -> RunRecord {
        RunRecord {
            name: format!("{task_name}/{}", self.strategy.name()),
            task: task_name.to_string(),
            strategy: self.strategy.name(),
            ..Default::default()
        }
    }
}

/// Fold an aux-family (VPT/Adapter) final state map into a [`TaskDelta`]:
/// the trained head tensors become dense backbone planes, prompt/adapter
/// tensors ride in `extra` (they have no backbone slot), and the optimizer
/// moments (`m:*` / `v:*`) are dropped — they are session state, not task
/// state.
fn aux_delta(
    backbone: &ParamStore,
    state: BTreeMap<String, HostTensor>,
) -> Result<TaskDelta> {
    let mut delta = TaskDelta::new(&backbone.config_name);
    for (k, t) in state {
        if k.starts_with("m:") || k.starts_with("v:") {
            continue;
        }
        match k.as_str() {
            "head_w" => {
                delta.dense.insert("head.w".into(), t);
            }
            "head_b" => {
                delta.dense.insert("head.b".into(), t);
            }
            _ => {
                delta.extra.insert(k, t);
            }
        }
    }
    Ok(delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(routing: Routing, name: &str) -> SlotSrc {
        classify_input(routing, name).unwrap().0
    }

    fn frozen(routing: Routing, name: &str) -> bool {
        classify_input(routing, name).unwrap().1
    }

    #[test]
    fn input_routing_matches_family_contracts() {
        use Routing as R;
        // params: trained (dynamic) only under dense training
        assert_eq!(src(R::Dense, "param:head.w"), SlotSrc::Param("head.w".into()));
        assert!(!frozen(R::Dense, "param:head.w"));
        for r in [R::DenseEval, R::Lora, R::Aux, R::Calibrate, R::GradScores] {
            assert!(frozen(r, "param:head.w"), "{r:?} params must freeze");
        }
        // masks: frozen wherever they appear
        assert!(frozen(R::Dense, "mask:block0.attn.qkv.w"));
        assert!(frozen(R::Lora, "mask:head.w"));
        // optimizer moments are dense-only dynamics
        assert_eq!(src(R::Dense, "adam_m:head.w"), SlotSrc::AdamM("head.w".into()));
        assert!(!frozen(R::Dense, "adam_v:head.w"));
        // sgd momentum (pretraining's train_sgd) likewise
        assert_eq!(src(R::Dense, "mom:head.w"), SlotSrc::Mom("head.w".into()));
        assert!(!frozen(R::Dense, "mom:head.w"));
        // lora factors + moments route to the flat state map, dynamic
        for name in ["lora_b:head.w", "lora_a:head.w", "mb:head.w", "va:head.w"] {
            assert_eq!(src(R::Lora, name), SlotSrc::State(name.into()));
            assert!(!frozen(R::Lora, name));
        }
        // aux state is a catch-all over the named map
        assert_eq!(src(R::Aux, "prompt"), SlotSrc::State("prompt".into()));
        assert_eq!(src(R::Aux, "m:head_w"), SlotSrc::State("m:head_w".into()));
        // scalars + batch tensors
        assert_eq!(src(R::Dense, "lr"), SlotSrc::Lr);
        assert_eq!(src(R::Lora, "step"), SlotSrc::Step);
        assert_eq!(src(R::Aux, "wd"), SlotSrc::Wd);
        assert_eq!(src(R::Calibrate, "images"), SlotSrc::Images);
        assert_eq!(src(R::GradScores, "labels"), SlotSrc::Labels);
    }

    #[test]
    fn input_routing_rejects_misrouted_slots() {
        use Routing as R;
        // calibrate takes images only
        assert!(classify_input(R::Calibrate, "labels").is_err());
        assert!(classify_input(R::Calibrate, "lr").is_err());
        // dense artifacts have no lora factors; eval has no moments/masks
        assert!(classify_input(R::Dense, "lora_b:head.w").is_err());
        assert!(classify_input(R::DenseEval, "adam_m:head.w").is_err());
        assert!(classify_input(R::DenseEval, "mask:head.w").is_err());
        assert!(classify_input(R::DenseEval, "mom:head.w").is_err());
        assert!(classify_input(R::Lora, "mom:head.w").is_err());
        // scalar inputs only exist on the train/aux side
        assert!(classify_input(R::GradScores, "wd").is_err());
    }

    #[test]
    fn output_routing_moves_state_and_skips_summaries() {
        use Routing as R;
        assert_eq!(classify_output(R::Dense, "loss"), OutSink::Loss);
        assert_eq!(classify_output(R::Dense, "n_correct"), OutSink::NCorrect);
        assert_eq!(
            classify_output(R::Dense, "param:head.w"),
            OutSink::Param("head.w".into())
        );
        assert_eq!(
            classify_output(R::Dense, "adam_m:head.w"),
            OutSink::AdamM("head.w".into())
        );
        assert_eq!(
            classify_output(R::Dense, "mom:head.w"),
            OutSink::Mom("head.w".into())
        );
        assert_eq!(
            classify_output(R::Lora, "lora_b:head.w"),
            OutSink::State("lora_b:head.w".into())
        );
        assert_eq!(
            classify_output(R::Aux, "m:prompt"),
            OutSink::State("m:prompt".into())
        );
        // per-step top5 is ignored; eval triples are read positionally
        assert_eq!(classify_output(R::Aux, "top5_correct"), OutSink::Skip);
        assert_eq!(classify_output(R::Aux, "loss_sum"), OutSink::Skip);
        for name in ["loss_sum", "n_correct", "top5_correct", "stat:head.in"] {
            assert_eq!(classify_output(R::Calibrate, name), OutSink::Skip);
            assert_eq!(classify_output(R::DenseEval, name), OutSink::Skip);
        }
        assert_eq!(
            classify_output(R::GradScores, "gradmag:head.w"),
            OutSink::Skip
        );
    }

    #[test]
    fn step_ctx_resolution_and_missing_context_errors() {
        let images = HostTensor::ones(&[2, 2]);
        let mut state = BTreeMap::new();
        state.insert("prompt".to_string(), HostTensor::zeros(&[3]));
        let ctx = StepCtx {
            images: Some(&images),
            state: Some(&state),
            ..StepCtx::default()
        };
        assert_eq!(
            ctx.resolve(&SlotSrc::Images).unwrap().shape,
            vec![2, 2]
        );
        assert_eq!(
            ctx.resolve(&SlotSrc::State("prompt".into())).unwrap().shape,
            vec![3]
        );
        // a key the map lacks and a context the step never bound both fail
        assert!(ctx.resolve(&SlotSrc::State("nope".into())).is_err());
        assert!(ctx.resolve(&SlotSrc::Labels).is_err());
        assert!(ctx.resolve(&SlotSrc::Param("head.w".into())).is_err());
    }

    #[test]
    fn eval_cadence_hits_every_kth_and_the_last_epoch() {
        let evals: Vec<usize> = (0..5).filter(|&e| eval_epoch(5, 2, e)).collect();
        assert_eq!(evals, vec![1, 3, 4], "every 2nd epoch plus the last");
        let all: Vec<usize> = (0..3).filter(|&e| eval_epoch(3, 1, e)).collect();
        assert_eq!(all, vec![0, 1, 2], "eval_every=1 evaluates every epoch");
    }
}
