//! Deterministic fault injection for the fleet round engine.
//!
//! A [`FaultPlan`] is a seeded, declarative description of what goes wrong
//! during a round — job panics, device stalls, corrupted delta uploads,
//! device death at a phase boundary. The engine consults it at fixed
//! points ([`FaultPlan::panics`], [`FaultPlan::stall_ms`],
//! [`FaultPlan::corrupts`], [`FaultPlan::dies_at`]); the default plan is
//! empty and every hook early-returns, so the fault machinery costs
//! nothing when unused.
//!
//! Determinism contract: every decision is a pure function of
//! `(plan, seed, job id, attempt)` — the same plan replays the same faults
//! on every run, which is what makes the chaos bench
//! (`benches/fleet_faults.rs`) and the CI smoke job reproducible.

use anyhow::{bail, Context, Result};

use super::rounds::RoundState;
use crate::util::hash::seed_with;
use crate::util::rng::Rng;

/// A declarative, seeded fault schedule. Parse one from a CLI spec with
/// [`FaultPlan::parse`]; the [`Default`] plan injects nothing.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Probability that a job's *first* attempt panics (transient fault —
    /// the retry succeeds).
    panic_rate: f64,
    /// Jobs whose every attempt panics (hard fault — exhausts retries).
    panic_jobs: Vec<usize>,
    /// Probability that a job's first upload arrives corrupted.
    corrupt_rate: f64,
    /// Jobs whose first upload arrives corrupted (the retry is clean).
    corrupt_jobs: Vec<usize>,
    /// Per-device stall in milliseconds, applied to every train attempt on
    /// that device (straggler simulation).
    stalls: Vec<(String, u64)>,
    /// Devices that die on entering the named phase.
    deaths: Vec<(String, RoundState)>,
}

impl FaultPlan {
    /// Parse a comma-separated fault spec, e.g.
    /// `panic=0.3,stall=jetson-nano:800,corrupt@2,die=phone-flagship@train`.
    ///
    /// Clauses:
    /// - `panic=RATE`    — each job's first attempt panics with prob RATE
    /// - `panic@JOB`     — job JOB panics on every attempt (hard fault)
    /// - `corrupt=RATE`  — each job's first upload corrupted with prob RATE
    /// - `corrupt@JOB`   — job JOB's first upload corrupted
    /// - `stall=DEV:MS`  — device DEV sleeps MS ms before each attempt
    /// - `die=DEV@PHASE` — device DEV dies entering PHASE
    ///   (join|warmup|train|collect|cooldown)
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan { seed, ..FaultPlan::default() };
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty())
        {
            if let Some(rate) = clause.strip_prefix("panic=") {
                plan.panic_rate = parse_rate(clause, rate)?;
            } else if let Some(job) = clause.strip_prefix("panic@") {
                plan.panic_jobs.push(parse_job(clause, job)?);
            } else if let Some(rate) = clause.strip_prefix("corrupt=") {
                plan.corrupt_rate = parse_rate(clause, rate)?;
            } else if let Some(job) = clause.strip_prefix("corrupt@") {
                plan.corrupt_jobs.push(parse_job(clause, job)?);
            } else if let Some(rest) = clause.strip_prefix("stall=") {
                let (dev, ms) = rest.split_once(':').with_context(|| {
                    format!("fault clause {clause:?}: expected stall=DEV:MS")
                })?;
                let ms: u64 = ms.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "fault clause {clause:?}: MS must be an integer"
                    )
                })?;
                plan.stalls.push((dev.to_string(), ms));
            } else if let Some(rest) = clause.strip_prefix("die=") {
                let (dev, phase) = rest.split_once('@').with_context(|| {
                    format!("fault clause {clause:?}: expected die=DEV@PHASE")
                })?;
                let state = RoundState::parse(phase).with_context(|| {
                    format!("fault clause {clause:?}")
                })?;
                plan.deaths.push((dev.to_string(), state));
            } else {
                bail!(
                    "unknown fault clause {clause:?} (expected panic=RATE, \
                     panic@JOB, corrupt=RATE, corrupt@JOB, stall=DEV:MS, or \
                     die=DEV@PHASE)"
                );
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing — the default, zero-cost state.
    pub fn is_noop(&self) -> bool {
        self.panic_rate == 0.0
            && self.corrupt_rate == 0.0
            && self.panic_jobs.is_empty()
            && self.corrupt_jobs.is_empty()
            && self.stalls.is_empty()
            && self.deaths.is_empty()
    }

    /// Should this `(job, attempt)` panic inside the worker?
    pub fn panics(&self, job: usize, attempt: u32) -> bool {
        if self.panic_jobs.contains(&job) {
            return true;
        }
        if self.panic_rate > 0.0 && attempt == 1 {
            let label = format!("panic:{job}");
            return Rng::new(seed_with(self.seed, &label)).uniform()
                < self.panic_rate;
        }
        false
    }

    /// Should this `(job, attempt)`'s uploaded delta arrive corrupted?
    pub fn corrupts(&self, job: usize, attempt: u32) -> bool {
        if attempt != 1 {
            return false;
        }
        if self.corrupt_jobs.contains(&job) {
            return true;
        }
        if self.corrupt_rate > 0.0 {
            let label = format!("corrupt:{job}");
            return Rng::new(seed_with(self.seed, &label)).uniform()
                < self.corrupt_rate;
        }
        false
    }

    /// Milliseconds this device stalls before each train attempt.
    pub fn stall_ms(&self, device: &str) -> u64 {
        self.stalls
            .iter()
            .find(|(d, _)| d == device)
            .map(|(_, ms)| *ms)
            .unwrap_or(0)
    }

    /// Does this device die on entering `phase`?
    pub fn dies_at(&self, device: &str, phase: RoundState) -> bool {
        self.deaths.iter().any(|(d, p)| d == device && *p == phase)
    }

    /// One-line rendering for logs and the journal header.
    pub fn summary(&self) -> String {
        if self.is_noop() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.panic_rate > 0.0 {
            parts.push(format!("panic={}", self.panic_rate));
        }
        for j in &self.panic_jobs {
            parts.push(format!("panic@{j}"));
        }
        if self.corrupt_rate > 0.0 {
            parts.push(format!("corrupt={}", self.corrupt_rate));
        }
        for j in &self.corrupt_jobs {
            parts.push(format!("corrupt@{j}"));
        }
        for (d, ms) in &self.stalls {
            parts.push(format!("stall={d}:{ms}"));
        }
        for (d, p) in &self.deaths {
            parts.push(format!("die={d}@{}", p.name()));
        }
        parts.join(",")
    }
}

fn parse_rate(clause: &str, s: &str) -> Result<f64> {
    let r: f64 = s.parse().map_err(|_| {
        anyhow::anyhow!("fault clause {clause:?}: RATE must be a number")
    })?;
    if !(0.0..=1.0).contains(&r) {
        bail!("fault clause {clause:?}: RATE must be in [0, 1]");
    }
    Ok(r)
}

fn parse_job(clause: &str, s: &str) -> Result<usize> {
    s.parse().map_err(|_| {
        anyhow::anyhow!("fault clause {clause:?}: JOB must be a job index")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_noop_and_injects_nothing() {
        let p = FaultPlan::default();
        assert!(p.is_noop());
        for job in 0..16 {
            for attempt in 1..4 {
                assert!(!p.panics(job, attempt));
                assert!(!p.corrupts(job, attempt));
            }
        }
        assert_eq!(p.stall_ms("jetson-nano"), 0);
        assert!(!p.dies_at("jetson-nano", RoundState::Train));
        assert_eq!(p.summary(), "none");
    }

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "panic=0.5,panic@3,corrupt@2,stall=jetson-nano:800,\
             die=phone-flagship@train",
            7,
        )
        .unwrap();
        assert!(!p.is_noop());
        assert!(p.panics(3, 1) && p.panics(3, 2) && p.panics(3, 3));
        assert!(p.corrupts(2, 1) && !p.corrupts(2, 2));
        assert_eq!(p.stall_ms("jetson-nano"), 800);
        assert_eq!(p.stall_ms("jetson-orin-nano"), 0);
        assert!(p.dies_at("phone-flagship", RoundState::Train));
        assert!(!p.dies_at("phone-flagship", RoundState::Join));
    }

    #[test]
    fn rate_faults_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::parse("panic=0.5", 1).unwrap();
        let b = FaultPlan::parse("panic=0.5", 1).unwrap();
        let c = FaultPlan::parse("panic=0.5", 2).unwrap();
        let hits_a: Vec<bool> = (0..64).map(|j| a.panics(j, 1)).collect();
        let hits_b: Vec<bool> = (0..64).map(|j| b.panics(j, 1)).collect();
        let hits_c: Vec<bool> = (0..64).map(|j| c.panics(j, 1)).collect();
        assert_eq!(hits_a, hits_b);
        assert_ne!(hits_a, hits_c);
        let n = hits_a.iter().filter(|&&h| h).count();
        assert!(n > 16 && n < 48, "rate 0.5 hit {n}/64 jobs");
        // transient: rate-driven panics hit only the first attempt
        assert!((0..64).all(|j| !a.panics(j, 2) || a.panic_jobs.contains(&j)));
    }

    #[test]
    fn malformed_specs_are_hard_errors() {
        for bad in [
            "panic=2.0",
            "panic=abc",
            "panic@x",
            "stall=jetson-nano",
            "stall=jetson-nano:ms",
            "die=jetson-nano@nowhere",
            "explode=1",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn summary_round_trips_through_parse() {
        let spec = "panic=0.25,corrupt@1,stall=jetson-nano:50,die=pi@join";
        let p = FaultPlan::parse(spec, 9).unwrap();
        let q = FaultPlan::parse(&p.summary(), 9).unwrap();
        assert_eq!(p.summary(), q.summary());
    }
}
