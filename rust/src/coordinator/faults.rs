//! Deterministic fault injection for the fleet round engine.
//!
//! A [`FaultPlan`] is a seeded, declarative description of what goes wrong
//! during a round — job panics, device stalls, corrupted delta uploads,
//! device death at a phase boundary. The engine consults it at fixed
//! points ([`FaultPlan::panics`], [`FaultPlan::stall_ms`],
//! [`FaultPlan::corrupts`], [`FaultPlan::dies_at`]); the default plan is
//! empty and every hook early-returns, so the fault machinery costs
//! nothing when unused.
//!
//! Determinism contract: every decision is a pure function of
//! `(plan, seed, job id, attempt)` — the same plan replays the same faults
//! on every run, which is what makes the chaos bench
//! (`benches/fleet_faults.rs`) and the CI smoke job reproducible.

use std::collections::BTreeSet;

use anyhow::{bail, Context, Result};

use super::rounds::RoundState;
use crate::util::hash::seed_with;
use crate::util::rng::Rng;

/// A declarative, seeded fault schedule. Parse one from a CLI spec with
/// [`FaultPlan::parse`]; the [`Default`] plan injects nothing.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Probability that a job's *first* attempt panics (transient fault —
    /// the retry succeeds).
    panic_rate: f64,
    /// Jobs whose every attempt panics (hard fault — exhausts retries).
    panic_jobs: Vec<usize>,
    /// Probability that a job's first upload arrives corrupted.
    corrupt_rate: f64,
    /// Jobs whose first upload arrives corrupted (the retry is clean).
    corrupt_jobs: Vec<usize>,
    /// Per-device stall in milliseconds, applied to every train attempt on
    /// that device (straggler simulation).
    stalls: Vec<(String, u64)>,
    /// Devices that die on entering the named phase.
    deaths: Vec<(String, RoundState)>,
    /// Probability that an outbound frame is silently dropped on the wire.
    net_drop_rate: f64,
    /// Probability that an outbound frame is sent twice.
    net_dup_rate: f64,
    /// Probability that an outbound frame's payload is flipped after the
    /// checksum is computed (the receiver detects it and reconnects).
    net_corrupt_rate: f64,
    /// Flat delay in milliseconds before every outbound frame.
    net_delay_ms: u64,
    /// Participants that drop their connection on entering the named phase
    /// (once per process — they reconnect and resume).
    disconnects: Vec<(String, RoundState)>,
    /// The primary coordinator process "crashes" on entering the named
    /// phase: the round engine bails without a summary, as if killed -9.
    /// HA failover testing — a standby is expected to take over.
    kill_primary: Option<RoundState>,
    /// Probability that a shipped journal entry is silently lost on its
    /// way to the standby (the standby re-runs those jobs on promotion).
    ship_drop_rate: f64,
}

impl FaultPlan {
    /// Parse a comma-separated fault spec, e.g.
    /// `panic=0.3,stall=jetson-nano:800,corrupt@2,die=phone-flagship@train`.
    ///
    /// Clauses:
    /// - `panic=RATE`         — each job's first attempt panics with prob RATE
    /// - `panic@JOB`          — job JOB panics on every attempt (hard fault)
    /// - `corrupt=RATE`       — each job's first upload corrupted with prob RATE
    /// - `corrupt@JOB`        — job JOB's first upload corrupted
    /// - `stall=DEV:MS`       — device DEV sleeps MS ms before each attempt
    /// - `die=DEV@PHASE`      — device DEV dies entering PHASE
    ///   (join|warmup|train|collect|cooldown)
    /// - `netdrop=RATE`       — each outbound frame dropped with prob RATE
    /// - `netdup=RATE`        — each outbound frame duplicated with prob RATE
    /// - `netcorrupt=RATE`    — each outbound frame corrupted with prob RATE
    /// - `netdelay=MS`        — MS ms delay before every outbound frame
    /// - `disconnect=DEV@PHASE` — participant DEV drops its connection on
    ///   entering PHASE (once), then reconnects
    /// - `killprimary@PHASE`  — the primary coordinator dies entering PHASE
    ///   (the round engine bails mid-round; a standby should promote)
    /// - `shipdrop=RATE`      — each journal entry shipped to the standby
    ///   is silently lost with prob RATE
    ///
    /// Each fault key may appear at most once (per target for the `@`/`:`
    /// forms): `panic=0.1,panic=0.2` and `stall=pi:5,stall=pi:9` are both
    /// rejected, naming the duplicated key. An unrecognized kind is
    /// rejected naming the bad token.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan { seed, ..FaultPlan::default() };
        // identity of each clause for duplicate detection: the kind plus its
        // target (job / device / device@phase), but never its value — two
        // settings for the same knob are a conflict even if they agree
        let mut seen: BTreeSet<String> = BTreeSet::new();
        let mut claim = |key: String| -> Result<()> {
            if !seen.insert(key.clone()) {
                bail!("duplicate fault key {key:?} — each key may appear once");
            }
            Ok(())
        };
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty())
        {
            if let Some(rate) = clause.strip_prefix("panic=") {
                claim("panic=".into())?;
                plan.panic_rate = parse_rate(clause, rate)?;
            } else if let Some(job) = clause.strip_prefix("panic@") {
                let job = parse_job(clause, job)?;
                claim(format!("panic@{job}"))?;
                plan.panic_jobs.push(job);
            } else if let Some(rate) = clause.strip_prefix("corrupt=") {
                claim("corrupt=".into())?;
                plan.corrupt_rate = parse_rate(clause, rate)?;
            } else if let Some(job) = clause.strip_prefix("corrupt@") {
                let job = parse_job(clause, job)?;
                claim(format!("corrupt@{job}"))?;
                plan.corrupt_jobs.push(job);
            } else if let Some(rest) = clause.strip_prefix("stall=") {
                let (dev, ms) = rest.split_once(':').with_context(|| {
                    format!("fault clause {clause:?}: expected stall=DEV:MS")
                })?;
                let ms: u64 = ms.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "fault clause {clause:?}: MS must be an integer"
                    )
                })?;
                claim(format!("stall={dev}"))?;
                plan.stalls.push((dev.to_string(), ms));
            } else if let Some(rest) = clause.strip_prefix("die=") {
                let (dev, state) = parse_dev_phase(clause, rest, "die")?;
                claim(format!("die={dev}@{}", state.name()))?;
                plan.deaths.push((dev, state));
            } else if let Some(rate) = clause.strip_prefix("netdrop=") {
                claim("netdrop=".into())?;
                plan.net_drop_rate = parse_rate(clause, rate)?;
            } else if let Some(rate) = clause.strip_prefix("netdup=") {
                claim("netdup=".into())?;
                plan.net_dup_rate = parse_rate(clause, rate)?;
            } else if let Some(rate) = clause.strip_prefix("netcorrupt=") {
                claim("netcorrupt=".into())?;
                plan.net_corrupt_rate = parse_rate(clause, rate)?;
            } else if let Some(ms) = clause.strip_prefix("netdelay=") {
                claim("netdelay=".into())?;
                plan.net_delay_ms = ms.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "fault clause {clause:?}: MS must be an integer"
                    )
                })?;
            } else if let Some(rest) = clause.strip_prefix("disconnect=") {
                let (dev, state) = parse_dev_phase(clause, rest, "disconnect")?;
                claim(format!("disconnect={dev}@{}", state.name()))?;
                plan.disconnects.push((dev, state));
            } else if let Some(phase) = clause.strip_prefix("killprimary@") {
                let state = RoundState::parse(phase)
                    .with_context(|| format!("fault clause {clause:?}"))?;
                claim("killprimary@".into())?;
                plan.kill_primary = Some(state);
            } else if let Some(rate) = clause.strip_prefix("shipdrop=") {
                claim("shipdrop=".into())?;
                plan.ship_drop_rate = parse_rate(clause, rate)?;
            } else {
                // name the kind token, not just the whole clause: the kind
                // is everything before the first '=' / '@' separator
                let kind =
                    clause.split(['=', '@']).next().unwrap_or(clause);
                bail!(
                    "unknown fault kind {kind:?} in clause {clause:?} \
                     (expected panic=RATE, panic@JOB, corrupt=RATE, \
                     corrupt@JOB, stall=DEV:MS, die=DEV@PHASE, netdrop=RATE, \
                     netdup=RATE, netcorrupt=RATE, netdelay=MS, \
                     disconnect=DEV@PHASE, killprimary@PHASE, or \
                     shipdrop=RATE)"
                );
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing — the default, zero-cost state.
    pub fn is_noop(&self) -> bool {
        self.panic_rate == 0.0
            && self.corrupt_rate == 0.0
            && self.panic_jobs.is_empty()
            && self.corrupt_jobs.is_empty()
            && self.stalls.is_empty()
            && self.deaths.is_empty()
            && self.net_drop_rate == 0.0
            && self.net_dup_rate == 0.0
            && self.net_corrupt_rate == 0.0
            && self.net_delay_ms == 0
            && self.disconnects.is_empty()
            && self.kill_primary.is_none()
            && self.ship_drop_rate == 0.0
    }

    /// Should this `(job, attempt)` panic inside the worker?
    pub fn panics(&self, job: usize, attempt: u32) -> bool {
        if self.panic_jobs.contains(&job) {
            return true;
        }
        if self.panic_rate > 0.0 && attempt == 1 {
            let label = format!("panic:{job}");
            return Rng::new(seed_with(self.seed, &label)).uniform()
                < self.panic_rate;
        }
        false
    }

    /// Should this `(job, attempt)`'s uploaded delta arrive corrupted?
    pub fn corrupts(&self, job: usize, attempt: u32) -> bool {
        if attempt != 1 {
            return false;
        }
        if self.corrupt_jobs.contains(&job) {
            return true;
        }
        if self.corrupt_rate > 0.0 {
            let label = format!("corrupt:{job}");
            return Rng::new(seed_with(self.seed, &label)).uniform()
                < self.corrupt_rate;
        }
        false
    }

    /// Milliseconds this device stalls before each train attempt.
    pub fn stall_ms(&self, device: &str) -> u64 {
        self.stalls
            .iter()
            .find(|(d, _)| d == device)
            .map(|(_, ms)| *ms)
            .unwrap_or(0)
    }

    /// Does this device die on entering `phase`?
    pub fn dies_at(&self, device: &str, phase: RoundState) -> bool {
        self.deaths.iter().any(|(d, p)| d == device && *p == phase)
    }

    /// Does this participant drop its connection on entering `phase`?
    /// (Unlike [`dies_at`](FaultPlan::dies_at), the participant reconnects
    /// — the caller is responsible for firing it only once per process.)
    pub fn disconnects_at(&self, device: &str, phase: RoundState) -> bool {
        self.disconnects.iter().any(|(d, p)| d == device && *p == phase)
    }

    /// Should the outbound frame with this per-connection sequence number
    /// be dropped? Pure function of `(plan seed, seq)`.
    pub fn net_drops(&self, seq: u64) -> bool {
        net_rate_hit(self.seed, self.net_drop_rate, "netdrop", seq)
    }

    /// Should this outbound frame be sent twice?
    pub fn net_dups(&self, seq: u64) -> bool {
        net_rate_hit(self.seed, self.net_dup_rate, "netdup", seq)
    }

    /// Should this outbound frame's payload be flipped after checksumming?
    pub fn net_corrupts(&self, seq: u64) -> bool {
        net_rate_hit(self.seed, self.net_corrupt_rate, "netcorrupt", seq)
    }

    /// Flat delay applied before every outbound frame.
    pub fn net_delay_ms(&self) -> u64 {
        self.net_delay_ms
    }

    /// Does the primary coordinator "crash" on entering `phase`? The
    /// round engine bails out mid-round, simulating kill -9: no summary
    /// entry is written and the process abandons its listener.
    pub fn kills_primary_at(&self, phase: RoundState) -> bool {
        self.kill_primary == Some(phase)
    }

    /// Should the shipped journal entry with this sequence number be
    /// silently lost before it reaches the standby? Pure function of
    /// `(plan seed, seq)`.
    pub fn ship_drops(&self, seq: u64) -> bool {
        net_rate_hit(self.seed, self.ship_drop_rate, "shipdrop", seq)
    }

    /// Does the plan inject any wire-level fault? (Lets the writer path
    /// skip the fault bookkeeping entirely for clean runs.)
    pub fn has_net_faults(&self) -> bool {
        self.net_drop_rate > 0.0
            || self.net_dup_rate > 0.0
            || self.net_corrupt_rate > 0.0
            || self.net_delay_ms > 0
    }

    /// One-line rendering for logs and the journal header.
    pub fn summary(&self) -> String {
        if self.is_noop() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.panic_rate > 0.0 {
            parts.push(format!("panic={}", self.panic_rate));
        }
        for j in &self.panic_jobs {
            parts.push(format!("panic@{j}"));
        }
        if self.corrupt_rate > 0.0 {
            parts.push(format!("corrupt={}", self.corrupt_rate));
        }
        for j in &self.corrupt_jobs {
            parts.push(format!("corrupt@{j}"));
        }
        for (d, ms) in &self.stalls {
            parts.push(format!("stall={d}:{ms}"));
        }
        for (d, p) in &self.deaths {
            parts.push(format!("die={d}@{}", p.name()));
        }
        if self.net_drop_rate > 0.0 {
            parts.push(format!("netdrop={}", self.net_drop_rate));
        }
        if self.net_dup_rate > 0.0 {
            parts.push(format!("netdup={}", self.net_dup_rate));
        }
        if self.net_corrupt_rate > 0.0 {
            parts.push(format!("netcorrupt={}", self.net_corrupt_rate));
        }
        if self.net_delay_ms > 0 {
            parts.push(format!("netdelay={}", self.net_delay_ms));
        }
        for (d, p) in &self.disconnects {
            parts.push(format!("disconnect={d}@{}", p.name()));
        }
        if let Some(p) = self.kill_primary {
            parts.push(format!("killprimary@{}", p.name()));
        }
        if self.ship_drop_rate > 0.0 {
            parts.push(format!("shipdrop={}", self.ship_drop_rate));
        }
        parts.join(",")
    }
}

/// Shared draw for per-frame wire faults: deterministic in
/// `(seed, kind, seq)` so the same plan replays the same frame fates.
fn net_rate_hit(seed: u64, rate: f64, kind: &str, seq: u64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    let label = format!("{kind}:{seq}");
    Rng::new(seed_with(seed, &label)).uniform() < rate
}

/// Parse the `DEV@PHASE` form shared by `die=` and `disconnect=`.
fn parse_dev_phase(
    clause: &str,
    rest: &str,
    kind: &str,
) -> Result<(String, RoundState)> {
    let (dev, phase) = rest.split_once('@').with_context(|| {
        format!("fault clause {clause:?}: expected {kind}=DEV@PHASE")
    })?;
    let state = RoundState::parse(phase)
        .with_context(|| format!("fault clause {clause:?}"))?;
    Ok((dev.to_string(), state))
}

fn parse_rate(clause: &str, s: &str) -> Result<f64> {
    let r: f64 = s.parse().map_err(|_| {
        anyhow::anyhow!("fault clause {clause:?}: RATE must be a number")
    })?;
    if !(0.0..=1.0).contains(&r) {
        bail!("fault clause {clause:?}: RATE must be in [0, 1]");
    }
    Ok(r)
}

fn parse_job(clause: &str, s: &str) -> Result<usize> {
    s.parse().map_err(|_| {
        anyhow::anyhow!("fault clause {clause:?}: JOB must be a job index")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_noop_and_injects_nothing() {
        let p = FaultPlan::default();
        assert!(p.is_noop());
        for job in 0..16 {
            for attempt in 1..4 {
                assert!(!p.panics(job, attempt));
                assert!(!p.corrupts(job, attempt));
            }
        }
        assert_eq!(p.stall_ms("jetson-nano"), 0);
        assert!(!p.dies_at("jetson-nano", RoundState::Train));
        assert_eq!(p.summary(), "none");
    }

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "panic=0.5,panic@3,corrupt@2,stall=jetson-nano:800,\
             die=phone-flagship@train",
            7,
        )
        .unwrap();
        assert!(!p.is_noop());
        assert!(p.panics(3, 1) && p.panics(3, 2) && p.panics(3, 3));
        assert!(p.corrupts(2, 1) && !p.corrupts(2, 2));
        assert_eq!(p.stall_ms("jetson-nano"), 800);
        assert_eq!(p.stall_ms("jetson-orin-nano"), 0);
        assert!(p.dies_at("phone-flagship", RoundState::Train));
        assert!(!p.dies_at("phone-flagship", RoundState::Join));
    }

    #[test]
    fn rate_faults_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::parse("panic=0.5", 1).unwrap();
        let b = FaultPlan::parse("panic=0.5", 1).unwrap();
        let c = FaultPlan::parse("panic=0.5", 2).unwrap();
        let hits_a: Vec<bool> = (0..64).map(|j| a.panics(j, 1)).collect();
        let hits_b: Vec<bool> = (0..64).map(|j| b.panics(j, 1)).collect();
        let hits_c: Vec<bool> = (0..64).map(|j| c.panics(j, 1)).collect();
        assert_eq!(hits_a, hits_b);
        assert_ne!(hits_a, hits_c);
        let n = hits_a.iter().filter(|&&h| h).count();
        assert!(n > 16 && n < 48, "rate 0.5 hit {n}/64 jobs");
        // transient: rate-driven panics hit only the first attempt
        assert!((0..64).all(|j| !a.panics(j, 2) || a.panic_jobs.contains(&j)));
    }

    #[test]
    fn malformed_specs_are_hard_errors() {
        for bad in [
            "panic=2.0",
            "panic=abc",
            "panic@x",
            "stall=jetson-nano",
            "stall=jetson-nano:ms",
            "die=jetson-nano@nowhere",
            "explode=1",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn summary_round_trips_through_parse() {
        let spec = "panic=0.25,corrupt@1,stall=jetson-nano:50,die=pi@join";
        let p = FaultPlan::parse(spec, 9).unwrap();
        let q = FaultPlan::parse(&p.summary(), 9).unwrap();
        assert_eq!(p.summary(), q.summary());
    }

    #[test]
    fn net_clauses_parse_and_round_trip() {
        let spec = "netdrop=0.2,netdup=0.1,netcorrupt=0.05,netdelay=15,\
                    disconnect=pi@train";
        let p = FaultPlan::parse(spec, 11).unwrap();
        assert!(!p.is_noop());
        assert!(p.has_net_faults());
        assert_eq!(p.net_delay_ms(), 15);
        assert!(p.disconnects_at("pi", RoundState::Train));
        assert!(!p.disconnects_at("pi", RoundState::Join));
        assert!(!p.disconnects_at("jetson-nano", RoundState::Train));
        let q = FaultPlan::parse(&p.summary(), 11).unwrap();
        assert_eq!(p.summary(), q.summary());
        // the engine-side death hook is untouched by disconnect clauses
        assert!(!p.dies_at("pi", RoundState::Train));
    }

    #[test]
    fn ha_clauses_parse_and_round_trip() {
        let spec = "killprimary@collect,shipdrop=0.3";
        let p = FaultPlan::parse(spec, 13).unwrap();
        assert!(!p.is_noop());
        assert!(p.kills_primary_at(RoundState::Collect));
        assert!(!p.kills_primary_at(RoundState::Train));
        let q = FaultPlan::parse(&p.summary(), 13).unwrap();
        assert_eq!(p.summary(), q.summary());
        // shipdrop draws deterministically and independently of netdrop
        let hits: Vec<bool> = (0..64).map(|s| p.ship_drops(s)).collect();
        let again: Vec<bool> = (0..64).map(|s| p.ship_drops(s)).collect();
        assert_eq!(hits, again);
        assert!(hits.iter().any(|&h| h) && hits.iter().any(|&h| !h));
        let nd = FaultPlan::parse("netdrop=0.3", 13).unwrap();
        assert_ne!(hits, (0..64).map(|s| nd.net_drops(s)).collect::<Vec<_>>());
        // value errors keep their specific messages
        for bad in ["killprimary@nowhere", "shipdrop=7"] {
            let err = FaultPlan::parse(bad, 0).unwrap_err().to_string();
            assert!(!err.contains("unknown fault kind"), "{err}");
        }
        // duplicates rejected
        for dup in
            ["killprimary@train,killprimary@train", "shipdrop=0.1,shipdrop=0.2"]
        {
            let err = FaultPlan::parse(dup, 0).unwrap_err().to_string();
            assert!(err.contains("duplicate fault key"), "{err}");
        }
    }

    #[test]
    fn net_rate_faults_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::parse("netdrop=0.5", 1).unwrap();
        let b = FaultPlan::parse("netdrop=0.5", 1).unwrap();
        let c = FaultPlan::parse("netdrop=0.5", 2).unwrap();
        let hits_a: Vec<bool> = (0..64).map(|s| a.net_drops(s)).collect();
        let hits_b: Vec<bool> = (0..64).map(|s| b.net_drops(s)).collect();
        let hits_c: Vec<bool> = (0..64).map(|s| c.net_drops(s)).collect();
        assert_eq!(hits_a, hits_b);
        assert_ne!(hits_a, hits_c);
        let n = hits_a.iter().filter(|&&h| h).count();
        assert!(n > 16 && n < 48, "rate 0.5 hit {n}/64 frames");
        // kinds draw independently: same seed, different streams
        let hits_dup: Vec<bool> =
            (0..64).map(|s| FaultPlan::parse("netdup=0.5", 1).unwrap().net_dups(s)).collect();
        assert_ne!(hits_a, hits_dup);
    }

    #[test]
    fn duplicate_fault_keys_are_rejected_naming_the_key() {
        for (spec, key) in [
            ("panic=0.1,panic=0.2", "panic="),
            ("panic=0.1,panic=0.1", "panic="),
            ("corrupt=0.1,stall=pi:5,corrupt=0.3", "corrupt="),
            ("panic@3,panic@3", "panic@3"),
            ("stall=pi:5,stall=pi:9", "stall=pi"),
            ("die=pi@train,die=pi@train", "die=pi@train"),
            ("netdrop=0.1,netdrop=0.2", "netdrop="),
            ("netdelay=5,netdelay=6", "netdelay="),
            ("disconnect=pi@train,disconnect=pi@train", "disconnect=pi@train"),
        ] {
            let err = FaultPlan::parse(spec, 0).unwrap_err().to_string();
            assert!(
                err.contains("duplicate fault key") && err.contains(key),
                "{spec:?}: error {err:?} should name key {key:?}"
            );
        }
        // distinct targets are NOT duplicates
        for ok in [
            "panic@1,panic@2",
            "stall=pi:5,stall=jetson-nano:9",
            "die=pi@train,die=pi@collect",
            "disconnect=pi@train,disconnect=jetson-nano@train",
        ] {
            assert!(FaultPlan::parse(ok, 0).is_ok(), "{ok:?} rejected");
        }
    }

    #[test]
    fn unknown_fault_kinds_are_rejected_naming_the_token() {
        for (spec, kind) in [
            ("explode=1", "explode"),
            ("pani=0.5", "pani"),
            ("netdrip=0.5", "netdrip"),
            ("frobnicate@3", "frobnicate"),
            ("disconnect:pi@train", "disconnect:pi@train"),
        ] {
            let err = FaultPlan::parse(spec, 0).unwrap_err().to_string();
            assert!(
                err.contains("unknown fault kind")
                    && err.contains(&format!("\"{kind}\"")),
                "{spec:?}: error {err:?} should name kind {kind:?}"
            );
        }
        // malformed values on KNOWN kinds keep their specific errors
        for bad in ["netdrop=2.0", "netdelay=soon", "disconnect=pi@nowhere"] {
            let err = FaultPlan::parse(bad, 0).unwrap_err().to_string();
            assert!(
                !err.contains("unknown fault kind"),
                "{bad:?}: got the unknown-kind error, want a value error: {err:?}"
            );
        }
    }
}
