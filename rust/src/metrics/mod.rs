//! Metrics: training curves, accuracy summaries, JSONL run logs, and the
//! learning-rate schedule the paper uses (cosine decay + linear warmup).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Paper §IV-B: cosine decay over total epochs with linear warmup.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: usize, total_steps: usize) -> LrSchedule {
        LrSchedule { base_lr, warmup_steps, total_steps, min_lr: 0.0 }
    }

    pub fn at(&self, step: usize) -> f32 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps.max(1) as f32;
        }
        let t = (step - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        let t = t.clamp(0.0, 1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr)
                * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// One epoch's aggregate metrics.
#[derive(Debug, Clone, Default)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub eval_loss: f64,
    pub eval_top1: f64,
    pub eval_top5: f64,
    pub steps: usize,
    pub wall_ms: f64,
}

/// Full run record: per-epoch curve + final summary.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub name: String,
    pub task: String,
    pub strategy: String,
    pub trainable_params: usize,
    pub trainable_frac: f64,
    pub curve: Vec<EpochMetrics>,
}

impl RunRecord {
    pub fn final_top1(&self) -> f64 {
        self.curve.last().map(|e| e.eval_top1).unwrap_or(0.0)
    }

    pub fn best_top1(&self) -> f64 {
        self.curve.iter().map(|e| e.eval_top1).fold(0.0, f64::max)
    }

    pub fn best_top5(&self) -> f64 {
        self.curve.iter().map(|e| e.eval_top5).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("task", self.task.as_str().into()),
            ("strategy", self.strategy.as_str().into()),
            ("trainable_params", self.trainable_params.into()),
            ("trainable_frac", self.trainable_frac.into()),
            (
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("epoch", e.epoch.into()),
                                ("train_loss", e.train_loss.into()),
                                ("train_acc", e.train_acc.into()),
                                ("eval_loss", e.eval_loss.into()),
                                ("eval_top1", e.eval_top1.into()),
                                ("eval_top5", e.eval_top5.into()),
                                ("steps", e.steps.into()),
                                ("wall_ms", e.wall_ms.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Append-only JSONL log writer for run records and events.
pub struct JsonlLogger {
    file: std::fs::File,
}

impl JsonlLogger {
    pub fn create(path: &Path) -> Result<JsonlLogger> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening log {path:?}"))?;
        Ok(JsonlLogger { file })
    }

    pub fn log(&mut self, value: &Json) -> Result<()> {
        writeln!(self.file, "{value}")?;
        Ok(())
    }
}

/// Streaming mean/min/max accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn add(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_cosine() {
        let s = LrSchedule::new(1.0, 10, 110);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-4);
        assert!(s.at(60) < s.at(10));
        assert!(s.at(109) < 0.01);
        // monotone decay after warmup
        for i in 10..109 {
            assert!(s.at(i + 1) <= s.at(i) + 1e-7);
        }
    }

    #[test]
    fn run_record_best() {
        let mut r = RunRecord::default();
        for (e, acc) in [(0, 0.1), (1, 0.6), (2, 0.5)] {
            r.curve.push(EpochMetrics { epoch: e, eval_top1: acc, ..Default::default() });
        }
        assert_eq!(r.best_top1(), 0.6);
        assert_eq!(r.final_top1(), 0.5);
    }

    #[test]
    fn summary_stats() {
        let mut s = Summary::default();
        for v in [2.0, 4.0, 6.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 6.0);
    }

    #[test]
    fn jsonl_logger_writes() {
        let path = std::env::temp_dir().join("taskedge_test_log.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut log = JsonlLogger::create(&path).unwrap();
            log.log(&Json::obj(vec![("a", 1usize.into())])).unwrap();
            log.log(&Json::obj(vec![("b", 2usize.into())])).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }
}
