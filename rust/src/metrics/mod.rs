//! Metrics: training curves, accuracy summaries, JSONL run logs, latency
//! histograms for the serving engine, and the learning-rate schedule the
//! paper uses (cosine decay + linear warmup).

use std::io::Write;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Paper §IV-B: cosine decay over total epochs with linear warmup.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: usize, total_steps: usize) -> LrSchedule {
        LrSchedule { base_lr, warmup_steps, total_steps, min_lr: 0.0 }
    }

    pub fn at(&self, step: usize) -> f32 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps.max(1) as f32;
        }
        let t = (step - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        let t = t.clamp(0.0, 1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr)
                * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// One epoch's aggregate metrics.
#[derive(Debug, Clone, Default)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub eval_loss: f64,
    pub eval_top1: f64,
    pub eval_top5: f64,
    pub steps: usize,
    pub wall_ms: f64,
}

/// Full run record: per-epoch curve + final summary.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub name: String,
    pub task: String,
    pub strategy: String,
    pub trainable_params: usize,
    pub trainable_frac: f64,
    pub curve: Vec<EpochMetrics>,
}

impl RunRecord {
    pub fn final_top1(&self) -> f64 {
        self.curve.last().map(|e| e.eval_top1).unwrap_or(0.0)
    }

    pub fn best_top1(&self) -> f64 {
        self.curve.iter().map(|e| e.eval_top1).fold(0.0, f64::max)
    }

    pub fn best_top5(&self) -> f64 {
        self.curve.iter().map(|e| e.eval_top5).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("task", self.task.as_str().into()),
            ("strategy", self.strategy.as_str().into()),
            ("trainable_params", self.trainable_params.into()),
            ("trainable_frac", self.trainable_frac.into()),
            (
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("epoch", e.epoch.into()),
                                ("train_loss", e.train_loss.into()),
                                ("train_acc", e.train_acc.into()),
                                ("eval_loss", e.eval_loss.into()),
                                ("eval_top1", e.eval_top1.into()),
                                ("eval_top5", e.eval_top5.into()),
                                ("steps", e.steps.into()),
                                ("wall_ms", e.wall_ms.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a record previously written by [`RunRecord::to_json`].
    /// Skipped-epoch eval metrics serialize as `null` (the JSON layer has
    /// no NaN literal); they come back as `f64::NAN`, so the
    /// write-read round trip is lossless for every finite value and maps
    /// non-finite values to NaN.
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        let s = |key: &str| -> Result<String> {
            Ok(j.req(key)?
                .as_str()
                .with_context(|| format!("{key} must be a string"))?
                .to_string())
        };
        // only an explicit null (a skipped epoch's metric) reads as NaN; a
        // missing or non-numeric key is a malformed record and hard-errors
        // like every other field
        let num = |e: &Json, key: &str| -> Result<f64> {
            match e.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v
                    .as_f64()
                    .with_context(|| format!("{key} must be a number or null")),
            }
        };
        let curve = j
            .req("curve")?
            .as_arr()
            .context("curve must be an array")?
            .iter()
            .map(|e| {
                Ok(EpochMetrics {
                    epoch: e.req("epoch")?.as_usize().context("epoch")?,
                    train_loss: num(e, "train_loss")?,
                    train_acc: num(e, "train_acc")?,
                    eval_loss: num(e, "eval_loss")?,
                    eval_top1: num(e, "eval_top1")?,
                    eval_top5: num(e, "eval_top5")?,
                    steps: e.req("steps")?.as_usize().context("steps")?,
                    wall_ms: num(e, "wall_ms")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunRecord {
            name: s("name")?,
            task: s("task")?,
            strategy: s("strategy")?,
            trainable_params: j
                .req("trainable_params")?
                .as_usize()
                .context("trainable_params")?,
            trainable_frac: j
                .req("trainable_frac")?
                .as_f64()
                .context("trainable_frac")?,
            curve,
        })
    }
}

/// Append-only JSONL log writer for run records and events.
pub struct JsonlLogger {
    file: std::fs::File,
}

impl JsonlLogger {
    pub fn create(path: &Path) -> Result<JsonlLogger> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening log {path:?}"))?;
        Ok(JsonlLogger { file })
    }

    pub fn log(&mut self, value: &Json) -> Result<()> {
        writeln!(self.file, "{value}")?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Latency histogram
// ---------------------------------------------------------------------------

/// 2^HIST_SUB_BITS linear sub-buckets per power of two. Buckets span a
/// 1/2^HIST_SUB_BITS relative range and quantiles report the bucket's
/// inclusive upper bound, so the worst-case relative error is ~25%
/// (conservative, never under-reports) — ample for p50/p95/p99 serving
/// reports.
const HIST_SUB_BITS: u32 = 2;
const HIST_SUBS: usize = 1 << HIST_SUB_BITS;
/// 4 exact buckets for 0..4ns plus 62 octaves × 4 sub-buckets covers the
/// entire u64 nanosecond range in 252 counters.
const HIST_BUCKETS: usize = HIST_SUBS + (64 - HIST_SUB_BITS as usize) * HIST_SUBS;

/// Fixed-footprint log-bucketed latency histogram (HDR-style): O(1)
/// `record`, mergeable across servers/tasks, approximate quantiles with
/// bounded relative error. Samples are nanoseconds; `record` never
/// allocates, so it is safe to call under the serving stats lock.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bucket(ns: u64) -> usize {
        if ns < HIST_SUBS as u64 {
            return ns as usize;
        }
        let octave = 63 - ns.leading_zeros(); // >= HIST_SUB_BITS here
        let sub = ((ns >> (octave - HIST_SUB_BITS)) as usize) & (HIST_SUBS - 1);
        (HIST_SUBS + (octave - HIST_SUB_BITS) as usize * HIST_SUBS + sub)
            .min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (quantiles report this bound).
    fn bucket_bound(i: usize) -> u64 {
        if i < HIST_SUBS {
            return i as u64;
        }
        let octave = (i - HIST_SUBS) / HIST_SUBS;
        let sub = (i - HIST_SUBS) % HIST_SUBS;
        let hi = ((HIST_SUBS + sub + 1) as u128) << octave;
        (hi - 1).min(u64::MAX as u128) as u64
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(if self.count == 0 { 0 } else { self.max_ns })
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.count as u128) as u64)
    }

    /// Approximate quantile (`q` in [0,1]): the upper bound of the bucket
    /// holding the q-th ranked sample, clamped to the observed min/max.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let ns = Self::bucket_bound(i).clamp(self.min_ns, self.max_ns);
                return Duration::from_nanos(ns);
            }
        }
        Duration::from_nanos(self.max_ns)
    }

    /// Merge another histogram into this one (router-level aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line `n/p50/p95/p99/max` summary for logs and tables.
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} p50={} p95={} p99={} max={}",
            self.count,
            fmt_duration(self.quantile(0.50)),
            fmt_duration(self.quantile(0.95)),
            fmt_duration(self.quantile(0.99)),
            fmt_duration(self.max()),
        )
    }
}

/// Human-scaled duration formatting shared by the serving reports.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Human-scaled byte-count formatting shared by the serving and runtime
/// reports (parameter-literal cache sizes, conversion savings). Unit
/// thresholds sit at the value whose rounded mantissa reaches 1000, so a
/// count just under a boundary promotes to the next unit ("1.00 MB", not
/// "1000.0 KB").
pub fn fmt_bytes(b: usize) -> String {
    let v = b as f64;
    if v >= 999.995e6 {
        format!("{:.2} GB", v / 1e9)
    } else if v >= 999.95e3 {
        format!("{:.2} MB", v / 1e6)
    } else if v >= 999.95 {
        format!("{:.1} KB", v / 1e3)
    } else {
        format!("{b} B")
    }
}

/// Streaming mean/min/max accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn add(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_cosine() {
        let s = LrSchedule::new(1.0, 10, 110);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-4);
        assert!(s.at(60) < s.at(10));
        assert!(s.at(109) < 0.01);
        // monotone decay after warmup
        for i in 10..109 {
            assert!(s.at(i + 1) <= s.at(i) + 1e-7);
        }
    }

    #[test]
    fn run_record_roundtrips_skipped_epoch_nans_as_null() {
        let mut r = RunRecord {
            name: "pets/taskedge_k2".into(),
            task: "pets".into(),
            strategy: "taskedge_k2".into(),
            trainable_params: 123,
            trainable_frac: 0.01,
            curve: Vec::new(),
        };
        r.curve.push(EpochMetrics {
            epoch: 0,
            train_loss: 1.25,
            train_acc: 0.5,
            // a skipped epoch: eval metrics are NaN (see session's
            // should_eval) and must serialize as null, not `NaN`
            eval_loss: f64::NAN,
            eval_top1: f64::NAN,
            eval_top5: f64::NAN,
            steps: 4,
            wall_ms: 10.0,
        });
        r.curve.push(EpochMetrics {
            epoch: 1,
            train_loss: 0.75,
            train_acc: 0.75,
            eval_loss: 0.9,
            eval_top1: 0.625,
            eval_top5: 1.0,
            steps: 4,
            wall_ms: 11.5,
        });
        let text = r.to_json().to_string();
        assert!(
            !text.contains("NaN"),
            "record JSON must not contain the invalid NaN literal: {text}"
        );
        // the emitted text is valid JSON and reads back losslessly
        let back = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, r.name);
        assert_eq!(back.trainable_params, 123);
        assert_eq!(back.curve.len(), 2);
        assert!(back.curve[0].eval_loss.is_nan());
        assert!(back.curve[0].eval_top1.is_nan());
        assert_eq!(back.curve[0].train_loss, 1.25);
        assert_eq!(back.curve[1].eval_top1, 0.625);
        // summary helpers ignore the NaN epoch (fold over max)
        assert_eq!(back.best_top1(), 0.625);
        // a record with a *missing* metric key is malformed, not a skipped
        // epoch: parsing hard-errors instead of silently producing NaN
        let truncated = text.replace("\"train_loss\":1.25,", "");
        assert_ne!(truncated, text, "test must actually remove the key");
        assert!(RunRecord::from_json(&Json::parse(&truncated).unwrap()).is_err());
    }

    #[test]
    fn run_record_best() {
        let mut r = RunRecord::default();
        for (e, acc) in [(0, 0.1), (1, 0.6), (2, 0.5)] {
            r.curve.push(EpochMetrics { epoch: e, eval_top1: acc, ..Default::default() });
        }
        assert_eq!(r.best_top1(), 0.6);
        assert_eq!(r.final_top1(), 0.5);
    }

    #[test]
    fn summary_stats() {
        let mut s = Summary::default();
        for v in [2.0, 4.0, 6.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 6.0);
    }

    #[test]
    fn histogram_small_values_exact() {
        let mut h = Histogram::new();
        for ns in [1u64, 2, 3] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.0), Duration::from_nanos(1));
        assert_eq!(h.quantile(1.0), Duration::from_nanos(3));
        assert_eq!(h.max(), Duration::from_nanos(3));
    }

    #[test]
    fn histogram_quantiles_bounded_error() {
        let mut h = Histogram::new();
        // 1µs..=1000µs, uniform
        for us in 1..=1000u64 {
            h.record_ns(us * 1_000);
        }
        for (q, want_ns) in [(0.50, 500_000.0), (0.95, 950_000.0), (0.99, 990_000.0)] {
            let got = h.quantile(q).as_nanos() as f64;
            let rel = (got - want_ns).abs() / want_ns;
            assert!(rel < 0.15, "q={q}: got {got}, want ~{want_ns} (rel {rel:.3})");
        }
        // quantiles are clamped to observed extremes
        assert!(h.quantile(1.0) <= h.max());
        assert!(h.quantile(0.0) >= Duration::from_micros(1));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::new();
        let mut state = 0x2545f4914f6cdd1du64;
        for _ in 0..500 {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            h.record_ns(state % 10_000_000);
        }
        let mut prev = Duration::ZERO;
        for i in 0..=20 {
            let q = h.quantile(i as f64 / 20.0);
            assert!(q >= prev, "quantile not monotone at {i}");
            prev = q;
        }
    }

    #[test]
    fn histogram_merge_matches_union() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut u = Histogram::new();
        for ns in [10u64, 20, 30, 1_000_000] {
            a.record_ns(ns);
            u.record_ns(ns);
        }
        for ns in [5u64, 400, 2_000_000] {
            b.record_ns(ns);
            u.record_ns(ns);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        assert_eq!(a.max(), u.max());
        assert_eq!(a.mean(), u.mean());
        for i in 0..=10 {
            assert_eq!(a.quantile(i as f64 / 10.0), u.quantile(i as f64 / 10.0));
        }
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.max(), Duration::ZERO);
        assert_eq!(h.summary(), "n=0");
    }

    #[test]
    fn bytes_format_scales() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(999), "999 B");
        assert_eq!(fmt_bytes(1_500), "1.5 KB");
        assert_eq!(fmt_bytes(2_500_000), "2.50 MB");
        assert_eq!(fmt_bytes(3_210_000_000), "3.21 GB");
        // just under a unit boundary: promote, never print "1000.0 KB"
        assert_eq!(fmt_bytes(999_999), "1.00 MB");
        assert_eq!(fmt_bytes(999_999_999), "1.00 GB");
    }

    #[test]
    fn jsonl_logger_writes() {
        let path = std::env::temp_dir().join("taskedge_test_log.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut log = JsonlLogger::create(&path).unwrap();
            log.log(&Json::obj(vec![("a", 1usize.into())])).unwrap();
            log.log(&Json::obj(vec![("b", 2usize.into())])).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }
}
