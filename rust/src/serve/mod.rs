//! Serving path: a dynamic batcher + request router over the AOT `fwd`
//! graph — the deployment half of the paper's edge story (fine-tuned
//! task-specific models answering on-device requests).
//!
//! The AOT graphs have a static batch dimension, so the batcher groups
//! incoming single-image requests into full batches, padding the tail with
//! replicas when the linger deadline expires (padding rows are computed
//! but their outputs dropped). Requests are answered through channels;
//! worker threads share the PJRT runtime's compiled executable cache.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::{Bind, HostTensor, Runtime};
use crate::vit::ParamStore;

/// One inference request: a single image, answered with class logits.
pub struct Request {
    pub image: Vec<f32>,
    pub respond: mpsc::Sender<Response>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// queueing + batching + execution, as observed by the server
    pub latency: Duration,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// max time a partial batch waits for more requests before padding
    pub linger: Duration,
    /// number of executor threads pulling batches
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { linger: Duration::from_millis(2), workers: 1 }
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_rows: usize,
}

/// Dynamic batcher state shared between the submit side and the workers.
struct Queue {
    pending: Vec<Request>,
    closed: bool,
}

pub struct Server {
    rt: Arc<Runtime>,
    artifact: String,
    image_numel: usize,
    batch: usize,
    num_classes: usize,
    params: Arc<ParamStore>,
    cfg: ServerConfig,
    queue: Arc<Mutex<Queue>>,
    stats: Arc<Mutex<ServerStats>>,
}

impl Server {
    /// Build a server for `config_name`'s fwd artifact with the adapted
    /// parameters (backbone + fine-tuned tensors).
    pub fn new(
        rt: Arc<Runtime>,
        config_name: &str,
        params: Arc<ParamStore>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let mcfg = rt.manifest().config(config_name)?;
        let spec = rt.manifest().artifact_for("fwd", config_name)?;
        let image_numel = mcfg.image_size * mcfg.image_size * mcfg.channels;
        Ok(Server {
            artifact: spec.name.clone(),
            image_numel,
            batch: rt.manifest().batch,
            num_classes: mcfg.num_classes,
            rt,
            params,
            cfg,
            queue: Arc::new(Mutex::new(Queue { pending: Vec::new(), closed: false })),
            stats: Arc::new(Mutex::new(ServerStats::default())),
        })
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != self.image_numel {
            bail!("image has {} values, expected {}", image.len(), self.image_numel);
        }
        let (tx, rx) = mpsc::channel();
        let mut q = self.queue.lock().unwrap();
        if q.closed {
            bail!("server is shut down");
        }
        q.pending.push(Request { image, respond: tx, submitted: Instant::now() });
        Ok(rx)
    }

    /// Run the serving loop until `shutdown` is signalled (queue drained
    /// first). Blocks the calling thread; spawn workers per cfg.workers.
    pub fn run(&self, shutdown: Arc<std::sync::atomic::AtomicBool>) -> Result<()> {
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..self.cfg.workers.max(1) {
                let shutdown = shutdown.clone();
                handles.push(scope.spawn(move || self.worker_loop(&shutdown)));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
            }
            Ok(())
        })
    }

    fn worker_loop(&self, shutdown: &std::sync::atomic::AtomicBool) -> Result<()> {
        use std::sync::atomic::Ordering;
        let mut oldest_wait: Option<Instant> = None;
        loop {
            let batch = {
                let mut q = self.queue.lock().unwrap();
                let n = q.pending.len();
                let stop = shutdown.load(Ordering::Relaxed);
                if n == 0 {
                    if stop {
                        q.closed = true;
                        return Ok(());
                    }
                    None
                } else if n >= self.batch {
                    Some(q.pending.drain(..self.batch).collect::<Vec<_>>())
                } else {
                    // partial batch: flush when the oldest request has
                    // lingered long enough (or we're shutting down)
                    let oldest = q.pending[0].submitted;
                    if stop || oldest.elapsed() >= self.cfg.linger {
                        Some(q.pending.drain(..).collect::<Vec<_>>())
                    } else {
                        oldest_wait = Some(oldest);
                        None
                    }
                }
            };
            match batch {
                Some(reqs) => {
                    self.execute_batch(reqs)?;
                    oldest_wait = None;
                }
                None => {
                    // sleep until the linger deadline (or a short poll)
                    let naptime = oldest_wait
                        .map(|t| {
                            self.cfg
                                .linger
                                .saturating_sub(t.elapsed())
                                .max(Duration::from_micros(50))
                        })
                        .unwrap_or(Duration::from_micros(200));
                    std::thread::sleep(naptime);
                }
            }
        }
    }

    fn execute_batch(&self, reqs: Vec<Request>) -> Result<()> {
        let n_real = reqs.len();
        debug_assert!(n_real <= self.batch);
        // assemble (batch, H, W, C), padding with replicas of row 0
        let mut data = Vec::with_capacity(self.batch * self.image_numel);
        for r in &reqs {
            data.extend_from_slice(&r.image);
        }
        for _ in n_real..self.batch {
            let row0 = &reqs[0].image;
            data.extend_from_slice(row0);
        }
        let img_side = (self.image_numel / 3) as f64;
        let side = img_side.sqrt() as usize;
        let images = HostTensor::from_f32(&[self.batch, side, side, 3], data)?;

        let spec = self.rt.manifest().artifact(&self.artifact)?.clone();
        let inputs: Vec<Bind<'_>> = spec
            .inputs
            .iter()
            .map(|io| {
                if let Some(p) = io.name.strip_prefix("param:") {
                    Ok(Bind::Ref(self.params.get(p)?))
                } else if io.name == "images" {
                    Ok(Bind::Ref(&images))
                } else {
                    bail!("unexpected fwd input {}", io.name)
                }
            })
            .collect::<Result<_>>()?;
        let outputs = self.rt.execute_bound(&self.artifact, &inputs)?;
        let logits = outputs
            .first()
            .context("fwd returned no outputs")?
            .f32s()?;

        {
            let mut st = self.stats.lock().unwrap();
            st.requests += n_real;
            st.batches += 1;
            st.padded_rows += self.batch - n_real;
        }
        for (i, req) in reqs.into_iter().enumerate() {
            let row = &logits[i * self.num_classes..(i + 1) * self.num_classes];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = req.respond.send(Response {
                logits: row.to_vec(),
                argmax,
                latency: req.submitted.elapsed(),
            });
        }
        Ok(())
    }
}

/// Multi-task router: one adapted parameter set per task, routed by name —
/// the "many task-specific models on one device" deployment the paper
/// motivates. Task models share the single compiled executable (same
/// graph, different weights).
pub struct Router {
    servers: BTreeMap<String, Arc<Server>>,
}

impl Router {
    pub fn new() -> Router {
        Router { servers: BTreeMap::new() }
    }

    pub fn register(&mut self, task: &str, server: Arc<Server>) {
        self.servers.insert(task.to_string(), server);
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.servers.keys().map(|s| s.as_str()).collect()
    }

    pub fn submit(&self, task: &str, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.servers
            .get(task)
            .with_context(|| format!("no adapted model for task {task:?}"))?
            .submit(image)
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}
