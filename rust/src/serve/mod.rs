//! Event-driven serving engine: dynamic batching + multi-task routing over
//! the AOT `fwd` graph — the deployment half of the paper's edge story
//! (fine-tuned task-specific models answering on-device requests).
//!
//! The AOT graphs have a static batch dimension, so the batcher groups
//! incoming single-image requests into full batches, padding the tail with
//! replicas when the linger deadline expires (padding rows are computed but
//! their outputs dropped). Compared to the earlier sleep-polling prototype,
//! the engine is event-driven end to end:
//!
//! - **Condvar wakeups, no polling.** Submissions land in a bounded
//!   [`BatchQueue`]; worker threads sleep on a `Condvar` and are woken by
//!   the submit that completes a batch. A partial batch is flushed by a
//!   `wait_timeout` aimed at exactly the oldest request's linger deadline —
//!   there is no 50–200µs sleep loop anywhere on the path.
//! - **Backpressure.** `submit` fails fast once `max_queue` requests are
//!   pending instead of buffering unboundedly; rejections are counted in
//!   [`ServerStats::rejected`].
//! - **One-time batch plan.** The artifact name, input binding order,
//!   padded image-buffer geometry, and logits output index are resolved
//!   once at [`Server::new`] ([`BatchPlan`]); the hot path performs zero
//!   manifest lookups and zero `ArtifactSpec` clones per batch.
//! - **Observability.** Per-server latency histograms (queue wait and PJRT
//!   execute) are recorded into [`ServerStats`] and aggregated across tasks
//!   by [`Router::stats`].
//! - **Draining shutdown.** [`Server::shutdown`] closes the queue and wakes
//!   every worker; requests already queued are still batched and answered
//!   before [`Server::run`] returns, so no responder is dropped.
//! - **Adapter hot-swap.** A server is `backbone + TaskDelta`:
//!   [`Server::from_delta`] materializes the adapted parameter set once,
//!   and [`Server::swap_delta`] atomically replaces it on a live server.
//!   Workers snapshot the current `Arc<ParamStore>` at each batch boundary,
//!   so a swap never tears a batch, never drains the queue, and in-flight
//!   requests are answered by whichever parameter set their batch started
//!   with.
//!
//! Requests are answered through channels; worker threads share the PJRT
//! runtime's compiled executable cache.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::Histogram;
use crate::runtime::{Bind, HostTensor, Runtime};
use crate::vit::{ParamStore, TaskDelta};

/// One inference request: a single image, answered with class logits.
struct Request {
    image: Vec<f32>,
    respond: mpsc::Sender<Response>,
    submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// queueing + batching + execution, as observed by the server
    pub latency: Duration,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// max time a partial batch waits for more requests before padding
    pub linger: Duration,
    /// number of executor threads pulling batches
    pub workers: usize,
    /// max pending requests before `submit` rejects (backpressure)
    pub max_queue: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            linger: Duration::from_millis(2),
            workers: 1,
            max_queue: 1024,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_rows: usize,
    /// submissions refused because the queue was at `max_queue`
    pub rejected: usize,
    /// live parameter-set replacements ([`Server::swap_delta`])
    pub swaps: usize,
    /// submit -> batch formation wait, per request
    pub queue: Histogram,
    /// PJRT execute latency, per batch
    pub execute: Histogram,
}

impl ServerStats {
    /// Fold another server's stats into this one (router aggregation).
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_rows += other.padded_rows;
        self.rejected += other.rejected;
        self.swaps += other.swaps;
        self.queue.merge(&other.queue);
        self.execute.merge(&other.execute);
    }
}

/// NaN-safe argmax over one logits row, first index winning ties (numpy
/// semantics). Uses `f32::total_cmp`, under which +NaN orders above +inf —
/// a NaN logit yields that index deterministically instead of panicking
/// the worker (and poisoning the stats mutex) as `partial_cmp().unwrap()`
/// did. Empty rows return 0.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate().skip(1) {
        if v.total_cmp(&row[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// BatchQueue: the Condvar-signalled bounded queue at the engine's core
// ---------------------------------------------------------------------------

/// Why `submit` refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PushError {
    /// queue is at `max_queue` depth — caller should shed or retry later
    Full,
    /// server is shutting down
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PushError::Full => write!(f, "serve queue full (backpressure)"),
            PushError::Closed => write!(f, "server is shut down"),
        }
    }
}

struct QueueState {
    pending: VecDeque<Request>,
    closed: bool,
}

/// Bounded MPMC request queue with batch-granular, deadline-aware consume.
/// Producers wake exactly one worker per submit; consumers sleep on the
/// condvar with a timeout aimed at the oldest request's linger deadline.
struct BatchQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    capacity: usize,
    batch: usize,
    linger: Duration,
}

impl BatchQueue {
    fn new(capacity: usize, batch: usize, linger: Duration) -> BatchQueue {
        BatchQueue {
            state: Mutex::new(QueueState { pending: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
            batch: batch.max(1),
            linger,
        }
    }

    fn push(&self, req: Request) -> std::result::Result<(), PushError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.pending.len() >= self.capacity {
            return Err(PushError::Full);
        }
        st.pending.push_back(req);
        // one submit can complete at most one batch: wake one worker
        self.ready.notify_one();
        Ok(())
    }

    /// Close the queue: further pushes fail, workers drain what is pending
    /// (partial batches flush immediately) and then see `None`.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.ready.notify_all();
    }

    /// Block until a batch is ready: a full batch, or a partial one whose
    /// oldest request has lingered past the deadline (or the queue closed).
    /// Returns `None` when the queue is closed and fully drained.
    fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.pending.len() >= self.batch {
                return Some(st.pending.drain(..self.batch).collect());
            }
            if let Some(front) = st.pending.front() {
                let deadline = front.submitted + self.linger;
                let now = Instant::now();
                if st.closed || now >= deadline {
                    let n = st.pending.len();
                    return Some(st.pending.drain(..n).collect());
                }
                // sleep until more work arrives or the linger deadline
                // passes; re-check on every (possibly spurious) wakeup
                let (guard, _) = self.ready.wait_timeout(st, deadline - now).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                st = self.ready.wait(st).unwrap();
            }
        }
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }
}

// ---------------------------------------------------------------------------
// BatchPlan: everything `execute_batch` needs, resolved once at Server::new
// ---------------------------------------------------------------------------

/// One input position of the fwd artifact, pre-classified at construction.
enum Slot {
    /// the padded image batch assembled per execution
    Images,
    /// a named tensor from the adapted parameter store
    Param(String),
}

/// The batch-assembly plan: artifact identity, input binding order, padded
/// image-buffer geometry, and output location — computed **once** so the
/// per-batch hot path does no manifest lookups or `ArtifactSpec` clones.
struct BatchPlan {
    artifact: String,
    slots: Vec<Slot>,
    /// `[batch, image_size, image_size, channels]`, exact from the manifest
    image_shape: Vec<usize>,
    /// values per request image (`image_size² × channels`)
    image_numel: usize,
    batch: usize,
    num_classes: usize,
    logits_index: usize,
}

impl BatchPlan {
    fn new(rt: &Runtime, config_name: &str, params: &ParamStore) -> Result<BatchPlan> {
        let mcfg = rt.manifest().config(config_name)?;
        let spec = rt.manifest().artifact_for("fwd", config_name)?;
        let batch = rt.manifest().batch;
        // Exact integer geometry from the model config — no floating-point
        // side derivation. Non-square or non-RGB configs are carried
        // faithfully; a manifest/config mismatch is an error, not a
        // silently mis-shaped buffer.
        let image_shape =
            vec![batch, mcfg.image_size, mcfg.image_size, mcfg.channels];
        let image_numel = mcfg.image_size * mcfg.image_size * mcfg.channels;
        let mut slots = Vec::with_capacity(spec.inputs.len());
        let mut has_images = false;
        for io in &spec.inputs {
            if let Some(p) = io.name.strip_prefix("param:") {
                // fail fast at construction if the store can't satisfy the
                // binding order, instead of on the first request
                params.get(p).with_context(|| {
                    format!("fwd input param:{p} missing from parameter store")
                })?;
                slots.push(Slot::Param(p.to_string()));
            } else if io.name == "images" {
                if io.shape != image_shape {
                    bail!(
                        "fwd images input shape {:?} != config-derived {:?} \
                         (batch={batch}, image_size={}, channels={})",
                        io.shape, image_shape, mcfg.image_size, mcfg.channels
                    );
                }
                has_images = true;
                slots.push(Slot::Images);
            } else {
                bail!("unexpected fwd input {:?}", io.name);
            }
        }
        if !has_images {
            bail!("fwd artifact {} has no images input", spec.name);
        }
        let logits_index = spec.output_index("logits")?;
        Ok(BatchPlan {
            artifact: spec.name.clone(),
            slots,
            image_shape,
            image_numel,
            batch,
            num_classes: mcfg.num_classes,
            logits_index,
        })
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// The fwd graph consumes only backbone `param:*` tensors; a delta whose
/// task state lives outside the backbone (VPT prompt, adapter stacks in
/// `extra`) cannot be served through it — refusing loudly beats silently
/// answering with an un-adapted forward path.
fn ensure_servable(delta: &TaskDelta) -> Result<()> {
    if !delta.extra.is_empty() {
        let names: Vec<&str> =
            delta.extra.keys().map(|k| k.as_str()).collect();
        bail!(
            "delta for task {:?} (strategy {:?}) carries auxiliary tensors \
             {names:?} with no backbone slot — the fwd graph cannot serve \
             this family via backbone+delta",
            delta.task,
            delta.strategy
        );
    }
    Ok(())
}

pub struct Server {
    rt: Arc<Runtime>,
    /// the frozen shared backbone — kept so `swap_delta` can re-derive an
    /// adapted parameter set from any task's delta
    backbone: Arc<ParamStore>,
    /// the live parameter set; workers snapshot the Arc per batch, so a
    /// swap takes effect at the next batch boundary without draining
    params: RwLock<Arc<ParamStore>>,
    plan: BatchPlan,
    queue: BatchQueue,
    stats: Mutex<ServerStats>,
    workers: usize,
}

impl Server {
    /// Build a server for `config_name`'s fwd artifact with the adapted
    /// parameters (backbone + fine-tuned tensors). Resolves the full batch
    /// plan here so the serving hot path never touches the manifest.
    pub fn new(
        rt: Arc<Runtime>,
        config_name: &str,
        params: Arc<ParamStore>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let plan = BatchPlan::new(&rt, config_name, &params)?;
        let queue = BatchQueue::new(cfg.max_queue, plan.batch, cfg.linger);
        Ok(Server {
            rt,
            backbone: params.clone(),
            params: RwLock::new(params),
            plan,
            queue,
            stats: Mutex::new(ServerStats::default()),
            workers: cfg.workers.max(1),
        })
    }

    /// Build a server from `backbone + delta` — the deployment contract of
    /// the TaskDelta subsystem: the (shared, immutable) backbone plus one
    /// task's sparse delta fully determine a serving parameter set.
    ///
    /// Fails for deltas carrying `extra` tensors (VPT prompt, adapter
    /// stacks): the fwd graph has no input for them, so serving would
    /// silently answer with the un-adapted forward path.
    pub fn from_delta(
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        delta: &TaskDelta,
        cfg: ServerConfig,
    ) -> Result<Server> {
        ensure_servable(delta)?;
        let adapted = Arc::new(delta.apply_to(&backbone)?);
        let plan = BatchPlan::new(&rt, config_name, &adapted)?;
        let queue = BatchQueue::new(cfg.max_queue, plan.batch, cfg.linger);
        Ok(Server {
            rt,
            backbone,
            params: RwLock::new(adapted),
            plan,
            queue,
            stats: Mutex::new(ServerStats::default()),
            workers: cfg.workers.max(1),
        })
    }

    /// Atomically replace the live parameter set with `backbone + delta`.
    /// Takes effect at the next batch boundary: batches already being
    /// assembled/executed finish on the old set, everything after runs on
    /// the new one. The queue is never drained and no request is dropped.
    /// On validation failure the server keeps serving the old parameters.
    pub fn swap_delta(&self, delta: &TaskDelta) -> Result<()> {
        ensure_servable(delta)?;
        let adapted = Arc::new(delta.apply_to(&self.backbone)?);
        *self.params.write().unwrap() = adapted;
        self.stats.lock().unwrap().swaps += 1;
        Ok(())
    }

    /// Snapshot of the parameter set new batches will execute with.
    pub fn current_params(&self) -> Arc<ParamStore> {
        self.params.read().unwrap().clone()
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Submit a request; the response arrives on the returned receiver.
    /// Fails fast when the image is mis-sized, the server is shut down, or
    /// the queue is at `max_queue` depth (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != self.plan.image_numel {
            bail!(
                "image has {} values, expected {}",
                image.len(),
                self.plan.image_numel
            );
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { image, respond: tx, submitted: Instant::now() };
        match self.queue.push(req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                if e == PushError::Full {
                    self.stats.lock().unwrap().rejected += 1;
                }
                bail!("{e}");
            }
        }
    }

    /// Run the serving loop: spawns `cfg.workers` executor threads and
    /// blocks until [`Server::shutdown`] is called and the queue is
    /// drained. Workers sleep on the queue's condvar — no polling.
    pub fn run(&self) -> Result<()> {
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..self.workers {
                handles.push(scope.spawn(|| self.worker_loop()));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("serve worker panicked"))??;
            }
            Ok(())
        })
    }

    /// Signal shutdown: new submissions fail, pending requests are still
    /// batched and answered, then `run` returns.
    pub fn shutdown(&self) {
        self.queue.close();
    }

    fn worker_loop(&self) -> Result<()> {
        while let Some(reqs) = self.queue.next_batch() {
            if let Err(e) = self.execute_batch(reqs) {
                // fail fast: close the queue so submitters get an error (or
                // a disconnected channel) instead of waiting on responses
                // that will never arrive from a dead worker
                self.queue.close();
                return Err(e);
            }
        }
        Ok(())
    }

    fn execute_batch(&self, reqs: Vec<Request>) -> Result<()> {
        let plan = &self.plan;
        let n_real = reqs.len();
        debug_assert!(n_real > 0 && n_real <= plan.batch);
        let formed = Instant::now();

        // snapshot the live parameter set ONCE per batch: `swap_delta` can
        // land a new Arc mid-flight without tearing this batch
        let params = self.params.read().unwrap().clone();

        // assemble (batch, H, W, C), padding with replicas of row 0
        let mut data = Vec::with_capacity(plan.batch * plan.image_numel);
        for r in &reqs {
            data.extend_from_slice(&r.image);
        }
        for _ in n_real..plan.batch {
            data.extend_from_slice(&reqs[0].image);
        }
        let images = HostTensor::from_f32(&plan.image_shape, data)?;

        let inputs: Vec<Bind<'_>> = plan
            .slots
            .iter()
            .map(|slot| {
                Ok(match slot {
                    Slot::Images => Bind::Ref(&images),
                    Slot::Param(p) => Bind::Ref(params.get(p)?),
                })
            })
            .collect::<Result<_>>()?;

        let t_exec = Instant::now();
        let outputs = self.rt.execute_bound(&plan.artifact, &inputs)?;
        let exec_elapsed = t_exec.elapsed();
        let logits = outputs
            .get(plan.logits_index)
            .context("fwd returned no logits output")?
            .f32s()?;

        {
            let mut st = self.stats.lock().unwrap();
            st.requests += n_real;
            st.batches += 1;
            st.padded_rows += plan.batch - n_real;
            st.execute.record(exec_elapsed);
            for r in &reqs {
                st.queue.record(formed.duration_since(r.submitted));
            }
        }
        for (i, req) in reqs.into_iter().enumerate() {
            let row = &logits[i * plan.num_classes..(i + 1) * plan.num_classes];
            let _ = req.respond.send(Response {
                logits: row.to_vec(),
                argmax: argmax(row),
                latency: req.submitted.elapsed(),
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Multi-task router: one adapted parameter set per task, routed by name —
/// the "many task-specific models on one device" deployment the paper
/// motivates. Task models share the single compiled executable (same
/// graph, different weights).
pub struct Router {
    servers: BTreeMap<String, Arc<Server>>,
}

/// Aggregate view over every routed task: per-task snapshots plus a merged
/// total (histograms merge bucket-wise, so total quantiles are exact over
/// the union of samples up to bucket resolution).
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub per_task: BTreeMap<String, ServerStats>,
    pub total: ServerStats,
}

impl Router {
    pub fn new() -> Router {
        Router { servers: BTreeMap::new() }
    }

    pub fn register(&mut self, task: &str, server: Arc<Server>) {
        self.servers.insert(task.to_string(), server);
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.servers.keys().map(|s| s.as_str()).collect()
    }

    pub fn server(&self, task: &str) -> Option<&Arc<Server>> {
        self.servers.get(task)
    }

    pub fn submit(&self, task: &str, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.servers
            .get(task)
            .with_context(|| format!("no adapted model for task {task:?}"))?
            .submit(image)
    }

    /// Hot-swap one routed task's fine-tuned parameter set (see
    /// [`Server::swap_delta`]): live, no drain, next-batch-boundary.
    /// Refuses a delta labeled for a different task — a wrong-task swap
    /// would silently answer every `task` request with another task's
    /// weights (clear `delta.task` for deliberately generic payloads).
    pub fn swap_delta(&self, task: &str, delta: &TaskDelta) -> Result<()> {
        if !delta.task.is_empty() && delta.task != task {
            bail!(
                "delta is labeled for task {:?}; refusing to swap it into \
                 the server for task {task:?}",
                delta.task
            );
        }
        self.servers
            .get(task)
            .with_context(|| format!("no adapted model for task {task:?}"))?
            .swap_delta(delta)
    }

    /// Snapshot every server's stats and the cross-task aggregate.
    pub fn stats(&self) -> RouterStats {
        let mut total = ServerStats::default();
        let per_task: BTreeMap<String, ServerStats> = self
            .servers
            .iter()
            .map(|(task, server)| {
                let st = server.stats();
                total.merge(&st);
                (task.clone(), st)
            })
            .collect();
        RouterStats { per_task, total }
    }

    /// Signal shutdown on every routed server (each `run` returns after
    /// draining its queue).
    pub fn shutdown(&self) {
        for server in self.servers.values() {
            server.shutdown();
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Engine unit tests (no PJRT runtime required)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        let (tx, _rx) = mpsc::channel();
        Request { image: Vec::new(), respond: tx, submitted: Instant::now() }
    }

    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        // regression: a NaN logit used to panic the worker via
        // partial_cmp().unwrap(); total_cmp orders +NaN above +inf
        let row = [0.1f32, f32::NAN, 0.9, f32::INFINITY];
        assert_eq!(argmax(&row), 1);
        // no NaN: plain maximum
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        // ties: first index wins (numpy semantics)
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
        // genuinely empty row: 0
        assert_eq!(argmax(&[]), 0);
        // -NaN sorts below everything
        assert_eq!(argmax(&[-f32::NAN, -1.0]), 1);
    }

    #[test]
    fn aux_deltas_are_rejected_for_serving() {
        // a VPT/adapter delta's task state has no backbone slot: serving it
        // through the fwd graph would silently ignore the adaptation
        let mut delta = TaskDelta::new("micro");
        delta.extra.insert("prompt".into(), HostTensor::zeros(&[2, 4]));
        assert!(ensure_servable(&delta).is_err());
        delta.extra.clear();
        assert!(ensure_servable(&delta).is_ok());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = BatchQueue::new(2, 16, Duration::from_secs(1));
        assert!(q.push(req()).is_ok());
        assert!(q.push(req()).is_ok());
        assert_eq!(q.push(req()).unwrap_err(), PushError::Full);
        // draining frees capacity again (closed flush returns the backlog)
        q.close();
        assert_eq!(q.next_batch().map(|b| b.len()), Some(2));
        assert_eq!(q.push(req()).unwrap_err(), PushError::Closed);
    }

    #[test]
    fn full_batch_wakes_worker_immediately() {
        // linger is effectively infinite: only the full-batch condition can
        // release the worker, and it must do so without any polling delay
        let q = Arc::new(BatchQueue::new(64, 4, Duration::from_secs(3600)));
        let t0 = Instant::now();
        let batch = std::thread::scope(|scope| {
            let qc = q.clone();
            let h = scope.spawn(move || qc.next_batch());
            for _ in 0..4 {
                q.push(req()).unwrap();
            }
            h.join().unwrap()
        });
        assert_eq!(batch.map(|b| b.len()), Some(4));
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "full batch did not wake the worker"
        );
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn linger_flushes_partial_batch_within_deadline() {
        let linger = Duration::from_millis(50);
        let q = BatchQueue::new(64, 16, linger);
        q.push(req()).unwrap();
        q.push(req()).unwrap();
        // next_batch blocks on wait_timeout until the oldest request's
        // deadline, then flushes the partial batch — no polling loop
        let batch = q.next_batch().expect("linger flush produced no batch");
        assert_eq!(batch.len(), 2);
        // the flush happened at (not before) the oldest request's deadline
        assert!(
            batch[0].submitted.elapsed() >= linger,
            "partial batch flushed before the linger deadline"
        );
    }

    #[test]
    fn shutdown_drains_pending_then_ends() {
        let q = BatchQueue::new(64, 16, Duration::from_secs(3600));
        for _ in 0..3 {
            q.push(req()).unwrap();
        }
        q.close();
        // the pending partial batch is flushed despite the huge linger...
        assert_eq!(q.next_batch().map(|b| b.len()), Some(3));
        // ...and only then does the queue report end-of-stream
        assert!(q.next_batch().is_none());
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn close_wakes_idle_workers() {
        let q = Arc::new(BatchQueue::new(64, 16, Duration::from_secs(3600)));
        let got = std::thread::scope(|scope| {
            let qc = q.clone();
            let h = scope.spawn(move || qc.next_batch());
            // let the worker reach the condvar wait, then close
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            h.join().unwrap()
        });
        assert!(got.is_none(), "close must release workers blocked on empty queue");
    }
}
