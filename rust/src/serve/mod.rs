//! Device-shared serving engine: one **`DeviceExecutor`** — a single
//! work-conserving worker pool — serves every fine-tuned task on the
//! device, the deployment half of the paper's edge story (many
//! task-specific models answering on-device requests over one frozen
//! backbone).
//!
//! The AOT graphs have a static batch dimension, so single-image requests
//! are grouped into per-task sub-batches, padding the tail with replicas
//! only when a request's linger deadline forces a flush. Architecture:
//!
//! - **Per-task bounded queues, one shared worker pool.** Each task owns a
//!   bounded FIFO with its own backpressure ([`ServerStats::rejected`])
//!   and a swap lock serializing parameter replacements;
//!   `DeviceExecutor` workers pull from *all* queues. A task with a
//!   partial batch no longer pins an idle worker: while its requests
//!   linger, the pool executes other tasks' full batches back-to-back, and
//!   by the time a worker returns to the partial queue more rows have
//!   arrived — padding becomes work conservation.
//! - **Deficit-weighted round-robin.** Tasks carry a scheduling weight;
//!   dispatch picks by deficit round-robin (deficits replenish in
//!   proportion to weight, idle queues bank no credit), so a flooding task
//!   cannot starve a trickle task, and expired partial batches — the
//!   latency contract — preempt full batches. Fairness counters land in
//!   [`DeviceStats`].
//! - **Cached parameter literals.** A task's parameter set is converted to
//!   XLA literals **once per generation** ([`Runtime::prepare`]) — at
//!   registration and again inside [`DeviceExecutor::swap_delta`], never
//!   on the hot path. Each batch converts only its padded image buffer
//!   ([`Runtime::execute_prepared`]); the backbone-sized conversion that
//!   used to dominate per-batch cost is gone (see
//!   `RuntimeStats::param_reuse_bytes`).
//! - **Event-driven, no polling.** Workers sleep on one condvar; a submit
//!   that completes a sub-batch (or starts a fresh linger clock) wakes
//!   exactly one, and partial flushes ride a `wait_timeout` aimed at the
//!   earliest pending deadline.
//! - **Adapter hot-swap, donation-sized.** A task is `backbone +
//!   TaskDelta`; a swap atomically replaces its parameter set *and*
//!   prepared device state at the next sub-batch boundary — no drain, no
//!   dropped requests, no stale literals. When the task solely owns its
//!   prepared set, the swap donates in place
//!   ([`Runtime::donate_writeback`]): only the delta-touched tensors are
//!   re-uploaded, so swap cost tracks the delta, not the backbone.
//! - **Draining shutdown.** [`Router::shutdown`] closes every queue;
//!   pending requests are still batched and answered before
//!   [`Router::run`] returns.
//!
//! [`Server`] remains as the single-task convenience wrapper (one task on
//! a private executor); [`Router`] is the device-level facade: name
//! routing, per-task + aggregate + device stats, swap and lifecycle.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::Histogram;
use crate::runtime::{HostTensor, PreparedParams, Runtime};
use crate::vit::{ParamStore, TaskDelta};

/// Scheduler-level weight clamp range (defense in depth — `DeviceBuilder`
/// already rejects non-finite or out-of-range weights loudly). The floor
/// keeps a tiny weight from never accumulating deficit (starvation by
/// configuration). The ceiling bounds *latency*, not just arithmetic: a
/// weight-w flood legitimately runs up to ~w back-to-back sub-batches
/// between a weight-1 peer's turns, so a peer's expired partial can be
/// deferred by ~w batch executions past its linger deadline — the ceiling
/// keeps that worst case to tens of batches instead of letting an
/// extreme weight (or an unclamped +inf, which would pin its deficit at
/// +inf) turn the fairness guarantee into practical starvation.
const MIN_WEIGHT: f64 = 0.05;
const MAX_WEIGHT: f64 = 64.0;

/// A queue may bank at most this many quanta of unused deficit, so a long
/// idle-ish task cannot burst far beyond its share once it turns hot.
const BURST_QUANTA: f64 = 4.0;

/// One inference request: a single image, answered with class logits.
struct Request {
    image: Vec<f32>,
    respond: mpsc::Sender<Response>,
    submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// queueing + batching + execution, as observed by the server
    pub latency: Duration,
}

/// Device-wide executor configuration (the old per-server knobs moved to
/// the device: one worker pool and one linger policy serve every task).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// max time a partial sub-batch waits for more requests before padding
    pub linger: Duration,
    /// executor threads shared by every task on the device
    pub workers: usize,
    /// default per-task queue bound (backpressure); override per task via
    /// [`TaskConfig::max_queue`]
    pub max_queue: usize,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            linger: Duration::from_millis(2),
            workers: 2,
            max_queue: 1024,
        }
    }
}

/// Per-task scheduling knobs.
#[derive(Debug, Clone)]
pub struct TaskConfig {
    /// deficit round-robin share: a weight-2 task gets twice the rows of a
    /// weight-1 task under contention. Must be finite and within
    /// [0.05, 64] — [`DeviceBuilder`] rejects anything else (the ceiling
    /// bounds how long a flood may defer a peer's expired partial batch).
    pub weight: f64,
    /// queue bound for this task; `None` inherits [`DeviceConfig::max_queue`]
    pub max_queue: Option<usize>,
}

impl Default for TaskConfig {
    fn default() -> Self {
        TaskConfig { weight: 1.0, max_queue: None }
    }
}

/// Single-task serving configuration, kept as the [`Server`] wrapper's
/// spelling: a single-task server is a one-task device, so the per-server
/// knobs ARE the device-wide ones.
pub type ServerConfig = DeviceConfig;

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_rows: usize,
    /// submissions refused because the queue was at its bound
    pub rejected: usize,
    /// live parameter-set replacements ([`Router::swap_delta`])
    pub swaps: usize,
    /// submit -> batch formation wait, per request
    pub queue: Histogram,
    /// PJRT execute latency, per batch
    pub execute: Histogram,
}

impl ServerStats {
    /// Fold another task's stats into this one (router aggregation).
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_rows += other.padded_rows;
        self.rejected += other.rejected;
        self.swaps += other.swaps;
        self.queue.merge(&other.queue);
        self.execute.merge(&other.execute);
    }
}

/// Device-level scheduling counters (cross-task behaviour the per-task
/// [`ServerStats`] cannot see).
#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    /// sub-batches dispatched by the shared pool
    pub dispatches: usize,
    /// dispatches where a worker switched to a different task than its
    /// previous sub-batch — back-to-back cross-task packing in action
    pub task_switches: usize,
    /// deficit replenish rounds the scheduler ran
    pub drr_rounds: usize,
    /// worker threads in the shared pool
    pub workers: usize,
    /// device bytes currently held by resident frozen-parameter sets
    /// (runtime-wide gauge; see `RuntimeStats::resident_bytes`)
    pub resident_bytes: usize,
    /// resident sets stripped to stay under the device byte budget
    pub resident_evictions: usize,
    /// in-place prepared-set refreshes ([`Runtime::donate_writeback`]) —
    /// on this path, swaps served without a full re-prepare
    pub donations: usize,
    /// frozen bytes bound from already-resident device buffers instead of
    /// re-crossing the bus (`RuntimeStats::h2d_resident_bytes`)
    pub upload_savings_bytes: usize,
}

/// NaN-safe argmax over one logits row, first index winning ties (numpy
/// semantics). Uses `f32::total_cmp`, under which +NaN orders above +inf —
/// a NaN logit yields that index deterministically instead of panicking
/// the worker (and poisoning the stats mutex) as `partial_cmp().unwrap()`
/// did. Empty rows return 0.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate().skip(1) {
        if v.total_cmp(&row[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Scheduler: per-task bounded queues + deficit-weighted round-robin
// ---------------------------------------------------------------------------

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PushError {
    /// the task's queue is at its bound — caller should shed or retry later
    Full,
    /// executor is shutting down
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PushError::Full => write!(f, "serve queue full (backpressure)"),
            PushError::Closed => write!(f, "server is shut down"),
        }
    }
}

struct TaskQueue {
    pending: VecDeque<Request>,
    /// deficit round-robin credit, in rows
    deficit: f64,
    weight: f64,
    capacity: usize,
}

struct SchedState {
    queues: Vec<TaskQueue>,
    /// round-robin position for full-batch dispatch
    cursor: usize,
    closed: bool,
    /// deficit replenish rounds (observability)
    rounds: usize,
}

/// The multi-queue heart of the executor: bounded per-task FIFOs drained
/// in deficit-weighted round-robin order by any number of workers.
///
/// Dispatch rules, in priority order:
/// 1. a partial sub-batch whose oldest request has outlived the linger
///    deadline (or the queue closed) — the latency contract; earliest
///    deadline first;
/// 2. a full sub-batch, round-robin from a rotating cursor.
///
/// Both are gated by the task's deficit, and **every dispatch costs one
/// full batch of credit** regardless of fill — on a static-batch graph a
/// 2-row padded flush occupies the device exactly as long as 16 real
/// rows, so device *compute* is the fairness currency. Under contention
/// this rations a trickle task's padded flushes to its weight share (its
/// partial keeps filling while heavier tasks run back-to-back, turning
/// would-be padding into real rows); on an idle device the replenish loop
/// spins freely and partials still flush right at their linger deadline.
/// When no candidate has enough credit, every backlogged queue's deficit
/// is replenished by `weight × batch` rows (idle queues reset to zero —
/// no banked credit, the classic DRR rule), which guarantees every
/// backlogged task dispatches within `ceil(1/weight)` rounds:
/// starvation-free by construction.
struct Scheduler {
    state: Mutex<SchedState>,
    ready: Condvar,
    batch: usize,
    linger: Duration,
}

impl Scheduler {
    fn new(batch: usize, linger: Duration, tasks: &[(f64, usize)]) -> Scheduler {
        let queues = tasks
            .iter()
            .map(|&(weight, capacity)| TaskQueue {
                pending: VecDeque::new(),
                deficit: 0.0,
                // NaN fails both clamp comparisons and lands on the floor
                weight: if weight.is_finite() {
                    weight.clamp(MIN_WEIGHT, MAX_WEIGHT)
                } else {
                    MIN_WEIGHT
                },
                capacity: capacity.max(1),
            })
            .collect();
        Scheduler {
            state: Mutex::new(SchedState {
                queues,
                cursor: 0,
                closed: false,
                rounds: 0,
            }),
            ready: Condvar::new(),
            batch: batch.max(1),
            linger,
        }
    }

    fn push(&self, task: usize, req: Request) -> std::result::Result<(), PushError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed);
        }
        let batch = self.batch;
        let q = &mut st.queues[task];
        if q.pending.len() >= q.capacity {
            return Err(PushError::Full);
        }
        q.pending.push_back(req);
        let len = q.pending.len();
        // wake one worker when this push completes another full sub-batch
        // (`len % batch == 0`), or when it STARTS a new sub-batch segment
        // (`(len - 1) % batch == 0`) — the latter is the request that will
        // become the queue front after the preceding full batches are
        // drained, so some worker must aim a wait_timeout at its linger
        // deadline; intermediate pushes wake nobody
        if len % batch == 0 || (len - 1) % batch == 0 {
            self.ready.notify_one();
        }
        Ok(())
    }

    /// Close every queue: further pushes fail, workers drain what is
    /// pending (partial sub-batches flush immediately) and then see `None`.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.ready.notify_all();
    }

    fn rounds(&self) -> usize {
        self.state.lock().unwrap().rounds
    }

    /// Block until a sub-batch is ready and this worker wins it; returns
    /// `(task, requests)` or `None` when closed and fully drained.
    fn next_work(&self) -> Option<(usize, Vec<Request>)> {
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let n = st.queues.len();
            let mut any_pending = false;
            let mut full_ready = false;
            // some queue holds an expired (or closed-flush) partial; the
            // actual pick happens in the DRR pass below
            let mut expired_any = false;
            // earliest not-yet-expired deadline, to aim the sleep at
            let mut earliest: Option<Instant> = None;
            for q in st.queues.iter() {
                let Some(front) = q.pending.front() else { continue };
                any_pending = true;
                if q.pending.len() >= self.batch {
                    full_ready = true;
                }
                let deadline = front.submitted + self.linger;
                if st.closed || deadline <= now {
                    expired_any = true;
                } else {
                    match earliest {
                        Some(e) if e <= deadline => {}
                        _ => earliest = Some(deadline),
                    }
                }
            }

            if full_ready || expired_any {
                // every dispatch — full or padded — costs one batch of
                // compute on the static-batch graph
                let cost = self.batch as f64;
                // DRR pick; replenish deficits until a candidate has credit
                let (task, take) = loop {
                    // pass 1 — expired partials (the latency contract):
                    // earliest deadline among queues that can PAY. Scanning
                    // all expired queues (not just the globally earliest)
                    // is what keeps this starvation-free: a flood whose
                    // backlog is always oldest goes broke after each
                    // dispatch, and a trickle's banked credit then wins the
                    // slot even though its deadline is younger.
                    let mut pick: Option<(usize, Instant)> = None;
                    for (i, q) in st.queues.iter().enumerate() {
                        let Some(front) = q.pending.front() else { continue };
                        if q.deficit < cost {
                            continue;
                        }
                        let deadline = front.submitted + self.linger;
                        if !(st.closed || deadline <= now) {
                            continue;
                        }
                        match pick {
                            Some((_, d)) if d <= deadline => {}
                            _ => pick = Some((i, deadline)),
                        }
                    }
                    if let Some((i, _)) = pick {
                        let take = st.queues[i].pending.len().min(self.batch);
                        break (i, take);
                    }
                    let mut found = None;
                    for k in 0..n {
                        let i = (st.cursor + k) % n;
                        if st.queues[i].pending.len() >= self.batch
                            && st.queues[i].deficit >= cost
                        {
                            found = Some(i);
                            break;
                        }
                    }
                    if let Some(i) = found {
                        st.cursor = (i + 1) % n;
                        break (i, self.batch);
                    }
                    // no candidate has credit: one DRR round — backlogged
                    // queues gain weight-proportional deficit (capped),
                    // idle queues bank nothing
                    st.rounds += 1;
                    let batch = self.batch as f64;
                    for q in st.queues.iter_mut() {
                        if q.pending.is_empty() {
                            q.deficit = 0.0;
                        } else {
                            let quantum = q.weight * batch;
                            // the cap must admit a full batch even for
                            // small weights, or low-weight tasks could
                            // never dispatch a full sub-batch
                            let cap = (quantum * BURST_QUANTA).max(batch);
                            q.deficit = (q.deficit + quantum).min(cap);
                        }
                    }
                };
                let q = &mut st.queues[task];
                q.deficit -= cost;
                let reqs: Vec<Request> = q.pending.drain(..take).collect();
                // hand the queues to another worker before leaving to
                // execute: this worker's deadline timer is gone, so the
                // woken one either dispatches more work right away or
                // re-arms a wait_timeout at the earliest remaining linger
                // deadline — a pending partial is never left watcherless
                // while a worker idles
                if st.queues.iter().any(|q| !q.pending.is_empty()) {
                    self.ready.notify_one();
                }
                return Some((task, reqs));
            }

            if st.closed && !any_pending {
                return None;
            }
            st = match earliest {
                // partial batches pending: sleep exactly until the first
                // linger deadline (or an earlier wakeup)
                Some(deadline) => {
                    self.ready
                        .wait_timeout(st, deadline.saturating_duration_since(now))
                        .unwrap()
                        .0
                }
                // nothing pending at all: sleep until a submit or close
                None => self.ready.wait(st).unwrap(),
            };
        }
    }

    #[cfg(test)]
    fn len(&self, task: usize) -> usize {
        self.state.lock().unwrap().queues[task].pending.len()
    }
}

// ---------------------------------------------------------------------------
// BatchPlan: everything the dispatch path needs, resolved once at build
// ---------------------------------------------------------------------------

/// The batch-assembly plan: artifact identity, parameter slot assignment,
/// padded image-buffer geometry, and output location — computed **once**
/// per device so the per-batch hot path does no manifest lookups, no
/// `ArtifactSpec` clones, and (with prepared literals) no parameter
/// conversions.
struct BatchPlan {
    artifact: String,
    /// `(input slot, param name)` for every `param:*` input, spec order
    param_slots: Vec<(usize, String)>,
    /// `[batch, image_size, image_size, channels]`, exact from the manifest
    image_shape: Vec<usize>,
    /// values per request image (`image_size² × channels`)
    image_numel: usize,
    batch: usize,
    num_classes: usize,
    logits_index: usize,
}

impl BatchPlan {
    fn new(rt: &Runtime, config_name: &str) -> Result<BatchPlan> {
        let mcfg = rt.manifest().config(config_name)?;
        let spec = rt.manifest().artifact_for("fwd", config_name)?;
        let batch = rt.manifest().batch;
        // Exact integer geometry from the model config — no floating-point
        // side derivation. Non-square or non-RGB configs are carried
        // faithfully; a manifest/config mismatch is an error, not a
        // silently mis-shaped buffer.
        let image_shape =
            vec![batch, mcfg.image_size, mcfg.image_size, mcfg.channels];
        let image_numel = mcfg.image_size * mcfg.image_size * mcfg.channels;
        let mut param_slots = Vec::with_capacity(spec.inputs.len());
        let mut has_images = false;
        for (i, io) in spec.inputs.iter().enumerate() {
            if let Some(p) = io.name.strip_prefix("param:") {
                param_slots.push((i, p.to_string()));
            } else if io.name == "images" {
                if io.shape != image_shape {
                    bail!(
                        "fwd images input shape {:?} != config-derived {:?} \
                         (batch={batch}, image_size={}, channels={})",
                        io.shape, image_shape, mcfg.image_size, mcfg.channels
                    );
                }
                has_images = true;
            } else {
                bail!("unexpected fwd input {:?}", io.name);
            }
        }
        if !has_images {
            bail!("fwd artifact {} has no images input", spec.name);
        }
        let logits_index = spec.output_index("logits")?;
        Ok(BatchPlan {
            artifact: spec.name.clone(),
            param_slots,
            image_shape,
            image_numel,
            batch,
            num_classes: mcfg.num_classes,
            logits_index,
        })
    }
}

/// Freeze a task's parameter set into cached literals: validates that the
/// store satisfies the fwd binding order and converts each `param:*`
/// tensor once (or reuses the runtime's generation-keyed cache).
fn prepare_store(
    rt: &Runtime,
    plan: &BatchPlan,
    store: &ParamStore,
) -> Result<Arc<PreparedParams>> {
    let mut fixed: Vec<(usize, &HostTensor)> =
        Vec::with_capacity(plan.param_slots.len());
    for (slot, name) in &plan.param_slots {
        let t = store.get(name).with_context(|| {
            format!("fwd input param:{name} missing from parameter store")
        })?;
        fixed.push((*slot, t));
    }
    rt.prepare(&plan.artifact, store.generation(), &fixed)
}

// ---------------------------------------------------------------------------
// DeviceExecutor
// ---------------------------------------------------------------------------

/// The fwd graph consumes only backbone `param:*` tensors; a delta whose
/// task state lives outside the backbone (VPT prompt, adapter stacks in
/// `extra`) cannot be served through it — refusing loudly beats silently
/// answering with an un-adapted forward path.
fn ensure_servable(delta: &TaskDelta) -> Result<()> {
    if !delta.extra.is_empty() {
        let names: Vec<&str> =
            delta.extra.keys().map(|k| k.as_str()).collect();
        bail!(
            "delta for task {:?} (strategy {:?}) carries auxiliary tensors \
             {names:?} with no backbone slot — the fwd graph cannot serve \
             this family via backbone+delta",
            delta.task,
            delta.strategy
        );
    }
    Ok(())
}

/// A task's live parameter state: the adapted store plus its prepared
/// literal set, replaced together so a batch can never pair one swap's
/// store with another swap's literals.
#[derive(Clone)]
struct LiveParams {
    params: Arc<ParamStore>,
    prepared: Arc<PreparedParams>,
}

struct TaskState {
    name: String,
    /// the frozen shared backbone — kept so `swap_delta` can re-derive an
    /// adapted parameter set from any delta for this task
    backbone: Arc<ParamStore>,
    /// serializes swaps for this task: a donation refreshes the prepared
    /// set in place, and two concurrent donations into one set could
    /// interleave slot refreshes across two generations. Ranked before
    /// every runtime lock (the fallback path compiles + prepares under it).
    swap: Mutex<()>,
    /// workers snapshot this per sub-batch: swaps land at batch boundaries
    live: RwLock<LiveParams>,
    stats: Mutex<ServerStats>,
}

/// One shared, work-conserving worker pool serving every task on the
/// device. Built via [`DeviceBuilder`]; most callers use it through
/// [`Router`] (by task name) or [`Server`] (single task).
pub struct DeviceExecutor {
    rt: Arc<Runtime>,
    plan: BatchPlan,
    tasks: Vec<TaskState>,
    sched: Scheduler,
    workers: usize,
    // lock-free device counters: workers must not serialize on a stats
    // mutex once per dispatch (same rationale as RuntimeStats' atomics)
    dispatches: AtomicUsize,
    task_switches: AtomicUsize,
}

impl DeviceExecutor {
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn task_name(&self, task: usize) -> Option<&str> {
        self.tasks.get(task).map(|t| t.name.as_str())
    }

    fn task(&self, task: usize) -> Result<&TaskState> {
        self.tasks.get(task).with_context(|| {
            format!("no task #{task} on this executor ({} tasks)", self.tasks.len())
        })
    }

    /// Submit a single-image request for `task`; the response arrives on
    /// the returned receiver. Fails fast when the image is mis-sized, the
    /// executor is shut down, or the task's queue is at its bound.
    pub fn submit(&self, task: usize, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        let ts = self.task(task)?;
        if image.len() != self.plan.image_numel {
            bail!(
                "image has {} values, expected {}",
                image.len(),
                self.plan.image_numel
            );
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { image, respond: tx, submitted: Instant::now() };
        match self.sched.push(task, req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                if e == PushError::Full {
                    ts.stats.lock().unwrap().rejected += 1;
                }
                bail!("task {:?}: {e}", ts.name);
            }
        }
    }

    /// Atomically replace `task`'s live parameter set with
    /// `backbone + delta`. All parameter staging happens **here**, off the
    /// hot path: by the time the new `Arc` is published, its prepared set
    /// is ready, so the very next sub-batch runs the new parameters with
    /// zero conversion work and zero stale literals. The queue is never
    /// drained and no request is dropped. On validation failure the old
    /// set keeps serving.
    ///
    /// When this task is the **sole owner** of its prepared set (no
    /// sibling task shares the `Arc`; sharing arises only when several
    /// tasks registered the identical parameter generation and hit the
    /// runtime's prepared-set memo), the swap *donates*: only the tensors
    /// the delta actually changed are converted and re-uploaded, in place,
    /// re-keyed to the adapted store's generation
    /// ([`Runtime::donate_writeback`]) — delta-sized bus traffic instead
    /// of backbone-sized. A shared set falls back to a full
    /// [`Runtime::prepare`] so siblings keep serving their own weights.
    /// Either way a batch never tears: workers bind a single atomic
    /// snapshot of the set's slots per sub-batch.
    pub fn swap_delta(&self, task: usize, delta: &TaskDelta) -> Result<()> {
        ensure_servable(delta)?;
        let ts = self.task(task)?;
        let _swap = ts.swap.lock().unwrap();
        let adapted = Arc::new(delta.apply_to(&ts.backbone)?);
        let old = ts.live.read().unwrap().clone();
        let prepared = match self.donate_swap(task, &old, &adapted)? {
            Some(donated) => donated,
            None => prepare_store(&self.rt, &self.plan, &adapted)?,
        };
        *ts.live.write().unwrap() = LiveParams { params: adapted, prepared };
        ts.stats.lock().unwrap().swaps += 1;
        Ok(())
    }

    /// Donation fast path for [`DeviceExecutor::swap_delta`]: refresh the
    /// delta-touched tensors inside the task's existing prepared set
    /// instead of converting and re-uploading the whole store. Returns
    /// `None` when a sibling task shares the set — donating into a shared
    /// set would hot-swap the sibling's weights too. Caller holds the
    /// task's swap lock, so this task is the only possible donor.
    fn donate_swap(
        &self,
        task: usize,
        old: &LiveParams,
        adapted: &ParamStore,
    ) -> Result<Option<Arc<PreparedParams>>> {
        let shared = self.tasks.iter().enumerate().any(|(i, t)| {
            i != task
                && Arc::ptr_eq(&t.live.read().unwrap().prepared, &old.prepared)
        });
        if shared {
            return Ok(None);
        }
        // diff against the set's current contents, not the delta's keys:
        // swapping delta B after delta A must also revert the tensors A
        // touched and B does not. Unchanged slots keep their cached
        // literal and resident device buffer.
        let mut updates: Vec<(usize, &HostTensor)> = Vec::new();
        for (slot, name) in &self.plan.param_slots {
            let new = adapted.get(name).with_context(|| {
                format!("fwd input param:{name} missing from swapped-in store")
            })?;
            if old.params.get(name).map_or(true, |cur| cur != new) {
                updates.push((*slot, new));
            }
        }
        self.rt
            .donate_writeback(&old.prepared, adapted.generation(), &updates)?;
        Ok(Some(old.prepared.clone()))
    }

    /// Snapshot of the parameter set `task`'s next sub-batch will use.
    pub fn current_params(&self, task: usize) -> Result<Arc<ParamStore>> {
        Ok(self.task(task)?.live.read().unwrap().params.clone())
    }

    pub fn task_stats(&self, task: usize) -> Result<ServerStats> {
        Ok(self.task(task)?.stats.lock().unwrap().clone())
    }

    pub fn device_stats(&self) -> DeviceStats {
        let rs = self.rt.stats();
        DeviceStats {
            dispatches: self.dispatches.load(Ordering::Relaxed),
            task_switches: self.task_switches.load(Ordering::Relaxed),
            drr_rounds: self.sched.rounds(),
            workers: self.workers,
            resident_bytes: rs.resident_bytes,
            resident_evictions: rs.resident_evictions,
            donations: rs.donations,
            upload_savings_bytes: rs.h2d_resident_bytes,
        }
    }

    /// Run the shared pool: spawns the device's worker threads and blocks
    /// until [`DeviceExecutor::shutdown`] is called and every queue is
    /// drained. Workers sleep on the scheduler's condvar — no polling.
    pub fn run(&self) -> Result<()> {
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..self.workers {
                handles.push(scope.spawn(|| self.worker_loop()));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("serve worker panicked"))??;
            }
            Ok(())
        })
    }

    /// Signal shutdown: new submissions fail, pending requests are still
    /// batched and answered, then `run` returns.
    pub fn shutdown(&self) {
        self.sched.close();
    }

    fn worker_loop(&self) -> Result<()> {
        let mut prev_task: Option<usize> = None;
        while let Some((task, reqs)) = self.sched.next_work() {
            if let Err(e) = self.execute_batch(task, reqs) {
                // fail fast: close the queues so submitters get an error
                // (or a disconnected channel) instead of waiting on
                // responses that will never arrive from a dead worker
                self.sched.close();
                return Err(e);
            }
            self.dispatches.fetch_add(1, Ordering::Relaxed);
            if prev_task.is_some_and(|p| p != task) {
                self.task_switches.fetch_add(1, Ordering::Relaxed);
            }
            prev_task = Some(task);
        }
        Ok(())
    }

    fn execute_batch(&self, task: usize, reqs: Vec<Request>) -> Result<()> {
        let plan = &self.plan;
        let ts = &self.tasks[task];
        let n_real = reqs.len();
        debug_assert!(n_real > 0 && n_real <= plan.batch);
        let formed = Instant::now();

        // snapshot the live parameter state ONCE per sub-batch: a
        // concurrent swap lands a new (store, literals) pair without
        // tearing this batch
        let live = ts.live.read().unwrap().clone();

        // assemble (batch, H, W, C), padding with replicas of row 0 —
        // the only host->literal conversion on this path
        let mut data = Vec::with_capacity(plan.batch * plan.image_numel);
        for r in &reqs {
            data.extend_from_slice(&r.image);
        }
        for _ in n_real..plan.batch {
            data.extend_from_slice(&reqs[0].image);
        }
        let images = HostTensor::from_f32(&plan.image_shape, data)?;

        let t_exec = Instant::now();
        let outputs = self.rt.execute_prepared(&live.prepared, &[&images])?;
        let exec_elapsed = t_exec.elapsed();
        let logits = outputs
            .get(plan.logits_index)
            .context("fwd returned no logits output")?
            .f32s()?;

        {
            let mut st = ts.stats.lock().unwrap();
            st.requests += n_real;
            st.batches += 1;
            st.padded_rows += plan.batch - n_real;
            st.execute.record(exec_elapsed);
            for r in &reqs {
                st.queue.record(formed.duration_since(r.submitted));
            }
        }
        for (i, req) in reqs.into_iter().enumerate() {
            let row = &logits[i * plan.num_classes..(i + 1) * plan.num_classes];
            let _ = req.respond.send(Response {
                logits: row.to_vec(),
                argmax: argmax(row),
                latency: req.submitted.elapsed(),
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// DeviceBuilder
// ---------------------------------------------------------------------------

struct PendingTask {
    name: String,
    backbone: Arc<ParamStore>,
    adapted: Arc<ParamStore>,
    weight: f64,
    capacity: usize,
}

/// Assembles a [`DeviceExecutor`] + [`Router`]: register every task the
/// device serves (plain parameter sets or `backbone + TaskDelta`), then
/// `build()`. Parameter literals are prepared during `build`, so the
/// first request pays no conversion cost.
pub struct DeviceBuilder {
    rt: Arc<Runtime>,
    config_name: String,
    cfg: DeviceConfig,
    tasks: Vec<PendingTask>,
}

impl DeviceBuilder {
    pub fn new(rt: Arc<Runtime>, config_name: &str, cfg: DeviceConfig) -> DeviceBuilder {
        DeviceBuilder {
            rt,
            config_name: config_name.to_string(),
            cfg,
            tasks: Vec::new(),
        }
    }

    /// Register a task served with `params` as-is (e.g. the frozen
    /// backbone, or a fully materialized adapted store).
    pub fn add_task(
        &mut self,
        name: &str,
        params: Arc<ParamStore>,
        tcfg: TaskConfig,
    ) -> Result<()> {
        self.push_task(name, params.clone(), params, tcfg)
    }

    /// Register a task served as `backbone + delta` — the deployment
    /// contract of the TaskDelta subsystem. Fails for deltas carrying
    /// `extra` tensors (VPT prompt, adapter stacks): the fwd graph has no
    /// input for them, so serving would silently answer with the
    /// un-adapted forward path. Task-label/name agreement is the caller's
    /// contract (see [`Router::swap_delta`] for the serving-time guard).
    pub fn add_task_from_delta(
        &mut self,
        name: &str,
        backbone: Arc<ParamStore>,
        delta: &TaskDelta,
        tcfg: TaskConfig,
    ) -> Result<()> {
        ensure_servable(delta)?;
        let adapted = Arc::new(delta.apply_to(&backbone)?);
        self.push_task(name, backbone, adapted, tcfg)
    }

    fn push_task(
        &mut self,
        name: &str,
        backbone: Arc<ParamStore>,
        adapted: Arc<ParamStore>,
        tcfg: TaskConfig,
    ) -> Result<()> {
        if self.tasks.iter().any(|t| t.name == name) {
            bail!("task {name:?} registered twice on this device");
        }
        // an inf/NaN weight would starve every other task, and an
        // out-of-range one would be silently served at the scheduler's
        // clamp bound — reject loudly instead
        if !tcfg.weight.is_finite()
            || tcfg.weight < MIN_WEIGHT
            || tcfg.weight > MAX_WEIGHT
        {
            bail!(
                "task {name:?}: scheduling weight must be a finite number \
                 in [{MIN_WEIGHT}, {MAX_WEIGHT}], got {}",
                tcfg.weight
            );
        }
        self.tasks.push(PendingTask {
            name: name.to_string(),
            backbone,
            adapted,
            weight: tcfg.weight,
            capacity: tcfg.max_queue.unwrap_or(self.cfg.max_queue),
        });
        Ok(())
    }

    /// Resolve the batch plan, prepare every task's parameter literals
    /// (conversion happens here, not on the first batch), and assemble the
    /// executor behind a [`Router`].
    pub fn build(self) -> Result<Router> {
        if self.tasks.is_empty() {
            bail!("device executor needs at least one task");
        }
        let plan = BatchPlan::new(&self.rt, &self.config_name)?;
        let mut index = BTreeMap::new();
        let mut states = Vec::with_capacity(self.tasks.len());
        let mut queue_cfg = Vec::with_capacity(self.tasks.len());
        for (i, t) in self.tasks.into_iter().enumerate() {
            let prepared = prepare_store(&self.rt, &plan, &t.adapted)?;
            index.insert(t.name.clone(), i);
            states.push(TaskState {
                name: t.name,
                backbone: t.backbone,
                swap: Mutex::new(()),
                live: RwLock::new(LiveParams { params: t.adapted, prepared }),
                stats: Mutex::new(ServerStats::default()),
            });
            queue_cfg.push((t.weight, t.capacity));
        }
        let sched = Scheduler::new(plan.batch, self.cfg.linger, &queue_cfg);
        let exec = Arc::new(DeviceExecutor {
            rt: self.rt,
            plan,
            tasks: states,
            sched,
            workers: self.cfg.workers.max(1),
            dispatches: AtomicUsize::new(0),
            task_switches: AtomicUsize::new(0),
        });
        Ok(Router { exec, index })
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Device-level facade over one shared [`DeviceExecutor`]: routes by task
/// name, swaps adapters, aggregates stats — the "many task-specific models
/// on one device" deployment the paper motivates, now with one
/// work-conserving worker pool instead of one isolated pool per task.
pub struct Router {
    exec: Arc<DeviceExecutor>,
    index: BTreeMap<String, usize>,
}

/// Aggregate view over every routed task: per-task snapshots, a merged
/// total (histograms merge bucket-wise, so total quantiles are exact over
/// the union of samples up to bucket resolution), and the device-level
/// scheduling counters.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub per_task: BTreeMap<String, ServerStats>,
    pub total: ServerStats,
    pub device: DeviceStats,
}

impl Router {
    fn task_id(&self, task: &str) -> Result<usize> {
        self.index
            .get(task)
            .copied()
            .with_context(|| format!("no adapted model for task {task:?}"))
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.index.keys().map(|s| s.as_str()).collect()
    }

    /// The shared executor (e.g. to hold it across threads).
    pub fn executor(&self) -> Arc<DeviceExecutor> {
        self.exec.clone()
    }

    pub fn submit(&self, task: &str, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.exec.submit(self.task_id(task)?, image)
    }

    /// Hot-swap one routed task's fine-tuned parameter set (see
    /// [`DeviceExecutor::swap_delta`]): live, no drain, next-batch-boundary,
    /// prepared literals replaced in the same atomic publish. Refuses a
    /// delta labeled for a different task — a wrong-task swap would
    /// silently answer every `task` request with another task's weights
    /// (clear `delta.task` for deliberately generic payloads).
    pub fn swap_delta(&self, task: &str, delta: &TaskDelta) -> Result<()> {
        if !delta.task.is_empty() && delta.task != task {
            bail!(
                "delta is labeled for task {:?}; refusing to swap it into \
                 the server for task {task:?}",
                delta.task
            );
        }
        self.exec.swap_delta(self.task_id(task)?, delta)
    }

    /// Snapshot of the parameter set `task`'s next sub-batch will use.
    pub fn current_params(&self, task: &str) -> Result<Arc<ParamStore>> {
        self.exec.current_params(self.task_id(task)?)
    }

    /// Snapshot every task's stats, the cross-task aggregate, and the
    /// device-level scheduler counters.
    pub fn stats(&self) -> RouterStats {
        let mut total = ServerStats::default();
        let per_task: BTreeMap<String, ServerStats> = self
            .index
            .iter()
            .map(|(task, &id)| {
                let st = self
                    .exec
                    .task_stats(id)
                    // lint:allow(panic): every id in self.index came from
                    // push_task on this executor; absence is memory
                    // corruption, not a recoverable state
                    .expect("router index out of sync with executor");
                total.merge(&st);
                (task.clone(), st)
            })
            .collect();
        RouterStats { per_task, total, device: self.exec.device_stats() }
    }

    /// Run the shared worker pool (blocks; see [`DeviceExecutor::run`]).
    pub fn run(&self) -> Result<()> {
        self.exec.run()
    }

    /// Signal shutdown on the shared executor; `run` returns after every
    /// queue is drained and answered.
    pub fn shutdown(&self) {
        self.exec.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Server: single-task wrapper over a private executor
// ---------------------------------------------------------------------------

/// The internal task name a [`Server`] registers on its private executor.
const SOLO_TASK: &str = "task";

/// A single task served by its own private [`DeviceExecutor`] — the
/// convenience wrapper for tests, examples, and single-model deployments.
/// Multi-task devices should share one executor via [`DeviceBuilder`] /
/// [`Router`] instead of running one `Server` per task.
pub struct Server {
    exec: Arc<DeviceExecutor>,
}

impl Server {
    /// Build a server for `config_name`'s fwd artifact with the adapted
    /// parameters (backbone + fine-tuned tensors). The batch plan and the
    /// parameter literal set are resolved here, so the serving hot path
    /// never touches the manifest and never converts parameters.
    pub fn new(
        rt: Arc<Runtime>,
        config_name: &str,
        params: Arc<ParamStore>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let mut b = DeviceBuilder::new(rt, config_name, cfg);
        b.add_task(SOLO_TASK, params, TaskConfig::default())?;
        Ok(Server { exec: b.build()?.executor() })
    }

    /// Build a server from `backbone + delta` — the deployment contract of
    /// the TaskDelta subsystem: the (shared, immutable) backbone plus one
    /// task's sparse delta fully determine a serving parameter set.
    ///
    /// Fails for deltas carrying `extra` tensors (VPT prompt, adapter
    /// stacks): the fwd graph has no input for them, so serving would
    /// silently answer with the un-adapted forward path.
    pub fn from_delta(
        rt: Arc<Runtime>,
        config_name: &str,
        backbone: Arc<ParamStore>,
        delta: &TaskDelta,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let mut b = DeviceBuilder::new(rt, config_name, cfg);
        b.add_task_from_delta(SOLO_TASK, backbone, delta, TaskConfig::default())?;
        Ok(Server { exec: b.build()?.executor() })
    }

    /// Atomically replace the live parameter set with `backbone + delta`
    /// (see [`DeviceExecutor::swap_delta`]).
    pub fn swap_delta(&self, delta: &TaskDelta) -> Result<()> {
        self.exec.swap_delta(0, delta)
    }

    /// Snapshot of the parameter set new batches will execute with.
    pub fn current_params(&self) -> Arc<ParamStore> {
        // lint:allow(panic): both constructors register task 0 before
        // handing out the Server
        self.exec.current_params(0).expect("solo task exists")
    }

    pub fn stats(&self) -> ServerStats {
        // lint:allow(panic): both constructors register task 0 before
        // handing out the Server
        self.exec.task_stats(0).expect("solo task exists")
    }

    /// Submit a request; the response arrives on the returned receiver.
    /// Fails fast when the image is mis-sized, the server is shut down, or
    /// the queue is at its bound (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.exec.submit(0, image)
    }

    /// Run the serving loop (blocks until [`Server::shutdown`] + drain).
    pub fn run(&self) -> Result<()> {
        self.exec.run()
    }

    /// Signal shutdown: new submissions fail, pending requests are still
    /// batched and answered, then `run` returns.
    pub fn shutdown(&self) {
        self.exec.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Engine unit tests (no PJRT runtime required)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        let (tx, _rx) = mpsc::channel();
        Request { image: Vec::new(), respond: tx, submitted: Instant::now() }
    }

    /// One-queue scheduler with the given batch/linger (legacy shape).
    fn solo(capacity: usize, batch: usize, linger: Duration) -> Scheduler {
        Scheduler::new(batch, linger, &[(1.0, capacity)])
    }

    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        // regression: a NaN logit used to panic the worker via
        // partial_cmp().unwrap(); total_cmp orders +NaN above +inf
        let row = [0.1f32, f32::NAN, 0.9, f32::INFINITY];
        assert_eq!(argmax(&row), 1);
        // no NaN: plain maximum
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        // ties: first index wins (numpy semantics)
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
        // genuinely empty row: 0
        assert_eq!(argmax(&[]), 0);
        // -NaN sorts below everything
        assert_eq!(argmax(&[-f32::NAN, -1.0]), 1);
    }

    #[test]
    fn aux_deltas_are_rejected_for_serving() {
        // a VPT/adapter delta's task state has no backbone slot: serving it
        // through the fwd graph would silently ignore the adaptation
        let mut delta = TaskDelta::new("micro");
        delta.extra.insert("prompt".into(), HostTensor::zeros(&[2, 4]));
        assert!(ensure_servable(&delta).is_err());
        delta.extra.clear();
        assert!(ensure_servable(&delta).is_ok());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = solo(2, 16, Duration::from_secs(1));
        assert!(q.push(0, req()).is_ok());
        assert!(q.push(0, req()).is_ok());
        assert_eq!(q.push(0, req()).unwrap_err(), PushError::Full);
        // draining frees capacity again (closed flush returns the backlog)
        q.close();
        assert_eq!(q.next_work().map(|(_, b)| b.len()), Some(2));
        assert_eq!(q.push(0, req()).unwrap_err(), PushError::Closed);
    }

    #[test]
    fn full_batch_wakes_worker_immediately() {
        // linger is effectively infinite: only the full-batch condition can
        // release the worker, and it must do so without any polling delay
        let q = Arc::new(solo(64, 4, Duration::from_secs(3600)));
        let t0 = Instant::now();
        let batch = std::thread::scope(|scope| {
            let qc = q.clone();
            let h = scope.spawn(move || qc.next_work());
            for _ in 0..4 {
                q.push(0, req()).unwrap();
            }
            h.join().unwrap()
        });
        assert_eq!(batch.map(|(_, b)| b.len()), Some(4));
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "full batch did not wake the worker"
        );
        assert_eq!(q.len(0), 0);
    }

    #[test]
    fn linger_flushes_partial_batch_within_deadline() {
        let linger = Duration::from_millis(50);
        let q = solo(64, 16, linger);
        q.push(0, req()).unwrap();
        q.push(0, req()).unwrap();
        // next_work blocks on wait_timeout until the oldest request's
        // deadline, then flushes the partial batch — no polling loop
        let (_, batch) = q.next_work().expect("linger flush produced no batch");
        assert_eq!(batch.len(), 2);
        // the flush happened at (not before) the oldest request's deadline
        assert!(
            batch[0].submitted.elapsed() >= linger,
            "partial batch flushed before the linger deadline"
        );
    }

    #[test]
    fn shutdown_drains_pending_then_ends() {
        let q = solo(64, 16, Duration::from_secs(3600));
        for _ in 0..3 {
            q.push(0, req()).unwrap();
        }
        q.close();
        // the pending partial batch is flushed despite the huge linger...
        assert_eq!(q.next_work().map(|(_, b)| b.len()), Some(3));
        // ...and only then does the queue report end-of-stream
        assert!(q.next_work().is_none());
        assert!(q.next_work().is_none());
    }

    #[test]
    fn close_wakes_idle_workers() {
        let q = Arc::new(solo(64, 16, Duration::from_secs(3600)));
        let got = std::thread::scope(|scope| {
            let qc = q.clone();
            let h = scope.spawn(move || qc.next_work());
            // let the worker reach the condvar wait, then close
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            h.join().unwrap()
        });
        assert!(got.is_none(), "close must release workers blocked on empty queue");
    }

    #[test]
    fn drr_dispatch_follows_weights() {
        // two deep backlogs, weights 3:1 — dispatched full batches must
        // follow the weight ratio (deterministic single-consumer trace)
        let batch = 4;
        let s = Scheduler::new(batch, Duration::from_secs(3600),
                               &[(3.0, 256), (1.0, 256)]);
        for _ in 0..40 {
            s.push(0, req()).unwrap();
            s.push(1, req()).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            let (task, reqs) = s.next_work().expect("backlog must dispatch");
            assert_eq!(reqs.len(), batch, "deep backlog: full batches only");
            counts[task] += 1;
        }
        assert_eq!(
            counts, [6, 2],
            "8 full batches at weights 3:1 must split 6:2"
        );
        assert!(s.rounds() > 0, "DRR must have replenished deficits");
    }

    #[test]
    fn expired_partial_preempts_full_batches() {
        // task 1 has one lingering request; task 0 floods. Once the linger
        // deadline passes, the next dispatch must flush task 1's partial
        // sub-batch ahead of task 0's remaining full batches.
        let batch = 4;
        let linger = Duration::from_millis(150);
        let s = Scheduler::new(batch, linger, &[(1.0, 256), (1.0, 256)]);
        s.push(1, req()).unwrap();
        for _ in 0..12 {
            s.push(0, req()).unwrap();
        }
        // not yet expired: the flood's full batches dispatch first
        let (t0, b0) = s.next_work().unwrap();
        assert_eq!((t0, b0.len()), (0, batch));
        std::thread::sleep(linger + Duration::from_millis(50));
        let (t1, b1) = s.next_work().unwrap();
        assert_eq!(
            (t1, b1.len()),
            (1, 1),
            "expired partial must preempt remaining full batches"
        );
        // and the flood resumes afterwards
        let (t2, b2) = s.next_work().unwrap();
        assert_eq!((t2, b2.len()), (0, batch));
    }

    #[test]
    fn low_weight_task_still_dispatches() {
        // starvation guard at the scheduler level: a tiny-weight backlog
        // must still win dispatches among a heavy competitor's
        let batch = 4;
        let s = Scheduler::new(batch, Duration::from_secs(3600),
                               &[(8.0, 256), (0.1, 256)]);
        // flood: 4 full batches for the heavy task, 16 for the light one
        for _ in 0..16 {
            s.push(0, req()).unwrap();
        }
        for _ in 0..64 {
            s.push(1, req()).unwrap();
        }
        let mut saw_low = false;
        for _ in 0..8 {
            let (task, _) = s.next_work().unwrap();
            if task == 1 {
                saw_low = true;
                break;
            }
        }
        assert!(saw_low, "low-weight task starved across 8 dispatches");
    }

    #[test]
    fn non_finite_weight_cannot_starve_peers() {
        // regression: an inf weight used to pin its queue's deficit at
        // +inf, permanently starving every other task; the scheduler now
        // clamps non-finite weights to the floor
        let batch = 4;
        let s = Scheduler::new(batch, Duration::from_secs(3600),
                               &[(f64::INFINITY, 256), (1.0, 256)]);
        for _ in 0..48 {
            s.push(0, req()).unwrap();
            s.push(1, req()).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..10 {
            counts[s.next_work().unwrap().0] += 1;
        }
        assert!(counts[1] > 0, "finite-weight peer starved by inf weight");
        assert!(
            counts[1] >= counts[0],
            "inf weight must clamp to the floor, not dominate: {counts:?}"
        );
    }
}
