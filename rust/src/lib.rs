//! TaskEdge: task-aware parameter-efficient fine-tuning at the edge.
//!
//! Rust + JAX + Pallas reproduction of Hu et al., "Task-Aware
//! Parameter-Efficient Fine-Tuning of Large Pre-Trained Models at the Edge"
//! (CS.LG 2025). Three-layer architecture:
//!
//! - **L1** (`python/compile/kernels/`): Pallas kernels — importance scoring
//!   (Eq. 2), per-neuron top-K / N:M allocation (Alg. 1), masked AdamW/SGD
//!   sparse updates, fused sparse-LoRA delta (Eq. 6), MXU-tiled matmul.
//! - **L2** (`python/compile/{model,train}.py`): ViT backbone + train/eval/
//!   calibrate graphs, AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **L3** (this crate): the edge fine-tuning coordinator — PJRT runtime,
//!   calibration/scoring/allocation pipeline, PEFT strategy zoo, SynthVTAB
//!   benchmark data, edge-device cost model, fleet scheduler, CLI.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `taskedge` binary is self-contained.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod masking;
pub mod metrics;
pub mod net;
pub mod peft;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod vit;
pub mod harness;
